"""Quickstart: build any assigned architecture at reduced size, train a few
steps, and decode — the whole public API in 40 lines.

  PYTHONPATH=src python examples/quickstart.py --arch qwen3-moe-30b-a3b
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.config import TrainConfig, get_arch, list_archs, reduced
from repro.data import pipeline
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx
from repro.optimizer import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_arch(args.arch)), dtype="float32")
    ctx = ModelCtx(attn_chunk=8, mamba_chunk=4, moe_group=16)
    print(f"arch={cfg.name}  family={cfg.family}  "
          f"reduced params={sum(x.size for x in jax.tree.leaves(tf.init_params(jax.random.PRNGKey(0), cfg))):,}")

    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params)
    tcfg = TrainConfig(steps=args.steps, learning_rate=1e-3,
                       checkpoint_every=0)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: tf.loss_fn(cfg, p, batch, ctx), has_aux=True)(params)
        params, opt = adamw.adamw_apply(params, g, opt, 1e-3, tcfg)
        return params, opt, loss

    for i, batch in enumerate(pipeline.synthetic_lm_batches(
            cfg.vocab_size, 8, 32, args.steps)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.encoder_layers:
            batch["frames"] = jnp.zeros((8, cfg.encoder_frames, cfg.d_model),
                                        jnp.float32)
        if cfg.pos_type == "mrope":
            batch["patch_embeds"] = jnp.zeros(
                (8, int(cfg.image_prefix_frac * 32), cfg.d_model), jnp.float32)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(32)[None, :, None], (8, 32, 3)).astype(jnp.int32)
        params, opt, loss = step(params, opt, batch)
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}")

    # greedy decode a few tokens
    if cfg.pos_type != "mrope":
        cache = tf.init_cache(cfg, 1, 16)
        if cfg.encoder_layers:
            ck, cv = tf.whisper_prefill_cross(
                cfg, params, jnp.zeros((1, cfg.encoder_frames, cfg.d_model),
                                       jnp.float32), ctx)
            cache["cross_k"], cache["cross_v"] = ck, cv
        tok = jnp.ones((1, 1), jnp.int32)
        out = []
        for _ in range(8):
            logits, cache = tf.decode_step(cfg, params, cache, tok, ctx)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            out.append(int(tok[0, 0]))
        print("greedy decode:", out)


if __name__ == "__main__":
    main()
