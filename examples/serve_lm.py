"""Serving example: the continuous-batching engine on a reduced LM.

The fixed-slot `SlotServer` toy that used to live here grew into
``src/repro/serving`` — a first-class engine with prefill-on-arrival, a
bounded admission queue, static/continuous refill policies, an optional
int8 KV cache, and SLO-aware latency metrics.  This example drives it over
a small simulated recsys workload and prints both the generations and the
latency report.  Every architecture family serves through the engine's
family-backend registry — try ``--arch rwkv6-1.6b`` or ``--arch
whisper-medium`` as readily as a uniform decoder.

  PYTHONPATH=src python examples/serve_lm.py --arch olmo-1b --requests 12
"""
import argparse
import dataclasses

import jax

from repro.cache_layout import CacheLayout
from repro.config import get_arch, list_archs, reduced
from repro.models import transformer as tf
from repro.serving import (EngineConfig, ServingEngine, TrafficConfig,
                           generate)
from repro.serving.engine import make_backend
from repro.serving.metrics import format_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=64.0)
    ap.add_argument("--kv", default="native", choices=("native", "int8"))
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_arch(args.arch)), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)

    requests = generate(TrafficConfig(
        n_requests=args.requests, rate=args.rate, prompt_max=24,
        new_tokens_max=16, vocab_size=cfg.vocab_size,
        encoder_frames=cfg.encoder_frames,
        frame_dim=cfg.d_model if cfg.encoder_layers else 0))
    layout = CacheLayout(kv_bits=8 if args.kv == "int8" else 16)
    engine = ServingEngine(make_backend(cfg, params, layout=layout),
                           EngineConfig(n_slots=args.slots, max_len=64))
    outputs, records, summary = engine.run(requests)

    for rec in records:
        state = "rejected" if rec.rejected else \
            f"user {rec.user_id:5d} -> {outputs[rec.rid][:8]}..."
        print(f"request {rec.rid:3d} [{rec.slo_name:11s}] {state}")
    print(format_report(summary, f"{cfg.name} x{args.slots} slots"))


if __name__ == "__main__":
    main()
