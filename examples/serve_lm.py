"""Serving example: batched request serving with slot-based continuous
batching — prefill on arrival, interleaved decode for active slots.

  PYTHONPATH=src python examples/serve_lm.py --arch olmo-1b --requests 12
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, list_archs, reduced
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx


class SlotServer:
    """Fixed-slot continuous batching: each slot holds one request's cache
    row; finished slots are refilled from the queue (the TPU-idiomatic
    version of vLLM-style batching: static shapes, per-slot lengths)."""

    def __init__(self, cfg, params, n_slots: int, max_len: int, ctx):
        self.cfg, self.params, self.ctx = cfg, params, ctx
        self.n_slots, self.max_len = n_slots, max_len
        self.cache = tf.init_cache(cfg, n_slots, max_len)
        self.active = np.zeros(n_slots, bool)
        self.remaining = np.zeros(n_slots, np.int32)
        self.outputs = [[] for _ in range(n_slots)]
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t: tf.decode_step(cfg, p, c, t, ctx))

    def add_request(self, slot: int, prompt, max_new: int):
        # prefill = teacher-forced decode of the prompt into the cache row
        # (a batched prefill kernel is the production path; slot-wise decode
        # keeps this example simple)
        for t in prompt:
            tok = self.tokens.at[slot, 0].set(int(t))
            _, self.cache = self._decode(self.params, self.cache, tok)
        self.active[slot] = True
        self.remaining[slot] = max_new
        self.tokens = self.tokens.at[slot, 0].set(int(prompt[-1]))

    def step(self):
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens)
        nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        done = []
        for s in range(self.n_slots):
            if self.active[s]:
                self.outputs[s].append(int(nxt[s]))
                self.remaining[s] -= 1
                if self.remaining[s] <= 0:
                    self.active[s] = False
                    done.append(s)
        return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_arch(args.arch)), dtype="float32")
    if cfg.pos_type == "mrope" or cfg.encoder_layers:
        raise SystemExit("serve_lm demo targets text decoder archs")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    ctx = ModelCtx(attn_chunk=8, mamba_chunk=4, moe_group=8)
    server = SlotServer(cfg, params, args.slots, 128, ctx)

    rng = np.random.default_rng(0)
    queue = [rng.integers(3, cfg.vocab_size, size=rng.integers(4, 10)).tolist()
             for _ in range(args.requests)]
    served = 0
    for s in range(min(args.slots, len(queue))):
        server.add_request(s, queue.pop(0), args.new_tokens)

    t0 = time.perf_counter()
    tokens_out = 0
    while server.active.any() or queue:
        done = server.step()
        tokens_out += int(server.active.sum()) + len(done)
        for s in done:
            served += 1
            print(f"request {served} done: {server.outputs[s][:8]}...")
            server.outputs[s] = []
            if queue:
                server.add_request(s, queue.pop(0), args.new_tokens)
    dt = time.perf_counter() - t0
    print(f"served {served + len([1 for o in server.outputs if o])} requests,"
          f" ~{tokens_out / dt:.1f} tokens/s (host CPU)")


if __name__ == "__main__":
    main()
