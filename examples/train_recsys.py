"""End-to-end driver (deliverable b): train the paper's RecLLM recommender
on the synthetic Amazon-Electronics dataset with the full runtime —
checkpointing/restart, LR schedule, gradient clipping, HR@10/NDCG@10 eval.

Default config is CPU-sized; ``--full`` selects the ~160M recllm-base
(paper-scale backbone — expect hours on CPU, minutes on accelerators).

  PYTHONPATH=src python examples/train_recsys.py --steps 200
"""
import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import embeddings
from repro.config import TrainConfig, get_arch, reduced
from repro.models.transformer import ModelCtx
from repro.optimizer import adamw, schedule
from repro.recsys import dataset, metrics, model as recmodel
from repro.runtime import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--scale", type=float, default=0.01,
                    help="dataset scale (1.0 = full Table 1 sizes)")
    ap.add_argument("--full", action="store_true",
                    help="use the full recllm-base (~160M params)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_recsys_ckpt")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--embed-plan", default="replicated",
                    choices=embeddings.PLANS,
                    help="CF-table sharding plan to cost (placement summary"
                         " printed before training)")
    ap.add_argument("--embed-mesh", default="8,4",
                    help="data,model mesh extents for the placement summary")
    args = ap.parse_args()

    ds = dataset.generate(scale=args.scale, seed=0)
    print(f"dataset: {ds.n_users:,} users, {ds.n_items:,} items, "
          f"{len(ds.user):,} interactions (80/10/10 chronological)")

    base = get_arch("recllm-base")
    cfg = dataclasses.replace(
        base if args.full else reduced(base, layers=4),
        vocab_size=ds.n_items + 3, vocab_pad_to=64, dtype="float32")
    ctx = ModelCtx(attn_chunk=min(args.seq, 512))
    tcfg = TrainConfig(steps=args.steps, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 20, 5),
                       checkpoint_every=max(args.steps // 4, 25),
                       checkpoint_dir=args.ckpt_dir, keep_checkpoints=2)

    params = recmodel.init_recllm(jax.random.PRNGKey(0), cfg, ds.n_users)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"RecLLM params: {n/1e6:.1f}M  (backbone {cfg.num_layers}L "
          f"d={cfg.d_model})")

    # embedding placement: what each sharding plan would cost at scale
    dp, mp = (int(x) for x in args.embed_mesh.split(","))
    mesh_shape = {"data": dp, "model": mp}
    plan = embeddings.make_plan(args.embed_plan)
    batch_per_dev = max(1, args.batch // dp)
    for spec in recmodel.embed_specs(cfg, ds.n_users).values():
        try:
            s = embeddings.plan_summary(spec, plan, mesh_shape,
                                        batch_per_dev)
        except ValueError as e:                  # dims don't divide the mesh
            print(f"embed[{spec.name}] plan {plan.kind}: skipped ({e})")
            continue
        print(f"embed[{spec.name}] plan {plan.kind} on mesh {mesh_shape}: "
              f"shard ({s['shard_rows']},{s['shard_cols']}) = "
              f"{s['table_bytes_per_dev']/1e6:.2f} MB/dev, "
              f"exchange {s['modeled_exchange_bytes']['total']/1e6:.3f} "
              f"MB/step (sparse DP sync "
              f"{s['modeled_sparse_sync_bytes']/1e6:.3f} MB)")
    opt = adamw.init_opt_state(params)

    def loss_fn(p, b):
        return recmodel.recllm_loss(cfg, p, b, ctx)

    @jax.jit
    def step_fn(params, opt, batch):
        lr = schedule.warmup_cosine(opt["step"], tcfg.learning_rate,
                                    tcfg.warmup_steps, tcfg.steps)
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                 batch)
        params, opt = adamw.adamw_apply(params, g, opt, lr, tcfg)
        return params, opt, {"loss": loss}

    # fault tolerance: resume if a previous run died
    start, state = trainer.resume_or_init({"params": params, "opt": opt},
                                          tcfg)
    if start:
        print(f"resumed from checkpoint at step {start}")

    def batches():
        for b in dataset.seq_batches(ds, args.batch, args.seq,
                                     steps=args.steps - start, seed=start):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    res = trainer.train_loop(state, batches(), step_fn, tcfg,
                             start_step=start,
                             samples_per_batch=args.batch, verbose=True,
                             log_every=max(args.steps // 10, 1))
    print(f"throughput: {res.throughput:.1f} samples/s (host)")

    # --- evaluation: HR@10 / NDCG@10 with history exclusion ---------------
    toks, gold, lens = dataset.eval_examples(ds, seq_len=args.seq,
                                             max_users=256)
    users = jnp.zeros((toks.shape[0],), jnp.int32)
    scores = recmodel.score_users(cfg, state["params"], jnp.asarray(toks),
                                  users, jnp.asarray(lens), ctx)
    excl = jnp.asarray(metrics.history_exclusion(toks, cfg.padded_vocab))
    hr, ndcg = metrics.hr_ndcg_at_k(scores, jnp.asarray(gold), k=10,
                                    exclude=excl)
    rand_hr = 10 / ds.n_items
    print(f"HR@10 {float(hr):.4f}  NDCG@10 {float(ndcg):.4f}  "
          f"(random baseline HR@10 ~ {rand_hr:.4f})")


if __name__ == "__main__":
    main()
