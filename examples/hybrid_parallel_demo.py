"""Hybrid-parallelism demo (paper §III): the same model trained under four
gradient-sync regimes on an 8-device host mesh — flat ring All-Reduce
(Eq. 8), hierarchical All-Reduce (rack->pod analogue), 1-bit EF-signSGD
(Eq. 10), and top-k sparsification (Eq. 11) — printing loss curves and the
per-step wire bytes each scheme puts on the interconnect.

  PYTHONPATH=src python examples/hybrid_parallel_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat
from repro.config import TrainConfig, get_arch, reduced  # noqa: E402
from repro.data import pipeline  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.models.transformer import ModelCtx  # noqa: E402
from repro.optimizer import adamw  # noqa: E402
from repro.runtime import trainer  # noqa: E402


def main():
    cfg = dataclasses.replace(reduced(get_arch("recllm-base")),
                              dtype="float32")
    ctx = ModelCtx(attn_chunk=8)
    mesh = compat.make_mesh((2, 4), ("pod", "data"))
    tcfg = TrainConfig(steps=30, learning_rate=3e-3, warmup_steps=3,
                       checkpoint_every=0)

    def loss_fn(p, b):
        return tf.loss_fn(cfg, p, b, ctx)[0]

    data = [{k: jnp.asarray(v) for k, v in b.items()}
            for b in pipeline.synthetic_lm_batches(cfg.vocab_size, 32, 16,
                                                   30, seed=5)]
    n_params = sum(x.size for x in jax.tree.leaves(
        tf.init_params(jax.random.PRNGKey(0), cfg)))

    print(f"model: recllm reduced, {n_params:,} params; "
          f"mesh pod=2 x data=4\n")
    print(f"{'sync mode':16s} {'final loss':>10s} {'wire bytes/step':>16s}")
    for mode, inter in (("flat", None), ("hierarchical", "pod"),
                        ("onebit", None), ("topk", None)):
        scfg = trainer.DPSyncConfig(mode=mode, inter_axis=inter,
                                    block=512, topk_block=2048, k=64)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init_opt_state(params)
        n = trainer.residual_size(params, scfg)
        resid = jnp.zeros((8, n))
        step = trainer.make_dp_train_step(loss_fn, mesh, tcfg, scfg)
        losses = []
        for b in data:
            params, opt, resid, loss = step(params, opt, resid, b)
            losses.append(float(loss))
        if mode == "flat":
            wire = 2 * n_params * 4
        elif mode == "hierarchical":
            wire = n_params * 4 * (1 + 2 / 4)   # RS + cross-pod AR + AG
        elif mode == "onebit":
            wire = n // 8 + n // 512 * 4        # packed signs + scales
        else:
            wire = n // 2048 * 64 * 8           # (val, idx) x k per block
        print(f"{mode:16s} {losses[-1]:10.4f} {wire:16,}")
    print("\ncompression cuts wire bytes ~8-30x at equal convergence "
          "(paper §III.B).")


if __name__ == "__main__":
    main()
