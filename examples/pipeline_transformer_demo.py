"""Pipeline parallelism (paper C2) over REAL transformer layers: an
olmo-family reduced model split into 4 balanced stages on a 'stage' mesh,
GPipe micro-batching via shard_map + ppermute, end-to-end gradient training.

Verifies pipelined loss == serial loss, then trains a few steps.

  PYTHONPATH=src python examples/pipeline_transformer_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat
from repro.config import get_arch, reduced  # noqa: E402
from repro.core import load_balance, pipeline  # noqa: E402
from repro.core.hybrid import layer_flops  # noqa: E402
from repro.models import layers as L  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.models.transformer import ModelCtx  # noqa: E402

N_STAGES, N_MICRO, B, S = 4, 8, 16, 32


def main():
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), num_layers=16,
                              dtype="float32")
    ctx = ModelCtx(attn_chunk=16)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)

    # --- stage balancing (paper C4): contiguous layer partition ----------
    costs = [layer_flops(cfg, "attn", i, S) for i in range(cfg.num_layers)]
    bounds = load_balance.balance_stages(costs, N_STAGES)
    print(f"stage bounds {bounds} "
          f"(per-stage cost ratio "
          f"{load_balance.stage_costs(costs, bounds).max() / np.mean(load_balance.stage_costs(costs, bounds)):.3f})")
    per_stage = bounds[1] - bounds[0]
    assert all(bounds[i + 1] - bounds[i] == per_stage
               for i in range(N_STAGES)), "uniform layers -> equal split"

    # reshape stacked layer params (L, ...) -> (stages, layers/stage, ...)
    stage_params = jax.tree.map(
        lambda a: a.reshape((N_STAGES, per_stage) + a.shape[1:]),
        params["blocks"])

    positions = jnp.broadcast_to(jnp.arange(S)[None], (B // N_MICRO, S))

    def stage_fn(blocks, x):
        def body(h, blk):
            a, _ = tf.attn_apply(cfg, blk["attn"], h, positions, ctx)
            h = h + a
            f, _ = tf.ffn_apply(cfg, blk["ffn"], h, ctx)
            return h + f, None
        x, _ = jax.lax.scan(body, x, blocks)
        return x

    def last_fn(lp, y, tgt):
        h = L.apply_norm(cfg, lp["final_norm"], y)
        logits = L.lm_logits(cfg, {**lp, "embed": lp["embed"]}, h)
        return L.cross_entropy_loss(logits, tgt)

    mesh = compat.make_mesh((N_STAGES,), ("stage",))
    loss_fn = pipeline.make_pipeline_loss(stage_fn, last_fn, mesh,
                                          N_STAGES, N_MICRO)

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)), jnp.int32)
    targets = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)), jnp.int32)
    x = pipeline.microbatch(L.embed_tokens(params["embed"], tokens), N_MICRO)
    tgt = pipeline.microbatch(targets, N_MICRO)
    last_params = {"final_norm": params["final_norm"],
                   "embed": params["embed"]}

    # --- parity: pipelined == serial --------------------------------------
    loss_pipe = loss_fn(stage_params, last_params, x, tgt)
    h = L.embed_tokens(params["embed"], tokens)

    def serial_body(h, blk):
        a, _ = tf.attn_apply(cfg, blk["attn"], h,
                             jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
                             ctx)
        h = h + a
        f, _ = tf.ffn_apply(cfg, blk["ffn"], h, ctx)
        return h + f, None

    h, _ = jax.lax.scan(serial_body, h, params["blocks"])
    h = L.apply_norm(cfg, params["final_norm"], h)
    loss_serial = L.cross_entropy_loss(L.lm_logits(cfg, params, h), targets)
    print(f"pipelined loss {float(loss_pipe):.6f}  "
          f"serial loss {float(loss_serial):.6f}")
    np.testing.assert_allclose(float(loss_pipe), float(loss_serial),
                               rtol=2e-4)

    # --- train through the pipeline (GPipe backward via autodiff) ---------
    valgrad = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1)))
    sp, lp = stage_params, last_params
    for step in range(5):
        loss, (gs, gl) = valgrad(sp, lp, x, tgt)
        sp = jax.tree.map(lambda p, g: p - 0.5 * g, sp, gs)
        lp = jax.tree.map(lambda p, g: p - 0.5 * g, lp, gl)
        print(f"pipeline train step {step}: loss {float(loss):.4f}")
    assert float(loss) < float(loss_pipe)
    print("pipeline training converges ✓")

    # --- the unified path: stage-sliced transformer + both schedules ------
    pp = tf.pp_partition_params(cfg, params, bounds)
    st_fn = tf.make_stage_fn(cfg, ctx)
    la_fn = tf.make_last_fn(cfg, ctx)
    mask = pipeline.microbatch(jnp.ones((B, S)), N_MICRO)
    print("\nschedule       loss        bubble  stash(micros)")
    for sched in ("gpipe", "1f1b"):
        vag = jax.jit(pipeline.make_pipeline_value_and_grad(
            st_fn, la_fn, mesh, N_STAGES, N_MICRO, schedule=sched))
        l_s, _ = vag(pp["stage"], pp["last"], x, tgt, mask)
        c = pipeline.schedule_cost(sched, N_STAGES, N_MICRO)
        print(f"{sched:12s} {float(l_s):10.6f}  {c['bubble_frac']:6.2f} "
              f"{c['stash_micros']:8d}")
        np.testing.assert_allclose(float(l_s), float(loss_serial), rtol=2e-4)
    print("1F1B == GPipe == serial, at a quarter of the activation stash ✓")


if __name__ == "__main__":
    main()
