"""Subprocess payload for distributed benchmarks: builds one parallelism
scheme on N host devices, measures real step wall-time, and derives the
roofline/communication profile from the compiled HLO.

Run:  python -m benchmarks._dist_payload --scheme hybrid --devices 8 ...
Prints one line ``BENCH_JSON:{...}``.
"""
import argparse
import json
import os
import sys
import tempfile

ap = argparse.ArgumentParser()
ap.add_argument("--scheme", required=True,
                choices=("baseline", "dp", "mp", "hybrid", "hybrid_auto"))
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--steps", type=int, default=8)
ap.add_argument("--batch", type=int, default=32)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--d-model", type=int, default=128)
ap.add_argument("--sync", default="flat",
                choices=("flat", "hierarchical", "onebit", "topk"))
args = ap.parse_args()

_DUMP = tempfile.mkdtemp(prefix="bench_dump_")
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.devices}"
    f" --xla_dump_to={_DUMP}"
    " --xla_dump_hlo_pass_re=all-reduce-promotion"
    " --xla_dump_large_constants=false")

import dataclasses  # noqa: E402
import glob  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.analysis import hlo_cost  # noqa: E402
from repro.config import (PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK,  # noqa: E402
                          DCI_BW_PER_LINK, TrainConfig, ParallelConfig,
                          ShapeConfig, get_arch, reduced)
from repro.core.hybrid import auto_plan  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.optimizer import adamw  # noqa: E402
from repro.runtime import trainer  # noqa: E402
from repro.data import pipeline  # noqa: E402


def make_mesh(scheme, n):
    from repro import compat
    if scheme == "baseline":
        return compat.make_mesh((1, 1), ("data", "model"))
    if scheme == "dp":
        return compat.make_mesh((n, 1), ("data", "model"))
    if scheme == "mp":
        return compat.make_mesh((1, n), ("data", "model"))
    return compat.make_mesh((n // 2, 2), ("data", "model"))


cfg = dataclasses.replace(
    reduced(get_arch("recllm-base")),
    num_layers=args.layers, d_model=args.d_model,
    num_heads=8, num_kv_heads=8, head_dim=args.d_model // 8,
    d_ff=args.d_model * 4, vocab_size=8192, vocab_pad_to=256,
    dtype="float32")
mesh = make_mesh(args.scheme, args.devices)
shape = ShapeConfig("bench", args.seq, args.batch, "train")
plan = auto_plan(cfg, mesh, shape, ParallelConfig())
tcfg = TrainConfig(steps=args.steps, checkpoint_every=0)

step, jitted, shardings_for = trainer.make_hybrid_train_step(cfg, plan, tcfg)
params = tf.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init_opt_state(params)
data = list(pipeline.synthetic_lm_batches(cfg.vocab_size, args.batch,
                                          args.seq, args.steps + 3))
fn = jitted(jax.eval_shape(lambda: params), data[0])

losses = []
if args.devices <= 16:
    # measured wall time (host CPU — relative only; modeled numbers below)
    params, opt, m = fn(params, opt, data[0])
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for b in data[1:args.steps + 1]:
        params, opt, m = fn(params, opt, b)
        losses.append(float(m["loss"]))
    dt = (time.perf_counter() - t0) / args.steps
else:
    # >16 virtual devices on one core aborts XLA:CPU thunk execution;
    # compile-only (the roofline numbers come from the dump anyway)
    fn.lower(jax.eval_shape(lambda: params),
             jax.eval_shape(lambda: opt), data[0]).compile()
    dt = float("nan")

# roofline from the dump
files = sorted(glob.glob(os.path.join(_DUMP, "*jit_step*"
                                      "before_all-reduce-promotion.txt")))
costs = hlo_cost.analyze(open(files[-1]).read() if files else "",
                         mesh.size)
t_compute = costs.flops / PEAK_FLOPS_BF16
t_memory = costs.bytes / HBM_BW
t_coll = (costs.coll_intra / ICI_BW_PER_LINK
          + costs.coll_cross / DCI_BW_PER_LINK)
t_bound = max(t_compute, t_memory, t_coll, 1e-12)

out = {
    "scheme": args.scheme, "devices": mesh.size,
    "host_step_ms": dt * 1e3,
    "losses": losses[:5],
    "flops_per_dev": costs.flops,
    "bytes_per_dev": costs.bytes,
    "coll_bytes_per_dev": costs.coll_total,
    "t_compute_ms": t_compute * 1e3,
    "t_memory_ms": t_memory * 1e3,
    "t_collective_ms": t_coll * 1e3,
    "modeled_throughput": args.batch / t_bound,
    "comm_fraction": t_coll / (t_coll + max(t_compute, t_memory)),
}
print("BENCH_JSON:" + json.dumps(out))
