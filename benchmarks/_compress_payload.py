"""Subprocess payload: compression ablation on real 8-way DP training of the
RecLLM recommender — reproduces the paper's claim that 1-bit / top-k
gradient compression does not degrade HR@10 / NDCG@10 (§III.B, Table 2).
"""
import json
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.config import TrainConfig, get_arch, reduced  # noqa: E402
from repro.models.transformer import ModelCtx  # noqa: E402
from repro.optimizer import adamw  # noqa: E402
from repro.recsys import dataset, metrics, model as recmodel  # noqa: E402
from repro.runtime import trainer  # noqa: E402

ds = dataset.generate(scale=0.005, seed=0)
cfg = dataclasses.replace(reduced(get_arch("recllm-base")),
                          vocab_size=ds.n_items + 3, vocab_pad_to=32,
                          dtype="float32")
ctx = ModelCtx(attn_chunk=8)
mesh = compat.make_mesh((8,), ("data",))
STEPS = 50


def loss_fn(p, b):
    return recmodel.recllm_loss(cfg, p, b, ctx)[0]


toks, gold, lens = dataset.eval_examples(ds, seq_len=16, max_users=128)
users = jnp.zeros((toks.shape[0],), jnp.int32)


def eval_hr(p):
    scores = recmodel.score_users(cfg, p, jnp.asarray(toks), users,
                                  jnp.asarray(lens), ctx)
    hr, ndcg = metrics.hr_ndcg_at_k(scores, jnp.asarray(gold), k=10)
    return float(hr), float(ndcg)


out = {}
N_PARAMS = None
for mode in ("flat", "hierarchical", "onebit", "topk"):
    params = recmodel.init_recllm(jax.random.PRNGKey(0), cfg, ds.n_users)
    opt = adamw.init_opt_state(params)
    tcfg = TrainConfig(steps=STEPS, learning_rate=1e-2, warmup_steps=5,
                       weight_decay=0.0, grad_clip=1.0, checkpoint_every=0)
    scfg = trainer.DPSyncConfig(
        mode=mode, block=512, topk_block=2048, k=64,
        inter_axis=None)
    n = trainer.residual_size(params, scfg)
    resid = jnp.zeros((8, n))
    step = trainer.make_dp_train_step(loss_fn, mesh, tcfg, scfg)
    N_PARAMS = sum(x.size for x in jax.tree.leaves(params))

    losses = []
    for batch in dataset.seq_batches(ds, 32, 16, steps=STEPS, seed=7):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, resid, loss = step(params, opt, resid, batch)
        losses.append(float(loss))
    hr, ndcg = eval_hr(params)
    # wire bytes per step per rank (analytic, from the sync contract)
    if mode in ("flat", "hierarchical"):
        wire = N_PARAMS * 4 * (2 if mode == "flat" else 1)
    elif mode == "onebit":
        wire = n // 8 + (n // 512) * 4
    else:
        wire = (n // 2048) * 64 * 8
    out[mode] = {"final_loss": float(np.mean(losses[-5:])),
                 "first_loss": losses[0], "hr10": hr, "ndcg10": ndcg,
                 "wire_bytes": wire}

print("BENCH_JSON:" + json.dumps(out))
