"""Subprocess payload for the ``serve`` artifact's recsys section: the CF
scoring head inside the engine on an N-device host mesh, cached vs
uncached hot-row replica, per sharding plan.

Run:  python -m benchmarks._recsys_payload --mesh 2,4 --candidates 16
Prints one line ``BENCH_JSON:{...}``.

Each request is a full retrieval->rank call: LM prefill + sharded
cf_user/cf_item factor lookups + gated fusion + candidate ranking.  Per
plan the same workload runs twice — hot-row cache off, then on — and the
payload records the measured hit rate, the ids that actually took the
cross-shard exchange, the ring-modeled lookup bytes at the measured hit
rate, and the exactness flags the CI gate checks (fused scores, rankings
and token streams must be bit-identical with the cache on or off).
"""
import argparse
import json
import os

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", default="2,4", help="data,model extents")
ap.add_argument("--requests", type=int, default=20)
ap.add_argument("--candidates", type=int, default=16)
ap.add_argument("--cache-rows", type=int, default=128)
ap.add_argument("--n-users", type=int, default=10_000)
ap.add_argument("--cf-dim", type=int, default=16)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

_DP, _MP = (int(x) for x in args.mesh.split(","))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_DP * _MP}")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.config import get_arch, reduced  # noqa: E402
from repro.embeddings import EmbedSpec, make_plan  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.serving import (CFHead, EngineConfig, ServingEngine,  # noqa: E402
                           TrafficConfig, cf_lookup_bytes, generate)
from repro.serving.engine import make_backend  # noqa: E402

cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
params = tf.init_params(jax.random.PRNGKey(0), cfg)
mesh = compat.make_mesh((_DP, _MP), ("data", "model"))
mesh_shape = dict(mesh.shape)

reqs = generate(TrafficConfig(
    n_requests=args.requests, rate=500.0, prompt_max=12, new_tokens_max=8,
    vocab_size=cfg.vocab_size, n_users=args.n_users, seed=args.seed,
    candidates=args.candidates))
# item table rows must divide by the row-axis extent; round the vocab up
n_items = -(-cfg.vocab_size // (8 * _MP)) * (8 * _MP)
backend = make_backend(cfg, params)
ecfg = EngineConfig(n_slots=4, max_len=64)


def head_for(plan, cache_rows):
    return CFHead.build(n_users=args.n_users, n_items=n_items,
                        cf_dim=args.cf_dim, seed=args.seed, plan=plan,
                        cache_rows=cache_rows, mesh=mesh)


def run(plan, cache_rows):
    # warm (compiles the LM buckets + this plan's shard_map lookups),
    # then a fresh head for clean hit/exchange counters
    ServingEngine(backend, ecfg,
                  cf_head=head_for(plan, cache_rows)).run(reqs)
    head = head_for(plan, cache_rows)
    engine = ServingEngine(backend, ecfg, cf_head=head)
    outputs, _, summary = engine.run(reqs)
    scores = {rid: (r["cf"].tolist(), r["fused"].tolist(),
                    r["ranking"].tolist())
              for rid, r in engine.cf_results.items()}
    exchanged = sum(lk.exchanged_ids for lk in head.lookups.values())
    return outputs, scores, summary, head, exchanged


item_spec = EmbedSpec("cf_item", rows=n_items, dim=args.cf_dim)
out = {"mesh": mesh_shape, "devices": mesh.size,
       "requests": args.requests, "candidates": args.candidates,
       "cache_rows": args.cache_rows, "n_users": args.n_users,
       "n_items": n_items, "plans": {}}
for plan in ("replicated", "row", "col", "row_col"):
    uo, us, usum, _, u_ex = run(plan, 0)
    co, cs, csum, chead, c_ex = run(plan, args.cache_rows)
    hr = chead.hit_rate
    # modeled wire bytes of one request's lookups (user + candidates)
    # at the measured hit rate, on the training-side ring cost model
    modeled = cf_lookup_bytes(item_spec, make_plan(plan), mesh_shape,
                              batch=args.candidates + 1, hit_rate=hr)
    out["plans"][plan] = {
        "hit_rate": hr,
        "cache_rows_live": chead.cache_rows_live,
        "requests_scored": csum["cf"]["requests_scored"],
        "tok_s_cached": csum["throughput_tok_s"],
        "tok_s_uncached": usum["throughput_tok_s"],
        "exchanged_ids_cached": c_ex,
        "exchanged_ids_uncached": u_ex,
        "modeled": modeled,
        "scores_exact": bool(cs == us),
        "tokens_exact": bool(co == uo),
    }
print("BENCH_JSON:" + json.dumps(out))
