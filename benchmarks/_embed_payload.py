"""Subprocess payload for the ``embed`` artifact: one embedding sharding
plan on N host devices — measured host step time, exchanged bytes from the
compiled HLO, per-device table memory, and roofline-modeled TPU terms.

Run:  python -m benchmarks._embed_payload --plan row --mesh 2,4 ...
Prints one line ``BENCH_JSON:{...}``.

The train step is one embedding-lookup step distilled from the recsys
model: Zipfian ids -> sharded lookup -> MSE against a target -> table-
gradient sync -> SGD row update, all inside shard_map so every exchange is
an explicit collective the cost analyzer can count.  ``--grad-sync
sparse`` swaps the dense DP all-reduce for the rows-touched all-gather.
"""
import argparse
import json
import os

ap = argparse.ArgumentParser()
ap.add_argument("--plan", required=True,
                choices=("replicated", "row", "col", "row_col"))
ap.add_argument("--mesh", default="2,4", help="data,model extents")
ap.add_argument("--grad-sync", default="dense", choices=("dense", "sparse"))
ap.add_argument("--rows", type=int, default=16384)
ap.add_argument("--dim", type=int, default=64)
ap.add_argument("--batch", type=int, default=1024, help="global ids/step")
ap.add_argument("--steps", type=int, default=5)
ap.add_argument("--zipf", type=float, default=1.3)
args = ap.parse_args()

_DP, _MP = (int(x) for x in args.mesh.split(","))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_DP * _MP}")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis import hlo_cost  # noqa: E402
from repro.config import (DCI_BW_PER_LINK, HBM_BW, ICI_BW_PER_LINK,  # noqa: E402
                          PEAK_FLOPS_BF16)
from repro.embeddings import (EmbedSpec, make_plan, named_sharding,  # noqa: E402
                              plan_summary, pspec, shard_bytes,
                              sharded_lookup_body, sparse_row_sync)

mesh = compat.make_mesh((_DP, _MP), ("data", "model"))
spec = EmbedSpec("bench", rows=args.rows, dim=args.dim)
plan = make_plan(args.plan)
mesh_shape = dict(mesh.shape)

rng = np.random.default_rng(0)
# Zipfian ids (recsys popularity skew) — what makes dedup worthwhile
ids_np = np.minimum(rng.zipf(args.zipf, size=(args.steps + 2, args.batch))
                    - 1, args.rows - 1).astype(np.int32)
tgt_np = rng.normal(size=(args.batch, args.dim)).astype(np.float32)
table0 = (rng.normal(size=(args.rows, args.dim)) * 0.02).astype(np.float32)

LR = 0.1


def body(tshard, ids_loc, tgt_loc):
    def loss_fn(ts):
        out = sharded_lookup_body(ts, ids_loc, plan)
        return 0.5 * jnp.mean((out - tgt_loc) ** 2)

    loss, g = jax.value_and_grad(loss_fn)(tshard)
    loss = jax.lax.pmean(loss, ("data", "model"))
    if plan.col_axis is None:
        # shard replicated across DP ranks -> gradients need the DP sync
        if args.grad_sync == "sparse":
            vr = tshard.shape[0]
            if plan.row_axis:
                lo = jax.lax.axis_index(plan.row_axis) * vr
                local = ids_loc - lo
                local = jnp.where((local >= 0) & (local < vr), local, vr)
            else:
                local = ids_loc
            g = sparse_row_sync(g, local, ("data",))
        else:
            g = jax.lax.pmean(g, "data")
    # col plans: each DP rank owns distinct columns — no table sync at all
    return tshard - LR * g, loss


tspec = pspec(plan)
step = jax.jit(
    shard_map(body, mesh=mesh,
              in_specs=(tspec, P("data"), P("data")),
              out_specs=(tspec, P()),
              check_rep=False),
    donate_argnums=(0,))

table = jax.device_put(jnp.asarray(table0), named_sharding(mesh, plan))
tgt = jax.device_put(jnp.asarray(tgt_np), NamedSharding(mesh, P("data")))
put_ids = lambda a: jax.device_put(  # noqa: E731
    jnp.asarray(a), NamedSharding(mesh, P("data")))

# AOT-compile once: the optimized HLO text is what the analyzer costs
# (the tables are f32 throughout, so the post-optimization byte sizes the
# analyzer sees match the lowering-time ones)
compiled = step.lower(table, put_ids(ids_np[0]), tgt).compile()
hlo_text = compiled.as_text()

table, loss = step(table, put_ids(ids_np[0]), tgt)       # compile + warm
jax.block_until_ready(loss)
t0 = time.perf_counter()
losses = []
for s in range(1, args.steps + 1):
    table, loss = step(table, put_ids(ids_np[s]), tgt)
    losses.append(float(loss))
dt = (time.perf_counter() - t0) / args.steps

costs = hlo_cost.analyze(hlo_text, mesh.size)
t_compute = costs.flops / PEAK_FLOPS_BF16
t_memory = costs.bytes / HBM_BW
t_coll = (costs.coll_intra / ICI_BW_PER_LINK
          + costs.coll_cross / DCI_BW_PER_LINK)

# per-device table memory at this mesh, and the ~1/N scaling curve
tb = shard_bytes(spec, plan, mesh_shape)
scaling = {}
for n in (1, 2, 4, 8):
    ms = {"data": max(n // _MP, 1) if _MP > 1 else n,
          "model": min(n, _MP)}
    try:
        scaling[n] = shard_bytes(spec, plan, ms)
    except ValueError:
        pass

out = {
    "plan": args.plan, "grad_sync": args.grad_sync,
    "mesh": mesh_shape, "devices": mesh.size,
    "rows": args.rows, "dim": args.dim, "batch": args.batch,
    "host_step_ms": dt * 1e3,
    "losses": losses[:5],
    "coll_bytes_per_dev": costs.coll_total,
    "coll_by_op": {k: v for k, v in costs.coll_bytes.items() if v},
    "bytes_per_dev": costs.bytes,
    "flops_per_dev": costs.flops,
    "table_bytes_per_dev": tb,
    "table_bytes_scaling": scaling,
    "t_compute_ms": t_compute * 1e3,
    "t_memory_ms": t_memory * 1e3,
    "t_collective_ms": t_coll * 1e3,
    "modeled": plan_summary(spec, plan, mesh_shape,
                            args.batch // mesh_shape["data"]),
}
print("BENCH_JSON:" + json.dumps(out))
