"""Subprocess payload for the ``train-parallel`` artifact: run ONE
parallelism scheme of the unified training path end-to-end on N host
devices and report measured host step time + losses.

Schemes (8 devices): ``dp`` = shard_map DP-8 (flat sync), ``tp`` = GSPMD
TP-8, ``pp`` = pipeline-only 1x1x8, ``hybrid`` = DP2 x TP2 x PP2 through
``make_pp_train_step``.  Prints one line ``BENCH_JSON:{...}``.
"""
import argparse
import json
import os

ap = argparse.ArgumentParser()
ap.add_argument("--scheme", required=True,
                choices=("dp", "tp", "pp", "hybrid"))
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--steps", type=int, default=4)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=32)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--pp-micro", type=int, default=4)
ap.add_argument("--schedule", default="1f1b", choices=("1f1b", "gpipe"))
args = ap.parse_args()

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.devices}")

import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import (ParallelConfig, ShapeConfig, TrainConfig,  # noqa: E402
                          get_arch, reduced)
from repro.core.hybrid import auto_plan  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import layers as L, transformer as tf  # noqa: E402
from repro.optimizer import adamw  # noqa: E402
from repro.runtime import trainer  # noqa: E402

cfg = dataclasses.replace(reduced(get_arch("olmo-1b")),
                          num_layers=args.layers, dtype="float32")
ctx = tf.ModelCtx(attn_chunk=8)
tcfg = TrainConfig(steps=args.steps, checkpoint_every=0)
params = tf.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
batches = [{"tokens": jnp.asarray(rng.integers(3, cfg.vocab_size,
                                               (args.batch, args.seq)),
                                  jnp.int32),
            "targets": jnp.asarray(rng.integers(3, cfg.vocab_size,
                                                (args.batch, args.seq)),
                                   jnp.int32),
            "mask": jnp.ones((args.batch, args.seq), jnp.float32)}
           for _ in range(args.steps + 1)]


def ref_loss(p, b):
    logits, _, _ = tf.forward(cfg, p, b, ctx)
    nll = L._nll(logits, b["targets"])
    return jnp.sum(nll * b["mask"]) / jnp.sum(b["mask"])


losses = []
if args.scheme == "dp":
    mesh = make_host_mesh(data=args.devices)
    scfg = trainer.DPSyncConfig(mode="flat")
    opt = adamw.init_opt_state(params)
    resid = jnp.zeros((args.devices, trainer.residual_size(params, scfg)))
    step = trainer.make_dp_train_step(ref_loss, mesh, tcfg, scfg)

    def run(p, o, r, b):
        p, o, r, loss = step(p, o, r, b)
        return p, o, r, loss

    state = (params, opt, resid)
elif args.scheme == "tp":
    mesh = make_host_mesh(data=1, model=args.devices)
    shape = ShapeConfig("bench", args.seq, args.batch, "train")
    plan = auto_plan(cfg, mesh, shape, ParallelConfig())
    step, jitted, _ = trainer.make_hybrid_train_step(cfg, plan, tcfg)
    opt = adamw.init_opt_state(params)
    fn = jitted(jax.eval_shape(lambda: params), batches[0])

    def run(p, o, r, b):
        p, o, m = fn(p, o, b)
        return p, o, r, m["loss"]

    state = (params, opt, None)
else:
    if args.scheme == "pp":
        dp, tp, pp = 1, 1, args.devices
    else:
        dp, tp, pp = 2, 2, 2
    mesh = make_host_mesh(data=dp, model=tp, stage=pp)
    shape = ShapeConfig("bench", args.seq, args.batch, "train")
    plan = auto_plan(cfg, mesh, shape,
                     ParallelConfig(dp=dp, tp=tp, pp=pp,
                                    microbatches=args.pp_micro,
                                    pp_schedule=args.schedule))
    bounds = list(plan.stage_bounds)
    scfg = trainer.DPSyncConfig(mode="flat")
    pp_params = tf.pp_partition_params(cfg, params, bounds)
    pp_shape = jax.eval_shape(lambda: pp_params)
    opt = adamw.init_opt_state(
        trainer.pp_trainable(pp_params, cfg.tie_embeddings))
    resid = jnp.zeros((dp, tp, pp,
                       trainer.pp_residual_size(cfg, pp_shape, mesh, scfg)))
    step = trainer.make_pp_train_step(cfg, mesh, tcfg, bounds, pp_shape,
                                      n_micro=args.pp_micro,
                                      pp_schedule=args.schedule, scfg=scfg,
                                      ctx=ctx)

    def run(p, o, r, b):
        return step(p, o, r, b)

    state = (pp_params, opt, resid)

p, o, r = state
p, o, r, loss = run(p, o, r, batches[0])            # compile + warm
jax.block_until_ready(loss)
t0 = time.perf_counter()
for b in batches[1:]:
    p, o, r, loss = run(p, o, r, b)
    losses.append(float(loss))
dt = (time.perf_counter() - t0) / args.steps

print("BENCH_JSON:" + json.dumps({
    "scheme": args.scheme, "devices": args.devices,
    "schedule": args.schedule if args.scheme in ("pp", "hybrid") else None,
    "host_step_ms": dt * 1e3,
    "losses": losses[:6],
}))
