"""Benchmark harness — one function per paper artifact.

Prints ``name,us_per_call,derived`` CSV rows (derived = value computed from
compiled-HLO roofline terms rather than wall clock; this container is
CPU-only so TPU-scale numbers are modeled, host wall-times are measured).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table2     # one artifact
"""
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(ROOT, "experiments", "bench")


def _run_payload(_module="benchmarks._dist_payload", **kw):
    cmd = [sys.executable, "-m", _module]
    for k, v in kw.items():
        cmd += [f"--{k.replace('_', '-')}", str(v)]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + ":" + ROOT
    p = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env=env, cwd=ROOT)
    for line in p.stdout.splitlines():
        if line.startswith("BENCH_JSON:"):
            return json.loads(line[len("BENCH_JSON:"):])
    raise RuntimeError(f"payload failed rc={p.returncode}:\n"
                       f"{p.stdout[-1500:]}\n{p.stderr[-2000:]}")


def _emit(rows, name, us, derived):
    rows.append(f"{name},{us:.1f},{derived}")
    print(rows[-1], flush=True)


def _save(tag, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{tag}.json"), "w") as f:
        json.dump(obj, f, indent=1)


# ---------------------------------------------------------------------------
# Table 2: scheme comparison (baseline / DP / MP / hybrid)
# ---------------------------------------------------------------------------

def table2(rows):
    out = {}
    for scheme in ("baseline", "dp", "mp", "hybrid"):
        r = _run_payload(scheme=scheme, devices=8, steps=6)
        out[scheme] = r
        _emit(rows, f"table2.{scheme}.host_step", r["host_step_ms"] * 1e3,
              "measured")
        _emit(rows, f"table2.{scheme}.modeled_tput",
              r["modeled_throughput"], "derived")
        _emit(rows, f"table2.{scheme}.comm_frac",
              r["comm_fraction"] * 100, "derived")
    base = out["baseline"]["modeled_throughput"]
    for scheme in ("dp", "mp", "hybrid"):
        _emit(rows, f"table2.{scheme}.speedup",
              out[scheme]["modeled_throughput"] / base, "derived")
    _save("table2", out)


# ---------------------------------------------------------------------------
# Table 3: scalability 1..4 "nodes" (8 chips per node)
# ---------------------------------------------------------------------------

def table3(rows):
    out = {}
    for nodes in (1, 2, 3, 4):
        n = 8 * nodes
        for scheme in ("dp", "hybrid"):
            r = _run_payload(scheme=scheme, devices=n, steps=4,
                             batch=max(32, n * 4))
            out[f"{scheme}_{nodes}"] = r
            _emit(rows, f"table3.{scheme}.n{nodes}.modeled_tput",
                  r["modeled_throughput"], "derived")
    _save("table3", out)


# ---------------------------------------------------------------------------
# Fig 4: compute/communication time split per scheme
# ---------------------------------------------------------------------------

def fig4(rows):
    out = {}
    for scheme in ("dp", "mp", "hybrid"):
        r = _run_payload(scheme=scheme, devices=8, steps=4)
        out[scheme] = {"compute_ms": r["t_compute_ms"],
                       "memory_ms": r["t_memory_ms"],
                       "comm_ms": r["t_collective_ms"],
                       "comm_fraction": r["comm_fraction"]}
        _emit(rows, f"fig4.{scheme}.comm_pct", r["comm_fraction"] * 100,
              "derived")
    _save("fig4", out)


# ---------------------------------------------------------------------------
# Fig 5: resource utilization (memory traffic per device per scheme)
# ---------------------------------------------------------------------------

def fig5(rows):
    out = {}
    for scheme in ("dp", "mp", "hybrid"):
        r = _run_payload(scheme=scheme, devices=8, steps=2)
        out[scheme] = {"bytes_per_dev": r["bytes_per_dev"],
                       "coll_bytes_per_dev": r["coll_bytes_per_dev"]}
        _emit(rows, f"fig5.{scheme}.hbm_traffic_gb",
              r["bytes_per_dev"] / 1e9, "derived")
    _save("fig5", out)


# ---------------------------------------------------------------------------
# Training parallelism: DP-only vs TP-only vs PP-only vs hybrid DP x TP x PP
# through the unified pipelined train step — measured host step times on the
# simulated 8-device mesh, plus the modeled production point (internlm2-20b
# on 32 chips) and the GPipe-vs-1F1B bubble column
# ---------------------------------------------------------------------------

def train_parallel(rows):
    from repro.config import SHAPES, get_arch
    from repro.core.hybrid import modeled_parallel_step
    from repro.core.pipeline import schedule_cost

    out = {"measured": {}, "modeled": {}, "bubble": {}}
    for scheme in ("dp", "tp", "pp", "hybrid"):
        r = _run_payload(_module="benchmarks._train_payload", scheme=scheme,
                         steps=4)
        out["measured"][scheme] = r
        _emit(rows, f"train_parallel.{scheme}.host_step",
              r["host_step_ms"] * 1e3, "measured")

    # modeled TPU-scale rows: a dense 20B at train_4k on 32 chips — the
    # configuration where the paper's Table-2 ordering (hybrid > any
    # single mode; pure DP cannot even hold its optimizer state) emerges
    cfg = get_arch("internlm2-20b")
    shape = SHAPES["train_4k"]
    cases = {"dp_only": dict(dp=32), "tp_only": dict(tp=32),
             "pp_only": dict(pp=32), "hybrid": dict(dp=2, tp=4, pp=4)}
    for name, kw in cases.items():
        m = modeled_parallel_step(cfg, shape, n_micro=8, schedule="1f1b",
                                  **kw)
        out["modeled"][name] = m
        _emit(rows, f"train_parallel.{name}.modeled_tput",
              m["modeled_throughput"], "derived")
        _emit(rows, f"train_parallel.{name}.state_gb_per_dev",
              m["state_gb_per_dev"], "derived")
        _emit(rows, f"train_parallel.{name}.bubble_pct",
              m["bubble_frac"] * 100, "derived")
    for sched in ("gpipe", "1f1b"):
        c = schedule_cost(sched, 4, 8)
        out["bubble"][sched] = c
        _emit(rows, f"train_parallel.bubble.{sched}",
              c["bubble_frac"] * 100, "derived")
        _emit(rows, f"train_parallel.stash.{sched}", c["stash_micros"],
              "derived")

    # -- observability: synthesize the 1F1B tick timeline (one track per
    # stage) from the measured pp host step, Perfetto-openable.  The
    # timeline's makespan-derived bubble is reported next to the
    # schedule_cost model's — the timeline prices every tick at the max
    # active-unit cost (lock-step stages), so its bubble is an upper
    # bound on the per-unit cost model's
    from repro.obs import Tracer, synthesize_pipeline_ticks, \
        write_chrome_trace
    n_stages, n_micro = 4, 8
    step_s = out["measured"]["pp"]["host_step_ms"] / 1e3
    stage_times = [step_s / (3 * n_micro + 2 * (n_stages - 1))] * n_stages
    tr = Tracer()
    end = synthesize_pipeline_ticks(tr, "1f1b", n_stages, n_micro,
                                    stage_times, bwd_cost_ratio=2.0)
    useful = n_micro * stage_times[0] * 3.0          # fwd + 2x bwd
    timeline_path = os.path.join(RESULTS_DIR, "train_timeline.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    n_ev = write_chrome_trace(timeline_path, tr)
    out["obs"] = {
        "timeline_file": os.path.relpath(timeline_path, ROOT),
        "timeline_events": n_ev,
        "makespan_s": end,
        "bubble_frac_timeline": 1.0 - useful / end,
        "bubble_frac_model": out["bubble"]["1f1b"]["bubble_frac"],
    }
    _emit(rows, "train_parallel.obs.timeline_events", n_ev, "derived")
    _emit(rows, "train_parallel.obs.bubble_pct_timeline",
          out["obs"]["bubble_frac_timeline"] * 100, "derived")
    _save("train_parallel", out)


# ---------------------------------------------------------------------------
# Compression ablation: none / 1-bit / top-k on real DP training (+HR@10)
# ---------------------------------------------------------------------------

def compression(rows):
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + ":" + ROOT
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks._compress_payload"],
        capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT)
    out = None
    for line in p.stdout.splitlines():
        if line.startswith("BENCH_JSON:"):
            out = json.loads(line[len("BENCH_JSON:"):])
            break
    if out is None:
        raise RuntimeError(p.stdout[-1500:] + p.stderr[-1500:])
    for mode, r in out.items():
        _emit(rows, f"compress.{mode}.final_loss", r["final_loss"] * 1e6,
              "measured")
        _emit(rows, f"compress.{mode}.hr10_x1e4", r["hr10"] * 1e4,
              "measured")
        _emit(rows, f"compress.{mode}.wire_bytes_per_step",
              r["wire_bytes"], "derived")
    _save("compression", out)


# ---------------------------------------------------------------------------
# Async staleness (Eq. 12)
# ---------------------------------------------------------------------------

def async_staleness(rows):
    import jax.numpy as jnp
    import numpy as np
    from repro.core import async_dp
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    A = A @ A.T / 16 + jnp.eye(16)

    def loss(p, b):
        return 0.5 * p @ A @ p + b @ p

    stream = [jnp.asarray(rng.normal(size=16) * 0.01, jnp.float32)
              for _ in range(80)]
    p0 = jnp.ones(16)
    out = {}
    for tau in (0, 2, 6):
        for comp in (True, False):
            cfg = async_dp.AsyncConfig(max_staleness=tau, compensate=comp,
                                       lr=0.1, staleness="random")
            _, losses = async_dp.simulate_async_sgd(loss, p0, stream, cfg)
            key = f"tau{tau}_{'comp' if comp else 'naive'}"
            out[key] = losses[-1]
            _emit(rows, f"async.{key}.final_loss_x1e6", losses[-1] * 1e6,
                  "measured")
    _save("async", out)


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (interpret-mode on CPU: correctness-path timing)
# ---------------------------------------------------------------------------

def kernels(rows):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    def timeit(fn, *a, n=5):
        fn(*a)                                   # compile+warm
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*a))
        return (time.perf_counter() - t0) / n * 1e6

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    _emit(rows, "kernel.flash_attention.interp",
          timeit(lambda a, b, c: ops.flash_attention_bhsd(
              a, b, c, block_q=64, block_k=64), q, k, v), "measured")
    _emit(rows, "kernel.flash_attention.ref",
          timeit(lambda a, b, c: ops.flash_attention_bhsd(
              a, b, c, impl="ref"), q, k, v), "measured")

    g = jax.random.normal(ks[0], (8 * 4096,))
    _emit(rows, "kernel.onebit_quantize.interp",
          timeit(lambda x: ops.onebit_quantize(x, 512), g), "measured")
    _emit(rows, "kernel.topk_sparsify.interp",
          timeit(lambda x: ops.topk_sparsify(x, 32, 2048), g), "measured")
    logits = jax.random.normal(ks[1], (2048, 64))
    _emit(rows, "kernel.moe_router.interp",
          timeit(lambda x: ops.moe_router(x, 6), logits), "measured")
    p, m, vv = (jax.random.normal(kk, (8 * 4096,)) for kk in ks)
    _emit(rows, "kernel.fused_adamw.interp",
          timeit(lambda a, b, c, d: ops.adamw_update(
              a, b, c, jnp.abs(d), 1e-3, 0.9, 0.95), p, g, m, vv),
          "measured")


# ---------------------------------------------------------------------------
# Embedding sharding plans: replicated-dense vs row / col / 2D, plus the
# sparse rows-touched gradient sync — exchanged bytes, per-device table
# memory, host step time, roofline-modeled TPU collective term
# ---------------------------------------------------------------------------

def embed(rows):
    cases = (
        # key               plan        mesh(d,m)  grad-sync
        ("replicated",       "replicated", "8,1", "dense"),
        ("replicated_sparse", "replicated", "8,1", "sparse"),
        ("row",              "row",        "2,4", "dense"),
        ("row_sparse",       "row",        "2,4", "sparse"),
        ("col",              "col",        "8,1", "dense"),
        ("row_col",          "row_col",    "2,4", "dense"),
    )
    out = {}
    for key, plan, mesh, sync in cases:
        r = _run_payload(_module="benchmarks._embed_payload", plan=plan,
                         mesh=mesh, grad_sync=sync, steps=4)
        out[key] = r
        _emit(rows, f"embed.{key}.host_step", r["host_step_ms"] * 1e3,
              "measured")
        _emit(rows, f"embed.{key}.coll_mb_per_step",
              r["coll_bytes_per_dev"] / 1e6, "derived")
        _emit(rows, f"embed.{key}.table_mb_per_dev",
              r["table_bytes_per_dev"] / 1e6, "derived")
        _emit(rows, f"embed.{key}.t_collective_us",
              r["t_collective_ms"] * 1e3, "derived")
    base = out["replicated"]["coll_bytes_per_dev"]
    for key in ("replicated_sparse", "row", "row_sparse", "col", "row_col"):
        _emit(rows, f"embed.{key}.bytes_vs_replicated",
              out[key]["coll_bytes_per_dev"] / base, "derived")
    _save("embed", out)


# ---------------------------------------------------------------------------
# Serving: static vs continuous batching vs int8-KV continuous, equal slots;
# then every architecture family through the same engine, with the modeled
# TPU-scale decode roofline terms for the full archs
# ---------------------------------------------------------------------------

SERVE_FAMILIES = (("uniform", "olmo-1b"), ("gemma", "gemma3-1b"),
                  ("jamba", "jamba-v0.1-52b"), ("rwkv6", "rwkv6-1.6b"),
                  ("whisper", "whisper-medium"))


def serve(rows):
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.cache_layout import CacheLayout
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    from repro.serving import EngineConfig, ServingEngine, TrafficConfig, \
        generate
    from repro.serving.engine import make_backend
    from repro.serving.roofline import decode_attn_read_bytes, \
        max_concurrent_slots, modeled_decode_step

    def decode_parity(fcfg, fparams, max_len=32):
        """dense vs flash decode_step logits on ragged prefilled slots
        (interpret-mode kernel on CPU) — the per-family parity record the
        CI gate checks actually ran."""
        rng = np.random.default_rng(3)
        frames = (jnp.asarray(rng.normal(size=(1, fcfg.encoder_frames,
                                               fcfg.d_model)), jnp.float32)
                  if fcfg.encoder_layers else None)
        prompts = [jnp.asarray(rng.integers(3, fcfg.vocab_size, (1, 24)),
                               jnp.int32) for _ in range(2)]
        caches = {}
        for impl in ("dense", "flash"):
            ctx = tf.ModelCtx(attn_chunk=8, decode_impl=impl,
                              decode_block_k=8)
            cache = tf.init_slots(fcfg, 2, max_len)
            for slot, ln in enumerate((5, 17)):
                _, cache = tf.prefill_into_slot(
                    fcfg, fparams, cache, prompts[slot], ln, slot, ctx,
                    frames=frames)
            logits, cache = tf.decode_step(
                fcfg, fparams, cache,
                jnp.asarray([[7], [9]], jnp.int32), ctx)
            caches[impl] = np.asarray(logits, np.float32)
        diff = float(np.max(np.abs(caches["flash"] - caches["dense"])))
        scale = float(np.max(np.abs(caches["dense"]))) + 1e-9
        return {"ran": True, "max_abs_diff": diff,
                "ok": bool(diff <= 1e-3 * max(scale, 1.0))}

    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    requests = generate(TrafficConfig(
        n_requests=32, rate=500.0, prompt_max=24, new_tokens_max=16,
        vocab_size=cfg.vocab_size))
    ecfg = EngineConfig(n_slots=4, max_len=64)

    out = {}
    for name, bits, refill in (("static", 16, "static"),
                               ("continuous", 16, "continuous"),
                               ("continuous_int8", 8, "continuous")):
        backend = make_backend(cfg, params,
                               layout=CacheLayout(kv_bits=bits))
        vcfg = dataclasses.replace(ecfg, refill=refill)
        ServingEngine(backend, vcfg).run(requests)       # compile/warm
        _, _, s = ServingEngine(backend, vcfg).run(requests)
        out[name] = s
        _emit(rows, f"serve.{name}.tok_s", s["throughput_tok_s"], "measured")
        _emit(rows, f"serve.{name}.ttft_p95_ms", s["ttft_s"]["p95"] * 1e3,
              "measured")
        _emit(rows, f"serve.{name}.decode_steps", s["decode_steps"],
              "measured")
        _emit(rows, f"serve.{name}.max_concurrent_slots",
              s["max_concurrent_slots"], "measured")
        _emit(rows, f"serve.{name}.kv_mb_per_step",
              s["kv_bytes_per_step"] / 1e6, "derived")
    _emit(rows, "serve.continuous_vs_static.speedup",
          out["continuous"]["throughput_tok_s"]
          / out["static"]["throughput_tok_s"], "measured")

    # -- decode hot path: dense einsum vs Pallas flash-decode (interpret
    # mode on this CPU container) vs int8-fused, same engine + workload
    out["decode_impls"] = {}
    for name, bits, impl in (("dense", 16, "dense"),
                             ("flash", 16, "flash"),
                             ("int8_fused", 8, "flash")):
        backend = make_backend(cfg, params,
                               layout=CacheLayout(kv_bits=bits, impl=impl))
        ServingEngine(backend, ecfg).run(requests)        # compile/warm
        _, _, s = ServingEngine(backend, ecfg).run(requests)
        out["decode_impls"][name] = s
        _emit(rows, f"serve.decode.{name}.tok_s", s["throughput_tok_s"],
              "measured")
        _emit(rows, f"serve.decode.{name}.decode_steps", s["decode_steps"],
              "measured")

    # -- cache layouts: dense vs paged (shared block pool, prefix sharing,
    # copy-on-write), same workload and slots.  Paged must stay token-exact;
    # its resident KV bytes track live blocks instead of slots*max_len
    out["layouts"] = {}
    layout_outputs = {}
    paged_setup = None
    for name, lay in (("dense", CacheLayout()),
                      ("paged", CacheLayout(kind="paged", block_size=8)),
                      ("paged_int8", CacheLayout(kind="paged", kv_bits=8,
                                                 block_size=8))):
        backend = make_backend(cfg, params, layout=lay)
        vcfg = dataclasses.replace(ecfg, layout=lay)
        ServingEngine(backend, vcfg).run(requests)        # compile/warm
        o, _, s = ServingEngine(backend, vcfg).run(requests)
        layout_outputs[name] = o
        out["layouts"][name] = s
        if name == "paged":
            paged_setup = (backend, vcfg)
        _emit(rows, f"serve.layout.{name}.tok_s", s["throughput_tok_s"],
              "measured")
        _emit(rows, f"serve.layout.{name}.max_concurrent_slots",
              s["max_concurrent_slots"], "measured")
        _emit(rows, f"serve.layout.{name}.kv_mb_per_step",
              s["kv_bytes_per_step"] / 1e6, "derived")
    out["layouts"]["paged_token_exact"] = bool(
        layout_outputs["paged"] == layout_outputs["dense"])
    _emit(rows, "serve.layout.paged_token_exact",
          int(out["layouts"]["paged_token_exact"]), "measured")

    # -- observability: the paged run again with tracing + metrics on.
    # Throughput runs on the simulated clock, so tracing must not perturb
    # the measured number (the CI gate holds the ratio within 5%); the
    # per-request spans must reconcile with the records' TTFT/TPOT
    from repro.obs import MetricsRegistry, Tracer, write_trace
    tbackend, tvcfg = paged_setup
    untraced = out["layouts"]["paged"]["throughput_tok_s"]
    tracer, registry = Tracer(), MetricsRegistry()
    _, trecs, ts = ServingEngine(tbackend, tvcfg, tracer=tracer,
                                 metrics=registry).run(requests)
    spans = {}                    # rid -> {span name: dur}
    for e in tracer.events:
        if e["ph"] == "X" and e["name"].startswith("req."):
            spans.setdefault(e["args"]["rid"], {})[e["name"]] = e
    reconciled = True
    for r in trecs:
        if r.finished is None:
            continue
        sp = spans.get(r.rid, {})
        ttft_tr = (sp["req.queue_wait"]["dur"] + sp["req.prefill"]["dur"])
        ok = abs(ttft_tr - r.ttft) < 1e-9
        if r.tpot is not None:
            ok = ok and abs(sp["req.decode"]["dur"] / (r.tokens_out - 1)
                            - r.tpot) < 1e-9
        reconciled = reconciled and ok
    os.makedirs(RESULTS_DIR, exist_ok=True)
    trace_path = os.path.join(RESULTS_DIR, "serve_trace.json")
    n_events = write_trace(trace_path, tracer, registry)
    out["obs"] = {
        "trace_file": os.path.relpath(trace_path, ROOT),
        "trace_events": n_events,
        "span_counts": ts["obs"]["span_counts"],
        "metrics": ts["obs"]["metrics"],
        "ttft_reconciled": bool(reconciled),
        "untraced_tok_s": untraced,
        "traced_tok_s": ts["throughput_tok_s"],
        "traced_over_untraced": ts["throughput_tok_s"] / untraced,
    }
    _emit(rows, "serve.obs.ttft_reconciled", int(reconciled), "measured")
    _emit(rows, "serve.obs.traced_over_untraced",
          out["obs"]["traced_over_untraced"], "measured")
    _emit(rows, "serve.obs.trace_events", n_events, "measured")

    # -- speculative multi-token decode: self-drafted n-gram verification
    # under steep-Zipf (recsys hot-item) prompts and decode-heavy
    # generations, where the model's own repetitive continuations give the
    # drafter real matches.  Every variant must stay token-identical to
    # single-step greedy; accepted tokens/step > 1 is the win — one
    # KV-cache stream serves several emitted tokens.  Alongside the
    # measured wall ratio (CPU interpret-mode: the verify rows are
    # *compute*-priced, which inverts speculation's economics for the
    # cheapest baselines) each entry derives a roofline-modeled TPU ratio,
    # where the decode step is memory-bound and k verify rows share one
    # params+state stream — the deployment arithmetic the feature buys.
    def zipf_prompts(reqs, vocab, s=3.0, seed=11):
        srng = np.random.default_rng(seed)
        return [dataclasses.replace(
            r, prompt=tuple(int(t) for t in np.minimum(
                srng.zipf(s, len(r.prompt)) + 2, vocab - 1)))
            for r in reqs]

    def spec_entry(key, backend, base_cfg, reqs, full_cfg, kv_bits=16):
        from repro.serving.roofline import modeled_decode_step
        # construct the k=max engine FIRST: it stamps backend.spec_k, so
        # init_slots lays out margined rings once and every engine on this
        # backend (spec and single-step) shares bit-identical cache shapes
        engines = {}
        for k in (4, 2, 1):
            vcfg = dataclasses.replace(base_cfg, spec_k=k)
            # two warm passes: the first pays jit compiles, which skew the
            # scheduler's wall-clock arrival interleaving enough that a
            # verify-bucket shape can first appear on the second run
            ServingEngine(backend, vcfg).run(reqs)
            ServingEngine(backend, vcfg).run(reqs)
            engines[k] = ServingEngine(backend, vcfg).run(reqs)
        bo, _, bs_ = engines[1]
        entry = {"single_step": {"tok_s": bs_["throughput_tok_s"],
                                 "decode_steps": bs_["decode_steps"]}}
        m1 = modeled_decode_step(full_cfg, base_cfg.n_slots,
                                 base_cfg.max_len, kv_bits)
        t_c, t_m = m1["t_compute_ms"], m1["t_memory_ms"]
        for k in (2, 4):
            so, _, ss = engines[k]
            acc = ss["spec"]["accepted_tokens_per_step"]
            rows_k = ss["spec"]["verify_rows_per_step"]
            # modeled TPU step: compute scales with verify rows, the
            # params+state stream does not — the step stays memory-bound
            # and the accepted tokens are (modeled) free
            modeled = acc * max(t_c, t_m) / max(t_c * rows_k, t_m)
            entry[f"k{k}"] = {
                "tok_s": ss["throughput_tok_s"],
                "decode_steps": ss["decode_steps"],
                "accepted_tokens_per_step": acc,
                "verify_rows_per_step": rows_k,
                "token_exact": bool(so == bo),
                "tok_s_vs_single_step":
                    ss["throughput_tok_s"] / bs_["throughput_tok_s"],
                "modeled_tok_s_vs_single_step": modeled,
            }
            _emit(rows, f"serve.spec.{key}.k{k}.accepted_per_step",
                  acc, "measured")
            _emit(rows, f"serve.spec.{key}.k{k}.token_exact",
                  int(entry[f"k{k}"]["token_exact"]), "measured")
            _emit(rows, f"serve.spec.{key}.k{k}.tok_s_vs_single_step",
                  entry[f"k{k}"]["tok_s_vs_single_step"], "measured")
            _emit(rows, f"serve.spec.{key}.k{k}.modeled_vs_single_step",
                  modeled, "derived")
        return entry

    out["spec_decode"] = {}
    # decode-heavy mix: short prompts, long generations — by mid-stream
    # the drafter has enough of the model's own output to match against,
    # so acceptance climbs with depth (and this is the regime speculation
    # targets: steady-state decode, not prefill)
    # rate=1e6 = everything arrives at t~0: scheduling (and hence the set
    # of verify-bucket shapes) is identical across warm and measured runs
    # instead of depending on how fast this host happens to step
    spec_reqs = zipf_prompts(generate(TrafficConfig(
        n_requests=12, rate=1e6, prompt_max=16,
        new_tokens_min=160, new_tokens_max=192,
        vocab_size=cfg.vocab_size)), cfg.vocab_size)
    spec_ecfg = dataclasses.replace(ecfg, max_len=256)
    full_olmo = get_arch("olmo-1b")
    for name, lay in (("dense", CacheLayout()),
                      ("paged", CacheLayout(kind="paged", block_size=8)),
                      ("int8", CacheLayout(kv_bits=8)),
                      ("paged_int8", CacheLayout(kind="paged", kv_bits=8,
                                                 block_size=8))):
        out["spec_decode"][name] = spec_entry(
            name, make_backend(cfg, params, layout=lay),
            dataclasses.replace(spec_ecfg, layout=lay), spec_reqs,
            full_olmo, kv_bits=lay.kv_bits or 16)
    # the non-uniform KV families: gemma's spec-margined sliding-window
    # ring (wraparound mid-draft) and whisper's per-slot cross-KV
    for fam, arch in (("gemma", "gemma3-1b"), ("whisper", "whisper-medium")):
        fcfg = dataclasses.replace(reduced(get_arch(arch)), dtype="float32")
        fparams = tf.init_params(jax.random.PRNGKey(0), fcfg)
        # milder zipf than the layout entries: these vocabularies are much
        # smaller, and at s=3.0 the prompts collapse to so few distinct
        # tokens that gemma's drafter loses its n-gram signal.  Generations
        # are long enough (40+) that acceptance reaches its depth regime.
        freqs = zipf_prompts(generate(TrafficConfig(
            n_requests=12, rate=1e6, prompt_max=12,
            new_tokens_min=40, new_tokens_max=48,
            vocab_size=fcfg.vocab_size,
            encoder_frames=fcfg.encoder_frames,
            frame_dim=fcfg.d_model if fcfg.encoder_layers else 0)),
            fcfg.vocab_size, s=1.2)
        out["spec_decode"][fam] = spec_entry(
            fam, make_backend(fcfg, fparams), ecfg, freqs,
            get_arch(arch))

    # -- per-family sweep: host-CPU reduced archs measure the engine; the
    # roofline terms model the FULL arch's TPU decode step (compute vs
    # resident-state memory, bf16 vs int8 KV) at a production-ish point
    out["families"] = {}
    for fam, arch in SERVE_FAMILIES:
        full = get_arch(arch)
        fcfg = dataclasses.replace(reduced(full), dtype="float32")
        fparams = tf.init_params(jax.random.PRNGKey(0), fcfg)
        # decode-dominated workload (short prompts, long + varied
        # generations): the static-batching drain barrier costs real steps,
        # so the continuous >= static gate has a wide, stable margin
        freqs = generate(TrafficConfig(
            n_requests=24, rate=500.0, prompt_max=12, new_tokens_max=32,
            vocab_size=fcfg.vocab_size,
            encoder_frames=fcfg.encoder_frames,
            frame_dim=fcfg.d_model if fcfg.encoder_layers else 0))
        backend = make_backend(fcfg, fparams)
        entry = {}
        dense_outputs = None
        for refill in ("static", "continuous"):
            vcfg = dataclasses.replace(ecfg, refill=refill)
            ServingEngine(backend, vcfg).run(freqs)      # compile/warm
            o, _, s = ServingEngine(backend, vcfg).run(freqs)
            if refill == "continuous":
                dense_outputs = o
            entry[refill] = s
            _emit(rows, f"serve.{fam}.{refill}.tok_s",
                  s["throughput_tok_s"], "measured")
            _emit(rows, f"serve.{fam}.{refill}.decode_steps",
                  s["decode_steps"], "measured")
        # paged-vs-dense token parity on THIS family (the per-family
        # record the CI paged gate checks actually ran): same workload
        # through the paged layout must reproduce the dense tokens exactly
        paged_layout = CacheLayout(kind="paged", block_size=8)
        pbackend = make_backend(fcfg, fparams, layout=paged_layout)
        pcfg = dataclasses.replace(ecfg, layout=paged_layout)
        po, _, ps = ServingEngine(pbackend, pcfg).run(freqs)
        entry["paged_parity"] = {
            "ran": True, "ok": bool(po == dense_outputs),
            "backend": type(pbackend).__name__,
            "shared_hits": ps["paged"]["shared_hits"],
            "cow_events": ps["paged"]["cow_events"],
        }
        _emit(rows, f"serve.{fam}.paged_token_exact",
              int(entry["paged_parity"]["ok"]), "measured")
        # modeled admission capacity at one HBM budget: dense reserves
        # max_len rows per slot, paged maps only live blocks.  Strictly
        # more slots whenever the family pages any KV (rwkv6 pages none —
        # its O(1) recurrent rows are identical under both layouts)
        budget, s_max, live = 8e9, 2048, 512
        adm_layout = CacheLayout(kind="paged", block_size=16)
        dense_slots = max_concurrent_slots(full, budget, s_max, live,
                                           CacheLayout())
        paged_slots = max_concurrent_slots(full, budget, s_max, live,
                                           adm_layout)
        entry["paged_admission"] = {
            "hbm_budget_gb": budget / 1e9, "max_len": s_max,
            "mean_live_len": live,
            "dense_slots": dense_slots, "paged_slots": paged_slots,
            "pageable": any(k == "attn" for k in full.layer_kinds()),
        }
        _emit(rows, f"serve.{fam}.admission.dense_slots", dense_slots,
              "derived")
        _emit(rows, f"serve.{fam}.admission.paged_slots", paged_slots,
              "derived")
        _emit(rows, f"serve.{fam}.continuous_vs_static.speedup",
              entry["continuous"]["throughput_tok_s"]
              / entry["static"]["throughput_tok_s"], "measured")
        entry["roofline"] = {
            "bf16": modeled_decode_step(full, n_slots=64, cache_len=2048,
                                        kv_bits=16),
            "int8": modeled_decode_step(full, n_slots=64, cache_len=2048,
                                        kv_bits=8),
        }
        _emit(rows, f"serve.{fam}.modeled_tpu_tok_s",
              entry["roofline"]["bf16"]["modeled_tok_s"], "derived")
        _emit(rows, f"serve.{fam}.modeled_state_mb_per_slot",
              entry["roofline"]["bf16"]["state_bytes_per_slot"] / 1e6,
              "derived")
        # decode-attention bytes/step on the FULL arch at ragged lengths
        # (mean utilization ~25% of S_max): dense streams the padded
        # cache, flash streams live KV blocks, int8-fused halves the bytes
        rng = np.random.default_rng(7)
        s_max = 4096
        ragged = rng.integers(0, s_max // 2, size=64).tolist()
        entry["decode_bytes"] = {
            "dense": decode_attn_read_bytes(full, ragged, s_max,
                                            impl="dense"),
            "flash": decode_attn_read_bytes(full, ragged, s_max,
                                            impl="flash"),
            "int8_fused": decode_attn_read_bytes(full, ragged, s_max,
                                                 impl="flash", kv_bits=8),
        }
        _emit(rows, f"serve.{fam}.attn_read_gb.dense",
              entry["decode_bytes"]["dense"]["attn_read_bytes_per_step"]
              / 1e9, "derived")
        _emit(rows, f"serve.{fam}.attn_read_gb.flash",
              entry["decode_bytes"]["flash"]["attn_read_bytes_per_step"]
              / 1e9, "derived")
        # parity record the CI gate checks: flash agrees with dense on
        # this family's decode step, ragged slots, interpret-mode kernel
        entry["decode_parity"] = decode_parity(fcfg, fparams)
        _emit(rows, f"serve.{fam}.decode_parity_maxdiff",
              entry["decode_parity"]["max_abs_diff"] * 1e6, "measured")
        out["families"][fam] = entry

    # -- disaggregated prefill/decode serving.  Pinned per-call clock
    # costs make the comparison a deterministic discrete-event sim: the
    # prefill-burst workload (long-prompt burst over a decode-heavy
    # background) hits one interleaved engine, then a 1-prefill +
    # 1-decode split whose decode tier is configured identically to the
    # interleaved engine.  The split takes the burst's prefills off the
    # decode path: p99 TTFT must drop while decode p50 TPOT holds
    # (within 5% — the decode tier steps the same pinned cost), and the
    # KV handoff must stay token-exact per family.
    from repro.serving import (PrefillBurstConfig, RouterConfig,
                               build_disagg, generate_prefill_burst)
    from repro.serving.traffic import Clock, Request

    COSTS = (0.010, 0.050, 0.002)   # decode / prefill / handoff seconds
    bcfg = PrefillBurstConfig(seed=0)
    bcfg = dataclasses.replace(bcfg, background=dataclasses.replace(
        bcfg.background, vocab_size=cfg.vocab_size))
    burst_reqs = generate_prefill_burst(bcfg)
    burst_rids = {r.rid for r in burst_reqs
                  if r.rid >= bcfg.background.n_requests}
    dcfg = dataclasses.replace(
        ecfg, layout=CacheLayout(kind="paged", block_size=8))

    def burst_split(records):
        """(all, background-only, burst-only) latency summaries."""
        bg = [r for r in records if r.rid not in burst_rids]
        bu = [r for r in records if r.rid in burst_rids]
        def lat(rs):
            ttfts = sorted(r.ttft for r in rs if r.ttft is not None)
            tpots = sorted(r.tpot for r in rs if r.tpot is not None)
            from repro.serving.metrics import percentile
            return {"ttft_p50_s": percentile(ttfts, 50),
                    "ttft_p99_s": percentile(ttfts, 99),
                    "tpot_p50_s": percentile(tpots, 50),
                    "tpot_p99_s": percentile(tpots, 99)}
        return {"all": lat(records), "background": lat(bg),
                "burst": lat(bu)}

    ibackend = make_backend(cfg, params, layout=dcfg.layout)
    io_, irecs, is_ = ServingEngine(
        ibackend, dcfg, Clock(*COSTS)).run(burst_reqs)
    srv = build_disagg(cfg, params, n_prefill=1, n_decode=1, ecfg=dcfg,
                       router_cfg=RouterConfig(), clock=Clock(*COSTS))
    do_, drecs, ds_ = srv.run(burst_reqs)
    ilat, dlat = burst_split(irecs), burst_split(drecs)
    ttft_ratio = (dlat["all"]["ttft_p99_s"] / ilat["all"]["ttft_p99_s"])
    tpot_ratio = (dlat["background"]["tpot_p50_s"]
                  / ilat["background"]["tpot_p50_s"])
    out["disagg"] = {
        "clock_costs_s": {"decode": COSTS[0], "prefill": COSTS[1],
                          "handoff": COSTS[2]},
        "topology": "1 interleaved vs 1 prefill + 1 decode "
                    f"({dcfg.n_slots} slots each tier)",
        "interleaved": ilat, "disagg": dlat,
        "handoffs": ds_["disagg"]["handoffs"],
        "router_policy": ds_["disagg"]["router_policy"],
        "token_exact_burst": bool(do_ == io_),
        "ttft_p99_ratio": ttft_ratio,
        "tpot_p50_ratio": tpot_ratio,
        "ttft_win": bool(ttft_ratio < 1.0),
        "tpot_held": bool(tpot_ratio <= 1.05),
    }
    _emit(rows, "serve.disagg.interleaved.ttft_p99_ms",
          ilat["all"]["ttft_p99_s"] * 1e3, "measured")
    _emit(rows, "serve.disagg.split.ttft_p99_ms",
          dlat["all"]["ttft_p99_s"] * 1e3, "measured")
    _emit(rows, "serve.disagg.ttft_p99_ratio", ttft_ratio, "measured")
    _emit(rows, "serve.disagg.tpot_p50_ratio", tpot_ratio, "measured")
    _emit(rows, "serve.disagg.handoffs", ds_["disagg"]["handoffs"],
          "measured")
    _emit(rows, "serve.disagg.token_exact_burst",
          int(out["disagg"]["token_exact_burst"]), "measured")

    # per-family handoff token-exactness (all five families; rwkv6 pages
    # zero KV leaves — its whole recurrent state rides the slot-state
    # half of the handoff).  Tiny workloads: the point is the bit-exact
    # flag, not throughput.
    out["disagg"]["token_exact"] = {}
    for fam, arch in SERVE_FAMILIES:
        fcfg = dataclasses.replace(reduced(get_arch(arch)),
                                   dtype="float32")
        fparams = tf.init_params(jax.random.PRNGKey(0), fcfg)
        rng = np.random.default_rng(0)
        freqs = []
        for i in range(4):
            frames = None
            if fcfg.encoder_layers:
                f = rng.normal(0, 0.02, (fcfg.encoder_frames,
                                         fcfg.d_model))
                frames = tuple(tuple(float(x) for x in row) for row in f)
            freqs.append(Request(
                rid=i, user_id=i,
                prompt=tuple(int(t) for t in rng.integers(
                    3, fcfg.vocab_size, int(rng.integers(4, 12)))),
                max_new_tokens=int(rng.integers(3, 8)),
                arrival=0.04 * i, frames=frames))
        fec = dataclasses.replace(dcfg, n_slots=2)
        fb = make_backend(fcfg, fparams, layout=fec.layout)
        so, _, _ = ServingEngine(fb, fec, Clock(*COSTS)).run(freqs)
        fsrv = build_disagg(fcfg, fparams, n_prefill=1, n_decode=1,
                            ecfg=fec, clock=Clock(*COSTS))
        fo, _, fs = fsrv.run(freqs)
        exact = bool(so == fo)
        out["disagg"]["token_exact"][fam] = {
            "ok": exact, "handoffs": fs["disagg"]["handoffs"]}
        _emit(rows, f"serve.disagg.{fam}.token_exact", int(exact),
              "measured")

    # modeled full-arch tier split: prefill compute-bound vs decode
    # memory-bound, and what one KV handoff costs next to the prefill
    # stall it removes from the decode path
    from repro.serving.roofline import modeled_tier_split
    out["disagg"]["roofline"] = {
        fam: modeled_tier_split(get_arch(arch), n_slots=64,
                                cache_len=2048, prompt_len=1024)
        for fam, arch in SERVE_FAMILIES}
    _emit(rows, "serve.disagg.modeled_stall_vs_handoff",
          out["disagg"]["roofline"]["uniform"]["stall_vs_handoff"],
          "derived")

    # -- recsys retrieval->rank: the sharded CF head inside the engine on
    # an 8-device subprocess mesh.  Per sharding plan the same Zipfian
    # candidate workload runs cache-off then cache-on; the hot-row
    # replica must cut the cross-shard lookup traffic (measured exchange
    # ids and ring-modeled bytes at the measured hit rate) while keeping
    # fused scores, rankings and token streams bit-identical
    r = _run_payload(_module="benchmarks._recsys_payload", mesh="2,4",
                     requests=20, candidates=16, cache_rows=128)
    out["recsys"] = r
    for plan, e in r["plans"].items():
        _emit(rows, f"serve.recsys.{plan}.hit_rate", e["hit_rate"],
              "measured")
        _emit(rows, f"serve.recsys.{plan}.tok_s_cached",
              e["tok_s_cached"], "measured")
        _emit(rows, f"serve.recsys.{plan}.exchanged_ids_cached",
              e["exchanged_ids_cached"], "measured")
        _emit(rows, f"serve.recsys.{plan}.exchanged_ids_uncached",
              e["exchanged_ids_uncached"], "measured")
        _emit(rows, f"serve.recsys.{plan}.modeled_bytes_cached",
              e["modeled"]["cached_bytes"], "derived")
        _emit(rows, f"serve.recsys.{plan}.modeled_bytes_uncached",
              e["modeled"]["uncached_bytes"], "derived")
        _emit(rows, f"serve.recsys.{plan}.scores_exact",
              int(e["scores_exact"]), "measured")
        _emit(rows, f"serve.recsys.{plan}.tokens_exact",
              int(e["tokens_exact"]), "measured")
    _save("serve", out)


ALL = {"table2": table2, "table3": table3, "fig4": fig4, "fig5": fig5,
       "compression": compression, "async": async_staleness,
       "kernels": kernels, "serve": serve, "embed": embed,
       "train-parallel": train_parallel}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    rows = ["name,us_per_call,derived"]
    print(rows[0])
    for name in which:
        try:
            ALL[name](rows)
        except Exception as e:  # noqa: BLE001 — benchmark isolation
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "bench.csv"), "w") as f:
        f.write("\n".join(rows) + "\n")


if __name__ == "__main__":
    main()
