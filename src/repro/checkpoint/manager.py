"""Fault-tolerant checkpointing: atomic sharded saves, keep-N GC, resume
from the latest *valid* checkpoint (torn writes are skipped), and elastic
resharding on restore (mesh/topology changes between runs).

Layout:  <dir>/step_<k>.tmp/ -> (atomic rename) -> <dir>/step_<k>/
           arrays.npz        flat {path: array}
           manifest.json     step, keys, mesh metadata, COMMIT marker
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         extra_meta: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step:010d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "keys": sorted(flat),
                "committed": True, **(extra_meta or {})}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic commit
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if _valid(os.path.join(ckpt_dir, name)):
                out.append(int(name[5:]))
    return sorted(out)


def _valid(path: str) -> bool:
    mf = os.path.join(path, "manifest.json")
    if not (os.path.exists(mf) and
            os.path.exists(os.path.join(path, "arrays.npz"))):
        return False
    try:
        with open(mf) as f:
            return bool(json.load(f).get("committed"))
    except (json.JSONDecodeError, OSError):
        return False


def restore(ckpt_dir: str, step: int, template, shardings=None
            ) -> Any:
    """Restore into ``template``'s structure; optionally place each leaf
    with ``shardings`` (elastic reshard across mesh changes — the loaded
    full array is re-laid-out onto the new mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves_p:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        a = arrays[key]
        if hasattr(leaf, "dtype"):
            a = a.astype(leaf.dtype)
        out.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, [jnp.asarray(a)
                                                  for a in out])
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s),
                            tree, shardings)
    return tree


def restore_latest(ckpt_dir: str, template, shardings=None
                   ) -> Tuple[Optional[int], Any]:
    """(step, tree) from the newest valid checkpoint, or (None, template).

    Walks backwards over checkpoints so a torn/corrupt newest write (node
    failure mid-save) falls through to the previous one."""
    for step in reversed(list_steps(ckpt_dir)):
        try:
            return step, restore(ckpt_dir, step, template, shardings)
        except (KeyError, OSError, ValueError):
            continue
    return None, template
