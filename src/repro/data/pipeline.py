"""Host-side data pipeline: synthetic batch sources, device placement with
the plan's shardings, and a background prefetcher (overlap host data prep
with device compute).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_lm_batches(vocab: int, batch: int, seq: int, steps: int,
                         seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic synthetic LM stream (zipf-ish token distribution)."""
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        ranks = rng.zipf(1.3, size=(batch, seq + 1))
        tokens = np.minimum(ranks - 1, vocab - 1).astype(np.int32)
        yield {"tokens": tokens[:, :-1],
               "targets": tokens[:, 1:],
               "mask": np.ones((batch, seq), np.float32)}


def place_batch(batch: Dict[str, np.ndarray], shardings: Optional[Any] = None
                ) -> Dict[str, jnp.ndarray]:
    """Host numpy -> device arrays with the plan's batch shardings."""
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return jax.tree.map(
        lambda v, s: jax.device_put(jnp.asarray(v), s), batch, shardings)


class Prefetcher:
    """Background-thread prefetch of N batches (host->device overlap)."""

    def __init__(self, it: Iterator, size: int = 2,
                 place: Callable = place_batch, shardings=None):
        self._q: "queue.Queue" = queue.Queue(maxsize=size)
        self._done = object()

        def worker():
            try:
                for item in it:
                    self._q.put(place(item, shardings))
            finally:
                self._q.put(self._done)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        while True:
            item = self._q.get()
            if item is self._done:
                return
            yield item
