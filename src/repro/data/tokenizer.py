"""Toy deterministic hash tokenizer for synthetic item text.

Real deployments plug a sentencepiece model in here; the framework only
requires ``encode -> List[int] < vocab``.
"""
from __future__ import annotations

import hashlib
from typing import List


class HashTokenizer:
    def __init__(self, vocab_size: int, reserved: int = 4):
        self.vocab_size = vocab_size
        self.reserved = reserved          # 0=pad, 1=bos, 2=eos, 3=unk

    def _tok(self, word: str) -> int:
        h = int(hashlib.md5(word.encode()).hexdigest()[:8], 16)
        return self.reserved + h % (self.vocab_size - self.reserved)

    def encode(self, text: str, max_len: int = 0) -> List[int]:
        ids = [1] + [self._tok(w) for w in text.lower().split()] + [2]
        if max_len:
            ids = ids[:max_len] + [0] * max(0, max_len - len(ids))
        return ids

    @property
    def pad_id(self) -> int:
        return 0
