"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run launcher sets XLA_FLAGS for 512 host devices *before*
any jax import; smoke tests and benchmarks see the real (1-device) backend.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0,
                   stage: int = 0):
    """Small mesh over however many host devices exist (tests/benches).
    ``stage > 0`` appends a pipeline-stage axis (DP x TP x PP meshes for
    the pipelined train step)."""
    shape = ((pod,) if pod else ()) + (data, model) + \
        ((stage,) if stage else ())
    axes = (("pod",) if pod else ()) + ("data", "model") + \
        (("stage",) if stage else ())
    return compat.make_mesh(shape, axes)
