"""Serving launcher: continuous-batching engine under simulated recsys load.

Default mode drives :mod:`repro.serving` — a fixed-slot continuous-batching
engine fed by the Poisson/bursty Zipfian traffic simulator — and reports
throughput plus p50/p95/p99 TTFT / per-token latency against SLO tiers.
The engine serves **every architecture family** through its family-backend
registry (uniform decoders, gemma ring buffers, jamba/rwkv6 recurrent
state, whisper cross-KV), and ``--kv int8`` composes with any KV-bearing
family:

  PYTHONPATH=src python -m repro.launch.serve --reduced
  PYTHONPATH=src python -m repro.launch.serve --reduced --arch deepseek-7b \\
      --slots 8 --requests 64 --rate 128 --process bursty --kv int8
  PYTHONPATH=src python -m repro.launch.serve --reduced --arch rwkv6-1.6b
  PYTHONPATH=src python -m repro.launch.serve --reduced --arch gemma3-1b \\
      --kv int8

``--decode-impl flash`` swaps the decode-attention hot path for the Pallas
flash-decode kernel (per-slot length-aware KV-block skipping); ``--prefill-
chunk N`` streams uniform-family prompts through prefill in fixed chunks:

  PYTHONPATH=src python -m repro.launch.serve --reduced --arch gemma3-1b \\
      --decode-impl flash
  PYTHONPATH=src python -m repro.launch.serve --reduced --arch olmo-1b \\
      --decode-impl flash --prefill-chunk 8 --kv int8

``--cache-layout paged`` switches the KV cache to the shared block pool
with prefix sharing and copy-on-write (``--block-size`` rows per block,
``--num-blocks`` to cap the pool below the dense footprint,
``--no-prefix-sharing`` to disable prompt dedup).  All the cache knobs —
paging, int8, decode impl — are one :class:`repro.cache_layout.CacheLayout`
under the hood:

  PYTHONPATH=src python -m repro.launch.serve --reduced --arch olmo-1b \\
      --cache-layout paged --block-size 16 --decode-impl flash
  PYTHONPATH=src python -m repro.launch.serve --reduced --arch gemma3-1b \\
      --cache-layout paged --kv int8

``--spec-k N`` turns on speculative multi-token decode: each scheduler
step self-drafts up to ``N - 1`` continuation tokens per greedy slot
(``--spec-draft ngram`` — no second model) and verifies all rows in one
fused k-row decode, emitting the accepted prefix.  Token streams are
identical to single-step greedy decode; recurrent families (jamba,
rwkv6) reject the flag with a clear error:

  PYTHONPATH=src python -m repro.launch.serve --reduced --arch olmo-1b \\
      --spec-k 4 --cache-layout paged --decode-impl flash

``--disagg`` splits serving into a prefill tier and a decode tier
(requires ``--cache-layout paged`` — the KV handoff rides the block
pool) with ``--prefill-replicas`` / ``--decode-replicas`` engines per
tier and a router placing arrivals / handoffs by ``--router-policy``
(``slo`` scores load + live windowed p99, ``least_loaded``,
``round_robin``).  Token streams stay bit-identical to one interleaved
engine; ``--scenario prefill-burst`` drives the workload disaggregation
is for (long-prompt burst over decode-heavy background):

  PYTHONPATH=src python -m repro.launch.serve --reduced --arch olmo-1b \\
      --cache-layout paged --disagg --prefill-replicas 1 \\
      --decode-replicas 2 --scenario prefill-burst

``--candidates N`` attaches a head-heavy (Zipfian) candidate item set to
every request and ``--cf-plan`` mounts the sharded CF scoring head inside
the engine: each request is then a full retrieval->rank call — LM prefill
+ CF factor lookup + gated fusion + candidate ranking.  ``--cf-cache-rows``
sizes the frequency-tracked hot-row replica in front of the sharded
lookup (hits skip the cross-shard exchange; scores are bit-identical with
the cache on or off).  The CF head rides the single-engine path; with
``--disagg`` the flags are ignored (candidate scoring happens at prefill
admission, which disagg delegates to tier replicas):

  PYTHONPATH=src python -m repro.launch.serve --reduced --arch olmo-1b \\
      --candidates 16 --cf-plan row --cf-cache-rows 256

``--mode raw`` keeps the original fixed-batch decode-loop microbenchmark:

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --mode raw --batch 8 --new-tokens 32
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.cache_layout import CacheLayout
from repro.config import get_arch, list_archs, reduced
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx
from repro.obs import MetricsRegistry, Tracer, write_trace
from repro.serving import (CFHead, EngineConfig, PrefillBurstConfig,
                           RouterConfig, ServingEngine, TrafficConfig,
                           build_disagg, generate, generate_prefill_burst)
from repro.serving.engine import make_backend
from repro.serving.metrics import format_report


def run_engine(args) -> int:
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)

    defaults = TrafficConfig()
    tcfg = TrafficConfig(
        n_requests=args.requests, rate=args.rate, process=args.process,
        prompt_max=max(defaults.prompt_min, min(48, args.max_len // 2)),
        new_tokens_max=max(defaults.new_tokens_min,
                           min(24, args.max_len // 4)),
        vocab_size=cfg.vocab_size, seed=args.seed,
        temperature=args.temperature, top_k=args.top_k,
        # recsys retrieval->rank: per-request candidate item sets (drawn
        # from a separate rng stream — the base workload is unperturbed)
        candidates=args.candidates,
        # enc-dec families: per-request encoder frames -> per-slot cross-KV
        encoder_frames=cfg.encoder_frames,
        frame_dim=cfg.d_model if cfg.encoder_layers else 0,
        # vlm (mrope): prompts carry an image-patch grid prefix so decode
        # exercises the text+patch position layout
        image_grid=(2, 2) if cfg.pos_type == "mrope" else ())
    if args.scenario == "prefill-burst":
        bcfg = PrefillBurstConfig(seed=args.seed)
        bcfg = dataclasses.replace(
            bcfg, background=dataclasses.replace(
                bcfg.background, vocab_size=cfg.vocab_size,
                seed=args.seed))
        requests = generate_prefill_burst(bcfg)
    else:
        requests = generate(tcfg)

    # every cache knob (paging, precision, decode impl) folds into one
    # CacheLayout; the legacy --kv/--decode-impl flags map onto it
    layout = CacheLayout(kind=args.cache_layout,
                         kv_bits=8 if args.kv == "int8" else 16,
                         impl=args.decode_impl,
                         block_size=args.block_size,
                         num_blocks=args.num_blocks,
                         prefix_sharing=not args.no_prefix_sharing)
    ecfg = EngineConfig(n_slots=args.slots, max_len=args.max_len,
                        queue_capacity=args.queue_capacity,
                        refill=args.refill, sample_seed=args.seed,
                        layout=layout, prefill_chunk=args.prefill_chunk,
                        spec_k=args.spec_k, spec_draft=args.spec_draft)
    try:
        rcfg = RouterConfig(policy=args.router_policy,
                            window=args.router_window,
                            ttft_weight=args.ttft_weight,
                            tpot_weight=args.tpot_weight)

        def mk_cf_head():
            if args.cf_plan == "off" or args.disagg:
                return None
            # trivial 1x1 mesh off-TPU: exercises the plan's shard_map
            # path; a real deployment hands in the training mesh
            mesh = compat.make_mesh((1, 1), ("data", "model"))
            return CFHead.build(
                n_users=tcfg.n_users, n_items=cfg.vocab_size, cf_dim=16,
                seed=args.seed, plan=args.cf_plan,
                cache_rows=args.cf_cache_rows, mesh=mesh)

        def mk_server(tracer=None, metrics=None):
            if args.disagg:
                return build_disagg(
                    cfg, params, n_prefill=args.prefill_replicas,
                    n_decode=args.decode_replicas, ecfg=ecfg,
                    router_cfg=rcfg, tracer=tracer, metrics=metrics)
            backend = make_backend(cfg, params, layout=layout,
                                   prefill_chunk=args.prefill_chunk)
            return ServingEngine(backend, ecfg, tracer=tracer,
                                 metrics=metrics, cf_head=mk_cf_head())

        if not args.no_warmup:
            # compile every prefill bucket + the decode step outside the
            # measured run, as a resident production server would be
            mk_server().run(requests)
        # tracing is scoped to the measured run only, never the warmup
        tracer = Tracer() if args.trace_out else None
        metrics = MetricsRegistry() if args.trace_out else None
        engine = mk_server(tracer=tracer, metrics=metrics)
    except ValueError as e:       # layout/family/spec_k mismatches
        raise SystemExit(str(e))
    outputs, records, summary = engine.run(requests)

    topo = (f"disagg {args.prefill_replicas}P+{args.decode_replicas}D "
            f"{args.router_policy} " if args.disagg else "")
    title = (f"{cfg.name} {topo}{args.cache_layout} kv={args.kv} "
             f"refill={args.refill} "
             f"slots={args.slots} {args.process}@{args.rate:g}req/s")
    print(format_report(summary, title))
    if "cf" in summary:
        s = summary["cf"]
        print(f"cf head: plan={s['plan']} scored={s['requests_scored']} "
              f"cache_rows={s['cache_rows']} (live {s['cache_rows_live']}) "
              f"hit_rate={s['hit_rate']:.3f} "
              f"({s['hits']} hits / {s['misses']} misses)")
    if args.trace_out:
        n = write_trace(args.trace_out, tracer, metrics)
        print(f"trace: {n} events -> {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")
    if args.json:
        print(json.dumps(summary, indent=1))
    return 0


def run_raw(args) -> int:
    """Legacy fixed-batch decode loop (any family, incl. ssm/enc-dec)."""
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    ctx = ModelCtx(attn_chunk=64, mamba_chunk=16, moe_group=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cache = tf.init_cache(cfg, args.batch, args.max_len)
    if cfg.encoder_layers:
        frames = jnp.zeros((args.batch, cfg.encoder_frames, cfg.d_model),
                           jnp.dtype(cfg.dtype))
        ck, cv = tf.whisper_prefill_cross(cfg, params, frames, ctx)
        cache["cross_k"], cache["cross_v"] = ck, cv

    decode = jax.jit(lambda p, c, t, pos=None: tf.decode_step(
        cfg, p, c, t, ctx, positions=pos))
    tok = jnp.ones((args.batch, 1), jnp.int32)
    pos = (jnp.zeros((args.batch, 1, 3), jnp.int32)
           if cfg.pos_type == "mrope" else None)

    # warmup + timed loop
    logits, cache = decode(params, cache, tok, pos)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(args.new_tokens):
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"{cfg.name}: {tps:.1f} tokens/s (host CPU), "
          f"{dt / args.new_tokens * 1e3:.1f} ms/step at batch {args.batch}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="engine", choices=("engine", "raw"))
    # engine mode
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=64.0)
    ap.add_argument("--process", default="poisson",
                    choices=("poisson", "bursty"))
    ap.add_argument("--kv", default="native", choices=("native", "int8"))
    ap.add_argument("--cache-layout", default="dense",
                    choices=("dense", "paged"),
                    help="KV cache layout: dense per-slot (B, S, ...) rows "
                         "or the shared block pool with per-slot block "
                         "tables, prefix sharing and copy-on-write")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged layout: KV rows per physical block")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged layout: pool size in blocks (0 = auto: one "
                         "dense footprint, slots*max_len/block_size); set "
                         "below auto to oversubscribe and exercise "
                         "admission queueing")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="paged layout: disable content-hash prompt-prefix "
                         "block sharing")
    ap.add_argument("--decode-impl", default="dense",
                    choices=("dense", "flash"),
                    help="decode-attention hot path: dense XLA einsum over "
                         "the padded cache, or the Pallas flash-decode "
                         "kernel (per-slot length-aware KV-block skipping; "
                         "interpret mode off-TPU)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="stream uniform-family prompts through prefill in "
                         "fixed chunks of this many tokens (0 = monolithic "
                         "padded forward)")
    ap.add_argument("--spec-k", type=int, default=1,
                    help="speculative decode: verify up to this many token "
                         "rows per slot per step (1 = classic one-token "
                         "decode; KV families only — jamba/rwkv6 refuse)")
    ap.add_argument("--spec-draft", default="ngram", choices=("ngram",),
                    help="speculative draft source: self-speculative n-gram "
                         "lookup over the request's own prompt + output "
                         "(no second model)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: a prefill tier hands "
                         "finished prompts' KV to a decode tier over the "
                         "block pool (requires --cache-layout paged); "
                         "token streams stay bit-identical to one "
                         "interleaved engine")
    ap.add_argument("--prefill-replicas", type=int, default=1,
                    help="disagg: engines in the prefill tier")
    ap.add_argument("--decode-replicas", type=int, default=1,
                    help="disagg: engines in the decode tier (0 = no "
                         "split; N 'both'-role replicas behind the "
                         "router)")
    ap.add_argument("--router-policy", default="slo",
                    choices=("slo", "least_loaded", "round_robin"),
                    help="replica placement: slo = normalized load + "
                         "windowed tail-latency percentile, least_loaded "
                         "= load only, round_robin = stateless")
    ap.add_argument("--router-window", type=int, default=64,
                    help="slo policy: recent latency samples per replica "
                         "feeding the windowed p99")
    ap.add_argument("--ttft-weight", type=float, default=1.0,
                    help="slo policy: weight of windowed p99 TTFT in the "
                         "prefill-placement score")
    ap.add_argument("--tpot-weight", type=float, default=10.0,
                    help="slo policy: weight of windowed p99 TPOT in the "
                         "decode-placement score")
    ap.add_argument("--scenario", default="traffic",
                    choices=("traffic", "prefill-burst"),
                    help="prefill-burst: seeded burst of long prompts "
                         "over a decode-heavy Zipfian background (the "
                         "disaggregation stress workload)")
    ap.add_argument("--candidates", type=int, default=0,
                    help="recsys retrieval->rank: head-heavy (Zipfian) "
                         "candidate item ids per request the CF head "
                         "scores and ranks (0 = plain LM serving)")
    ap.add_argument("--cf-plan", default="off",
                    choices=("off", "replicated", "row", "col", "row_col"),
                    help="mount the CF scoring head with its cf_user/"
                         "cf_item factor tables under this sharding plan "
                         "(single-engine mode only; ignored with --disagg)")
    ap.add_argument("--cf-cache-rows", type=int, default=128,
                    help="hot-row replica capacity per CF table: the "
                         "frequency-tracked head served without the "
                         "cross-shard exchange (0 = cache off; scores are "
                         "bit-identical either way)")
    ap.add_argument("--refill", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k best logits (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true")
    ap.add_argument("--trace-out", default="",
                    help="write the measured run's span timeline + metrics "
                         "here: .jsonl for raw events, anything else for "
                         "Chrome-trace/Perfetto JSON")
    ap.add_argument("--json", action="store_true")
    # raw mode
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args(argv)
    if args.mode == "raw":
        return run_raw(args)
    return run_engine(args)


if __name__ == "__main__":
    raise SystemExit(main())
