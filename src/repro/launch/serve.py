"""Serving launcher: batched decode benchmark for any --arch.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
      --batch 8 --new-tokens 32
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, list_archs, reduced
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    ctx = ModelCtx(attn_chunk=64, mamba_chunk=16, moe_group=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cache = tf.init_cache(cfg, args.batch, args.cache_len)
    if cfg.encoder_layers:
        frames = jnp.zeros((args.batch, cfg.encoder_frames, cfg.d_model),
                           jnp.dtype(cfg.dtype))
        ck, cv = tf.whisper_prefill_cross(cfg, params, frames, ctx)
        cache["cross_k"], cache["cross_v"] = ck, cv

    decode = jax.jit(lambda p, c, t, pos=None: tf.decode_step(
        cfg, p, c, t, ctx, positions=pos))
    tok = jnp.ones((args.batch, 1), jnp.int32)
    pos = (jnp.zeros((args.batch, 1, 3), jnp.int32)
           if cfg.pos_type == "mrope" else None)

    # warmup + timed loop
    logits, cache = decode(params, cache, tok, pos)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(args.new_tokens):
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"{cfg.name}: {tps:.1f} tokens/s (host CPU), "
          f"{dt / args.new_tokens * 1e3:.1f} ms/step at batch {args.batch}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
