"""Training launcher: any --arch at any scale on the available devices.

On real TPU pods this is the per-host entrypoint (jax.distributed handles
multi-host); on this CPU container it runs reduced configs end-to-end with
the full runtime (hybrid sharding plan, ZeRO-1/2, remat, checkpoints,
prefetch, straggler-aware data allocation).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 --batch 16 --seq 64

``--pp-stages N`` switches to the pipelined DP x TP x stage path: the
planner's balanced layer bounds slice the transformer into stages, the
1F1B (or GPipe, ``--pp-schedule``) schedule drives them over ``--pp-micro``
micro-batches, and DP gradient sync composes across the ``data`` axis:

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --host-devices 8 --data 2 --model 2 --pp-stages 2 --pp-micro 4 \
      --steps 10 --batch 16 --seq 32
"""
import argparse
import dataclasses
import os


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1, help="dp mesh size")
    ap.add_argument("--model", type=int, default=1, help="tp mesh size")
    ap.add_argument("--pp-stages", type=int, default=1,
                    help="pipeline stages (>1 enables the pipelined path)")
    ap.add_argument("--pp-micro", type=int, default=4,
                    help="pipeline micro-batches per step")
    ap.add_argument("--pp-schedule", default="1f1b",
                    choices=("1f1b", "gpipe"))
    ap.add_argument("--pp-rebalance-every", type=int, default=0,
                    help="every K steps, re-carve the layer->stage bounds "
                         "from measured per-stage times and live-remap "
                         "params/optimizer (0 = off)")
    ap.add_argument("--grad-sync", default="flat",
                    choices=("flat", "hierarchical", "onebit", "topk"),
                    help="DP gradient sync mode on the pipelined path")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N virtual host devices (set before jax "
                         "initializes; needed for --pp-stages on CPU)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--trace-out", default="",
                    help="write the training span timeline here (train_step "
                         "/ rebalance.probe / checkpoint spans, plus "
                         "per-stage stage_tick spans from rebalance probes "
                         "on the pipelined path): .jsonl for raw events, "
                         "anything else for Chrome-trace/Perfetto JSON")
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}")

    import jax
    import jax.numpy as jnp

    from repro.config import (ParallelConfig, ShapeConfig, TrainConfig,
                              get_arch, list_archs, reduced)
    from repro.core.hybrid import auto_plan
    from repro.data import pipeline
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tf
    from repro.obs import Tracer, write_trace
    from repro.optimizer import adamw
    from repro.runtime import trainer

    if args.arch not in list_archs():
        ap.error(f"unknown arch {args.arch}; have {list_archs()}")
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    pp = max(args.pp_stages, 1)
    mesh = make_host_mesh(data=args.data, model=args.model,
                          stage=pp if pp > 1 else 0)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    pcfg = ParallelConfig(dp=args.data, tp=args.model, pp=pp,
                          microbatches=args.pp_micro,
                          pp_schedule=args.pp_schedule)
    plan = auto_plan(cfg, mesh, shape, pcfg)
    tcfg = TrainConfig(steps=args.steps, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 20, 2),
                       checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=max(args.steps // 4, 10))

    tracer = Tracer() if args.trace_out else None
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params on mesh "
          f"data={args.data} model={args.model} stage={pp}; "
          f"plan notes: {plan.notes}")

    def gen(start):
        for b in pipeline.synthetic_lm_batches(
                cfg.vocab_size, args.batch, args.seq,
                args.steps - start, seed=start):
            b = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.encoder_layers:
                b["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_frames, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            if cfg.pos_type == "mrope":
                s_img = int(cfg.image_prefix_frac * args.seq)
                b["patch_embeds"] = jnp.zeros(
                    (args.batch, s_img, cfg.d_model), jnp.dtype(cfg.dtype))
                b["positions"] = jnp.broadcast_to(
                    jnp.arange(args.seq)[None, :, None],
                    (args.batch, args.seq, 3)).astype(jnp.int32)
            yield b

    if pp > 1:
        # --- pipelined DP x TP x stage path ------------------------------
        bounds = list(plan.stage_bounds)
        scfg = trainer.DPSyncConfig(mode=args.grad_sync)
        pp_params = tf.pp_partition_params(cfg, params, bounds)
        pp_shape = jax.eval_shape(lambda: pp_params)
        opt = adamw.init_opt_state(
            trainer.pp_trainable(pp_params, cfg.tie_embeddings))
        res = jnp.zeros((args.data, args.model, pp,
                         trainer.pp_residual_size(cfg, pp_shape, mesh,
                                                  scfg)))
        state = {"params": pp_params, "opt": opt, "residual": res,
                 "stage_bounds": jnp.asarray(bounds, jnp.int32)}
        start = 0
        if args.resume:
            start, state = trainer.resume_or_init(state, tcfg)
            # checkpoints restore by key (shapes come from disk): a run
            # rebalanced mid-flight restores its moved carve points, and
            # the step must be rebuilt at THOSE bounds, not the planner's
            bounds = [int(b) for b in state["stage_bounds"]]
            pp_shape = jax.eval_shape(lambda: state["params"])
        step_fn = trainer.make_pp_train_step(
            cfg, mesh, tcfg, bounds, pp_shape, n_micro=args.pp_micro,
            pp_schedule=args.pp_schedule, scfg=scfg)
        rebal = None
        if args.pp_rebalance_every:
            rebal = trainer.PPRebalancer(
                cfg, mesh, tcfg, bounds, n_micro=args.pp_micro,
                pp_schedule=args.pp_schedule, scfg=scfg, tracer=tracer)
        res_run = trainer.train_loop(
            state, gen(start), step_fn, tcfg, start_step=start,
            samples_per_batch=args.batch, verbose=True,
            rebalance_every=args.pp_rebalance_every, rebalance_fn=rebal,
            log_every=max(args.steps // 10, 1), tracer=tracer)
        if rebal is not None and len(rebal.history) > 1:
            print(f"stage bounds rebalanced {len(rebal.history) - 1}x: "
                  f"{rebal.history[0]} -> {rebal.history[-1]}")
    else:
        # --- GSPMD hybrid path (TP x DP) ---------------------------------
        step, jitted, shardings_for = trainer.make_hybrid_train_step(
            cfg, plan, tcfg)
        opt = adamw.init_opt_state(params)
        start, state = (trainer.resume_or_init(
            {"params": params, "opt": opt}, tcfg)
            if args.resume else (0, {"params": params, "opt": opt}))
        fn = jitted(jax.eval_shape(lambda: state["params"]),
                    next(iter(gen(start))))
        res_run = trainer.train_loop(
            state, gen(start), fn, tcfg, start_step=start,
            samples_per_batch=args.batch, verbose=True,
            log_every=max(args.steps // 10, 1), tracer=tracer)
    print(f"done: {res_run.steps_run} steps, host throughput "
          f"{res_run.throughput:.1f} samples/s, final loss "
          f"{res_run.losses[-1]:.4f}")
    if args.trace_out:
        nev = write_trace(args.trace_out, tracer)
        print(f"trace: {nev} events -> {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
