"""Training launcher: any --arch at any scale on the available devices.

On real TPU pods this is the per-host entrypoint (jax.distributed handles
multi-host); on this CPU container it runs reduced configs end-to-end with
the full runtime (hybrid sharding plan, ZeRO-1/2, remat, checkpoints,
prefetch, straggler-aware data allocation).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 --batch 16 --seq 64
"""
import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.config import (ParallelConfig, ShapeConfig, TrainConfig,
                          get_arch, list_archs, reduced)
from repro.core.hybrid import auto_plan
from repro.data import pipeline
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.optimizer import adamw
from repro.runtime import trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1, help="dp mesh size")
    ap.add_argument("--model", type=int, default=1, help="tp mesh size")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    mesh = make_host_mesh(data=args.data, model=args.model)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    plan = auto_plan(cfg, mesh, shape, ParallelConfig())
    tcfg = TrainConfig(steps=args.steps, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 20, 2),
                       checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=max(args.steps // 4, 10))

    step, jitted, shardings_for = trainer.make_hybrid_train_step(
        cfg, plan, tcfg)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params on mesh "
          f"data={args.data} model={args.model}; plan notes: {plan.notes}")

    start, state = (trainer.resume_or_init({"params": params, "opt": opt},
                                           tcfg)
                    if args.resume else (0, {"params": params, "opt": opt}))

    def gen():
        for b in pipeline.synthetic_lm_batches(
                cfg.vocab_size, args.batch, args.seq,
                args.steps - start, seed=start):
            b = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.encoder_layers:
                b["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_frames, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            if cfg.pos_type == "mrope":
                s_img = int(cfg.image_prefix_frac * args.seq)
                b["patch_embeds"] = jnp.zeros(
                    (args.batch, s_img, cfg.d_model), jnp.dtype(cfg.dtype))
                b["positions"] = jnp.broadcast_to(
                    jnp.arange(args.seq)[None, :, None],
                    (args.batch, args.seq, 3)).astype(jnp.int32)
            yield b

    fn = jitted(jax.eval_shape(lambda: state["params"]), next(iter(gen())))
    res = trainer.train_loop(state, gen(), fn, tcfg, start_step=start,
                             samples_per_batch=args.batch, verbose=True,
                             log_every=max(args.steps // 10, 1))
    print(f"done: {res.steps_run} steps, host throughput "
          f"{res.throughput:.1f} samples/s, final loss {res.losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
