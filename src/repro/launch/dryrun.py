import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The production dry-run needs 512 placeholder devices for the 2x16x16 mesh.

# HLO dump (still before any jax import): the roofline reads the post-SPMD,
# pre-float-normalization module — per-device shapes with bf16 preserved
# (XLA:CPU promotes bf16->f32 later; TPU would not).
import tempfile  # noqa: E402
_DUMP_DIR = os.environ.get("REPRO_DUMP_DIR") or tempfile.mkdtemp(
    prefix="repro_hlo_dump_")
os.environ["XLA_FLAGS"] += (
    f" --xla_dump_to={_DUMP_DIR}"
    " --xla_dump_hlo_pass_re=all-reduce-promotion"
    " --xla_dump_large_constants=false")

"""Multi-pod dry-run launcher (deliverable e).

For every (architecture x input shape) cell, lower + compile the appropriate
step (train_step / prefill_step / serve_step) against the production mesh —
16x16=256 chips single-pod and 2x16x16=512 chips multi-pod — and record
memory_analysis / cost_analysis / collective traffic for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

``--all`` runs each cell in a fresh subprocess (cell isolation: one cell's
compiler crash or memory blow-up cannot take down the sweep — the same
fault-tolerance stance the trainer takes toward nodes).
"""
import argparse      # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402


def _print_result(res: dict, dt: float) -> None:
    arch, shape, mesh_name = res["arch"], res["shape"], res["mesh"]
    if res["status"] == "ok":
        rl, mem = res["roofline"], res["memory"]
        print(f"[ok {dt:6.1f}s] {arch} x {shape} x {mesh_name}: "
              f"compute {rl['t_compute']*1e3:.1f}ms "
              f"memory {rl['t_memory']*1e3:.1f}ms "
              f"coll {rl['t_collective']*1e3:.1f}ms "
              f"-> {rl['bottleneck']}; "
              f"peak~{mem['peak_bf16adj_gb']:.2f}GB/dev "
              f"fits={mem['fits_16g']}", flush=True)
    elif res["status"] == "skipped":
        print(f"[skip   ] {arch} x {shape} x {mesh_name}: {res['notes'][0]}",
              flush=True)
    else:
        print(f"[ERROR {dt:5.1f}s] {arch} x {shape} x {mesh_name}:\n"
              f"{res['error']}", flush=True)


def run_one(arch: str, shape: str, multi_pod: bool, out_dir: str,
            force: bool = False, keep_hlo: bool = False) -> dict:
    from repro.launch import dryrun_lib
    from repro.launch.mesh import make_production_mesh
    mesh_name = "2x16x16" if multi_pod else "16x16"
    path = dryrun_lib.result_path(out_dir, arch, shape, mesh_name)
    if not force and os.path.exists(path):
        with open(path) as f:
            cached = json.load(f)
        if cached.get("status") in ("ok", "skipped"):
            print(f"[cached ] {arch} x {shape} x {mesh_name}: "
                  f"{cached['status']}", flush=True)
            return cached
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    res = dryrun_lib.run_cell(
        arch, shape, mesh, mesh_name,
        keep_hlo_dir=os.path.join(out_dir, "hlo") if keep_hlo else None,
        dump_dir=_DUMP_DIR)
    dt = time.perf_counter() - t0
    dryrun_lib.save_result(res, out_dir)
    _print_result(res.to_dict(), dt)
    return res.to_dict()


def run_all_subprocess(out_dir: str, force: bool, keep_hlo: bool,
                       timeout_s: int = 3000) -> int:
    """One subprocess per cell (isolation + fresh dump dir + fresh XLA)."""
    from repro.config import SHAPES, list_archs
    archs = tuple(a for a in list_archs() if a != "recllm-base")
    failures = 0
    for arch in archs:
        for shape in SHAPES:
            for flag in ([], ["--multi-pod"]):
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", out_dir] \
                    + flag + (["--force"] if force else []) \
                    + (["--keep-hlo"] if keep_hlo else [])
                env = dict(os.environ)
                env.pop("REPRO_DUMP_DIR", None)
                env.pop("XLA_FLAGS", None)
                try:
                    p = subprocess.run(cmd, env=env, timeout=timeout_s,
                                       cwd=os.getcwd())
                    failures += p.returncode != 0
                except subprocess.TimeoutExpired:
                    print(f"[TIMEOUT] {arch} x {shape} "
                          f"{'multi' if flag else 'single'}-pod", flush=True)
                    failures += 1
    return failures


def main(argv=None) -> int:
    from repro.config import SHAPES, list_archs
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=tuple(list_archs()))
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape x mesh) cell, subprocess each")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args(argv)

    if args.all:
        failures = run_all_subprocess(args.out, args.force, args.keep_hlo)
        print(f"done; {failures} failures")
        return 1 if failures else 0

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required unless --all")
    res = run_one(args.arch, args.shape, args.multi_pod, args.out,
                  force=args.force, keep_hlo=args.keep_hlo)
    return 1 if res["status"] == "error" else 0


if __name__ == "__main__":
    sys.exit(main())
