"""Dry-run machinery: build, lower and compile every (arch x shape x mesh)
cell without allocating real arrays (ShapeDtypeStruct in, compiled HLO out).

Kept separate from ``dryrun.py`` (which owns the XLA_FLAGS 512-device env
setup) so tests and benchmarks can reuse it on small host meshes.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import hlo_cost
from repro.analysis import roofline as roofline_mod
from repro.config import (ArchConfig, ParallelConfig, ShapeConfig, SHAPES,
                          cell_is_runnable, get_arch, list_archs,
                          HBM_BYTES_PER_CHIP)
from repro.core.hybrid import Plan, auto_plan
from repro.core.sharding import ShardingPlan
from repro.models import model_zoo, transformer as tf
from repro.models.transformer import ModelCtx
from repro.optimizer import adamw
from repro.runtime import trainer as trainer_mod
from repro.config import TrainConfig


def _named_tree(sh: ShardingPlan, spec_tree):
    return jax.tree.map(sh.named, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _kv_constrainer(sh: ShardingPlan):
    """Output-sharding hook for prefill KV trees."""
    M = sh.tp_axis

    def one(x):
        if not hasattr(x, "ndim"):
            return x
        if x.ndim == 5:        # (L, B, S, Hk, D)
            spec = sh.guard((None, sh.dp_axes, M, None, None), x.shape)
        elif x.ndim == 4:      # (B, S, Hk, D)
            spec = sh.guard((sh.dp_axes, M, None, None), x.shape)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, sh.named(spec))

    return lambda tree: jax.tree.map(one, tree)


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               pcfg: ParallelConfig = ParallelConfig(),
               tcfg: TrainConfig = TrainConfig(),
               plan: Optional[Plan] = None):
    """Returns (lower_fn) -> lowered; deferred so callers control timing."""
    plan = plan or auto_plan(cfg, mesh, shape, pcfg)
    sh = plan.sharding
    ctx = ModelCtx(remat=plan.remat, constrain=sh.constrain)
    bundle = model_zoo.build(cfg, ctx)

    params_shape = bundle.init_eval_shape()
    param_specs = sh.param_specs(cfg, params_shape)
    param_sh = _named_tree(sh, param_specs)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw.init_opt_state, params_shape)
        step, jitted, shardings_for = trainer_mod.make_hybrid_train_step(
            cfg, plan, tcfg)
        batch_shape = model_zoo.batch_specs(cfg, shape)
        psh, osh, bsh = shardings_for(params_shape, batch_shape)

        def lower():
            return jax.jit(step, in_shardings=(psh, osh, bsh),
                           out_shardings=(psh, osh, None),
                           donate_argnums=(0, 1)).lower(
                               params_shape, opt_shape, batch_shape)
        return lower, plan

    if shape.kind == "prefill":
        batch_shape = model_zoo.batch_specs(cfg, shape)
        bsh = _named_tree(sh, sh.batch_specs(batch_shape))
        kv_con = _kv_constrainer(sh)

        def prefill_fn(params, batch):
            logits, kvs = bundle.prefill(params, batch)
            return logits, kv_con(kvs)

        def lower():
            return jax.jit(prefill_fn, in_shardings=(param_sh, bsh)).lower(
                params_shape, batch_shape)
        return lower, plan

    # decode
    specs = model_zoo.decode_specs(cfg, shape)
    cache_shape = specs["cache"]
    cache_sh = _named_tree(sh, sh.cache_specs(cfg, cache_shape))
    tok_sh = sh.named(sh.guard((sh.dp_axes, None),
                               specs["tokens"].shape))
    has_pos = "positions" in specs

    def decode_fn(params, cache, tokens, positions=None):
        return bundle.decode(params, cache, tokens, positions=positions)

    def lower():
        if has_pos:
            pos_sh = sh.named(sh.guard((sh.dp_axes, None, None),
                                       specs["positions"].shape))
            return jax.jit(
                decode_fn, in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,)).lower(
                    params_shape, cache_shape, specs["tokens"],
                    specs["positions"])
        return jax.jit(
            decode_fn, in_shardings=(param_sh, cache_sh, tok_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,)).lower(
                params_shape, cache_shape, specs["tokens"])
    return lower, plan


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str                      # ok | skipped | error
    lower_s: float = 0.0
    compile_s: float = 0.0
    roofline: Optional[Dict] = None
    memory: Optional[Dict] = None
    error: Optional[str] = None
    notes: Tuple[str, ...] = ()

    def to_dict(self):
        return dataclasses.asdict(self)


def _find_dump(dump_dir: Optional[str], fn_name: str) -> Optional[str]:
    """Newest post-SPMD dump (before all-reduce promotion / bf16
    normalization — CPU-only passes that TPU would not run)."""
    if not dump_dir:
        return None
    for stage in ("before_all-reduce-promotion",
                  "before_float-normalization-bf16"):
        pat = os.path.join(dump_dir, f"*jit_{fn_name}*{stage}.txt")
        files = sorted(glob.glob(pat), key=os.path.getmtime)
        if files:
            return files[-1]
    return None


_KIND_FN = {"train": "step", "prefill": "prefill_fn", "decode": "decode_fn"}


def run_cell(arch: str, shape_name: str, mesh: Mesh, mesh_name: str,
             pcfg: ParallelConfig = ParallelConfig(),
             keep_hlo_dir: Optional[str] = None,
             dump_dir: Optional[str] = None) -> CellResult:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if not cell_is_runnable(arch, shape_name):
        return CellResult(arch, shape_name, mesh_name, "skipped",
                          notes=("long_500k requires sub-quadratic attention "
                                 "(DESIGN.md §5)",))
    try:
        lower_fn, plan = build_cell(cfg, shape, mesh, pcfg)
        t0 = time.perf_counter()
        lowered = lower_fn()
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        dump = _find_dump(dump_dir, _KIND_FN[shape.kind])
        if dump:
            with open(dump) as f:
                hlo = f.read()
            hlo_src = "pre-normalization-dump"
        else:                       # fallback: f32-promoted compiled module
            hlo = compiled.as_text()
            hlo_src = "compiled-module"
        costs = hlo_cost.analyze(hlo, mesh.size)
        rl = roofline_mod.from_costs(cfg, shape, mesh_name, mesh.size,
                                     costs, compiled.memory_analysis())
        ma = compiled.memory_analysis()
        mem = {"argument_gb": ma.argument_size_in_bytes / 1e9,
               "output_gb": ma.output_size_in_bytes / 1e9,
               "temp_gb": ma.temp_size_in_bytes / 1e9,
               "alias_gb": ma.alias_size_in_bytes / 1e9,
               "peak_est_gb": (ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes) / 1e9,
               # CPU backend promotes bf16 temps to f32; TPU temps are
               # roughly half (args/outputs keep their true dtypes)
               "peak_bf16adj_gb": (ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes / 2
                                   - ma.alias_size_in_bytes) / 1e9,
               "fits_16g": (ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes / 2
                            - ma.alias_size_in_bytes)
               < HBM_BYTES_PER_CHIP}
        if keep_hlo_dir:
            os.makedirs(keep_hlo_dir, exist_ok=True)
            with open(os.path.join(
                    keep_hlo_dir,
                    f"{arch}_{shape_name}_{mesh_name}.hlo.txt"), "w") as f:
                f.write(hlo)
        return CellResult(arch, shape_name, mesh_name, "ok",
                          lower_s=t1 - t0, compile_s=t2 - t1,
                          roofline=rl.to_dict(), memory=mem,
                          notes=plan.notes + (f"hlo:{hlo_src}",))
    except Exception as e:  # noqa: BLE001 — cell isolation by design
        return CellResult(arch, shape_name, mesh_name, "error",
                          error=f"{type(e).__name__}: {e}\n"
                                f"{traceback.format_exc(limit=8)}")


def result_path(out_dir: str, arch: str, shape: str, mesh_name: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")


def save_result(res: CellResult, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    p = result_path(out_dir, res.arch, res.shape, res.mesh)
    with open(p, "w") as f:
        json.dump(res.to_dict(), f, indent=1)
    return p
