"""Pallas TPU kernel: fused AdamW update.

Reads p, g, m, v once from HBM and writes p', m', v' once — 7 streams total
versus ~12+ for the unfused elementwise graph, a pure memory-roofline win.
Traced hyperparameters (lr schedule, bias corrections) arrive as a (1, 8)
f32 operand pinned to block (0, 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hyper_ref, p_ref, g_ref, m_ref, v_ref, p_out, m_out, v_out):
    h = hyper_ref[0]
    lr, b1, b2, eps, wd, bc1, bc2 = h[0], h[1], h[2], h[3], h[4], h[5], h[6]
    p = p_ref[...]
    g = g_ref[...]
    m = b1 * m_ref[...] + (1 - b1) * g
    v = b2 * v_ref[...] + (1 - b2) * g * g
    mh = m / bc1
    vh = v / bc2
    p_out[...] = p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)
    m_out[...] = m
    v_out[...] = v


def adamw_update(p, g, m, v, *, lr, b1, b2, eps, wd, bc1, bc2,
                 block: int = 2048, interpret=False):
    """All of p, g, m, v: flat (N,) f32 (N % 8 == 0).  Returns (p', m', v')."""
    N = p.shape[0]
    rows = 8
    M = N // rows
    block = min(block, M)
    assert M % block == 0, (N, block)
    nb = M // block
    hyper = jnp.stack([jnp.asarray(x, jnp.float32)
                       for x in (lr, b1, b2, eps, wd, bc1, bc2, 0.0)])[None]

    def spec():
        return pl.BlockSpec((rows, block), lambda i: (0, i))

    args = [x.reshape(rows, M) for x in (p, g, m, v)]
    p1, m1, v1 = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, 8), lambda i: (0, 0)),
                  spec(), spec(), spec(), spec()],
        out_specs=[spec(), spec(), spec()],
        out_shape=[jax.ShapeDtypeStruct((rows, M), jnp.float32)] * 3,
        interpret=interpret,
    )(hyper, *args)
    return p1.reshape(N), m1.reshape(N), v1.reshape(N)
