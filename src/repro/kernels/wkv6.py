"""Pallas TPU kernel: chunked WKV6 (rwkv6 time-mix recurrence).

The §Perf H1 hillclimb showed the WKV state scan is the SSM family's
hot-spot; this kernel keeps the (hs, hs) state AND the (C, C, hs) intra-
chunk decay tensor in VMEM across the chunk loop — HBM traffic is just the
r/k/v/w streams and one output write.  All decay exponents are <= 0 (exact,
no overflow; see models/ssm._wkv6_chunked for the math).

Grid: (B, H, T/C) with the chunk axis "arbitrary" (sequential) carrying the
state in VMEM scratch.  Tiles: (C, hs) streams, C=32..128, hs=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

_CompilerParams = compat.pallas_compiler_params()


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk: int,
            hs: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)              # (C, hs)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                 # (hs,)

    lw = jnp.log(jnp.maximum(w, 1e-30))
    cum = jnp.cumsum(lw, axis=0)                     # (C, hs), <= 0
    cum_prev = cum - lw
    # intra-chunk decay tensor, strictly causal (s < t): VMEM-resident
    expo = cum_prev[:, None, :] - cum[None, :, :]    # (C, C, hs)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    d = jnp.where(tri[:, :, None], jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
    m = jnp.sum(r[:, None, :] * d * k[None, :, :], axis=-1)   # (C, C)
    o = jax.lax.dot_general(m, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # cross-chunk state contribution
    o += jax.lax.dot_general(r * jnp.exp(cum_prev), s_scr[...],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # bonus (current token)
    o += jnp.sum(r * k * u[None, :], axis=-1, keepdims=True) * v
    # state update: S' = diag(exp(cum_C)) S + (k * exp(cum_C - cum))^T v
    cum_c = cum[-1]                                  # (hs,)
    k2 = k * jnp.exp(cum_c[None, :] - cum)
    s_scr[...] = (jnp.exp(cum_c)[:, None] * s_scr[...]
                  + jax.lax.dot_general(k2, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    o_ref[0, 0] = o.astype(o_ref.dtype)


def wkv6_chunked(r, k, v, w, u, *, chunk: int = 32, interpret=False):
    """r,k,v,w: (B, H, T, hs); w decay in (0,1); u: (H, hs) -> (B, H, T, hs).

    Zero initial state (prefill/train); T % chunk == 0.
    """
    B, H, T, hs = r.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    grid = (B, H, nc)
    kernel = functools.partial(_kernel, chunk=chunk, hs=hs, n_chunks=nc)

    def spec():
        return pl.BlockSpec((1, 1, chunk, hs), lambda b, h, c: (b, h, c, 0))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec(), spec(), spec(), spec(),
                  pl.BlockSpec((1, hs), lambda b, h, c: (h, 0))],
        out_specs=spec(),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hs), r.dtype),
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
    return out
