"""Pallas TPU flash attention: blocked online-softmax with causal /
sliding-window masking and GQA (grouped KV heads indexed in the BlockSpec
index maps — repeated KV is never materialized).

Layout: q (B, H, Sq, D); k, v (B, Hk, Sk, D).  Grid is
(B, H, Sq/bq, Sk/bk) with the KV axis as the innermost "arbitrary"
dimension; running max / sum / accumulator live in VMEM scratch across KV
iterations.  Tiles: bq x D and bk x D (D = head_dim, 64..256 — MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

_CompilerParams = compat.pallas_compiler_params()

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, kv_len: int,
            block_q: int, block_k: int, n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    pos_q = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    pos_k = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = pos_k < kv_len
    if causal:
        mask &= pos_k <= pos_q
    if window > 0:
        mask &= pos_k > pos_q - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]                                   # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[:, 0] * corr + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)                    # (bk, D)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softmax_scale=None,
                    block_q=128, block_k=128, interpret=False):
    B, H, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    G = H // Hk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    kv_len = Sk

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    n_q, n_kv = Sq_p // block_q, Sk_p // block_k

    grid = (B, H, n_q, n_kv)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, kv_len=kv_len,
        block_q=block_q, block_k=block_k, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :Sq]
    return out
