"""Pallas TPU kernel: fused MoE router — softmax + iterative top-k with
first-occurrence tie-break (paper §III.A.c).  One pass over the (tokens x
experts) logits block; E <= 128 fits a single lane tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, gates_ref, idx_ref, probs_ref, *, k: int, E: int):
    x = logits_ref[...].astype(jnp.float32)                 # (bt, E)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    probs_ref[...] = probs

    bt = x.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    tmp = probs
    gsum = jnp.zeros((bt,), jnp.float32)
    gates = []
    for j in range(k):
        mj = jnp.max(tmp, axis=-1)                          # (bt,)
        is_max = tmp == mj[:, None]
        idxj = jnp.min(jnp.where(is_max, iota, E), axis=-1)
        idx_ref[:, j] = idxj
        gates.append(mj)
        gsum = gsum + mj
        tmp = jnp.where(iota == idxj[:, None], -jnp.inf, tmp)
    gsum = jnp.maximum(gsum, 1e-9)
    for j in range(k):
        gates_ref[:, j] = gates[j] / gsum


def moe_router(logits: jnp.ndarray, k: int, block_t: int = 1024,
               interpret=False):
    """logits (T, E) -> (gates (T,k), idx (T,k) i32, probs (T,E))."""
    T, E = logits.shape
    block_t = min(block_t, T)
    pad = (-T) % block_t
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
    Tp = T + pad
    nb = Tp // block_t
    gates, idx, probs = pl.pallas_call(
        functools.partial(_kernel, k=k, E=E),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_t, E), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_t, k), lambda i: (i, 0)),
                   pl.BlockSpec((block_t, k), lambda i: (i, 0)),
                   pl.BlockSpec((block_t, E), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Tp, k), jnp.float32),
                   jax.ShapeDtypeStruct((Tp, k), jnp.int32),
                   jax.ShapeDtypeStruct((Tp, E), jnp.float32)],
        interpret=interpret,
    )(logits)
    return gates[:T], idx[:T], probs[:T]
