"""Pallas TPU kernel for block-local top-k gradient sparsification with
error-feedback residual (paper Eq. 11).

Semantics (shared with ``ref.topk_sparsify``): within each block keep every
element with |x| >= t where t is the k-th largest magnitude (ties included);
residual = x - kept.  The k-th magnitude is found by k iterations of
max-and-mask on the VPU — k is small (<= 64) in practice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, kept_ref, resid_ref, *, k: int):
    x = x_ref[...]                                          # (1, block)
    a = jnp.abs(x)

    def body(_, carry):
        tmp, thr = carry
        m = jnp.max(tmp)
        tmp = jnp.where(tmp >= m, -1.0, tmp)
        return tmp, m

    _, t = jax.lax.fori_loop(0, k, body, (a, jnp.float32(jnp.inf)))
    kept = jnp.where(a >= t, x, 0.0)
    kept_ref[...] = kept
    resid_ref[...] = x - kept


def topk_sparsify(x2d: jnp.ndarray, k: int, interpret=False):
    """x2d: (nb, block) f32 -> (kept, residual) same shape."""
    nb, block = x2d.shape
    kept, resid = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, block), lambda i: (i, 0)),
                   pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.float32),
                   jax.ShapeDtypeStruct((nb, block), jnp.float32)],
        interpret=interpret,
    )(x2d)
    return kept, resid
