"""Pallas TPU kernels for 1-bit (EF-signSGD) gradient compression — paper
Eq. 10.  Bit packing is expressed as an 8-sublane weighted reduction so it
vectorizes on the VPU (the TPU analogue of a CUDA warp-ballot pack).

Layout contract (matches ``ref.onebit_quantize``): the flat gradient of size
N (N % 8 == 0) is viewed as (8, M) with M = N // 8; ``packed[j]`` holds the 8
sign bits of column j; one f32 L1 scale per ``block`` columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(g_ref, packed_ref, scale_ref, *, block: int):
    g = g_ref[...]                                         # (8, block) f32
    bits = (g >= 0).astype(jnp.int32)
    w = jax.lax.broadcasted_iota(jnp.int32, (8, block), 0)
    weights = jnp.left_shift(jnp.ones_like(w), w)          # 2^row
    packed = jnp.sum(bits * weights, axis=0)               # (block,) int32
    packed_ref[...] = packed[None, :].astype(jnp.uint8)
    scale_ref[0, 0] = jnp.mean(jnp.abs(g))


def _dequant_kernel(packed_ref, scale_ref, g_ref, *, block: int):
    packed = packed_ref[...].astype(jnp.int32)             # (1, block)
    j = jax.lax.broadcasted_iota(jnp.int32, (8, block), 0)
    bits = jnp.right_shift(jnp.broadcast_to(packed, (8, block)), j) & 1
    signs = 2.0 * bits.astype(jnp.float32) - 1.0
    g_ref[...] = signs * scale_ref[0, 0]


def onebit_quantize(g2d: jnp.ndarray, block: int = 512, interpret=False):
    """g2d: (8, M) f32 -> (packed (M,) uint8, scales (M/block,) f32)."""
    _, M = g2d.shape
    assert M % block == 0, (M, block)
    nb = M // block
    packed, scales = pl.pallas_call(
        functools.partial(_quant_kernel, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((8, block), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, i), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, M), jnp.uint8),
            jax.ShapeDtypeStruct((1, nb), jnp.float32),
        ],
        interpret=interpret,
    )(g2d)
    return packed[0], scales[0]


def onebit_dequantize(packed: jnp.ndarray, scales: jnp.ndarray,
                      block: int = 512, interpret=False):
    """packed (M,) uint8, scales (M/block,) -> (8, M) f32."""
    M = packed.shape[0]
    nb = M // block
    g = pl.pallas_call(
        functools.partial(_dequant_kernel, block=block),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, i), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((8, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, M), jnp.float32),
        interpret=interpret,
    )(packed[None, :], scales[None, :])
    return g
