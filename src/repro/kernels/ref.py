"""Pure-jnp oracles for every Pallas kernel.  Tests assert_allclose the
kernel (interpret=True on CPU) against these.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# flash attention (layout: q (B,H,Sq,D); k,v (B,Hk,Sk,D))
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=0, softmax_scale=None,
                    kv_len=None):
    B, H, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    G = H // Hk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    pos_q = jnp.arange(Sq)[:, None]
    pos_k = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= pos_k <= pos_q
    if window > 0:
        m &= pos_k > pos_q - window
    if kv_len is not None:
        m &= pos_k < kv_len
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# flash-decode attention (layout: q (B,Sq,H,D); caches (B,S,Hk,D)) — the
# length-skipping oracle: per-slot live prefixes, sliding-window band or
# gemma ring wraparound masking, int8 per-(position, head) scales.  Empty
# slots (len == 0) are defined to produce exactly-zero outputs.
#
# Speculative decode generalizes Sq from 1 to k draft rows: ``lengths``
# keeps its single-step meaning (row 0's attendable length = cache_len + 1,
# the row's own freshly written position included), and draft row ``j``
# attends with effective length ``lengths + j`` — cache plus draft rows
# ``< j`` plus itself, the causal intra-draft mask.  ``q_lens`` (B,) caps
# the live rows per slot; rows ``>= q_lens`` are defined to produce
# exactly-zero outputs (they are padding in a ragged speculative batch).
# ---------------------------------------------------------------------------

def _decode_mask(lengths, S: int, window: int, ring: bool):
    """(B, S) bool: which cache rows a slot's single query may attend."""
    pos = jnp.arange(S)[None, :]
    lengths = lengths[:, None]
    if ring and window > 0:
        valid = pos < jnp.minimum(lengths, S)
        valid &= jnp.mod(lengths - 1 - pos, S) < window
    else:
        valid = pos < lengths
        if window > 0:
            valid &= pos > lengths - 1 - window
    return valid


def _decode_mask_rows(lengths, q_lens, Sq: int, S: int, window: int,
                      ring: bool):
    """(B, Sq, S) bool: rows draft row ``j`` of each slot may attend.

    Row ``j``'s effective length is ``lengths + j``; rows ``>= q_lens``
    (speculation padding) attend nothing."""
    pos = jnp.arange(S)[None, None, :]
    eff = (lengths[:, None] + jnp.arange(Sq)[None, :])[:, :, None]
    if ring and window > 0:
        valid = pos < jnp.minimum(eff, S)
        valid &= jnp.mod(eff - 1 - pos, S) < window
    else:
        valid = pos < eff
        if window > 0:
            valid &= pos > eff - 1 - window
    valid &= (jnp.arange(Sq)[None, :] < q_lens[:, None])[:, :, None]
    return valid


def decode_attention(q, k, v, lengths, *, window=0, ring=False,
                     softmax_scale=None, q_lens=None):
    B, Sq, H, D = q.shape
    _, S, Hk, _ = k.shape
    G = H // Hk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if q_lens is None:
        q_lens = jnp.full((B,), Sq, jnp.int32)
    qg = q.reshape(B, Sq, Hk, G, D).astype(jnp.float32)
    s = jnp.einsum("bjhgd,bkhd->bhjgk", qg, k.astype(jnp.float32)) * scale
    valid = _decode_mask_rows(lengths, q_lens, Sq, S, window, ring)
    s = jnp.where(valid[:, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, :, None, :], p, 0.0)        # len==0 -> 0
    out = jnp.einsum("bhjgk,bkhd->bjhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_quant(q, k_q, k_s, v_q, v_s, lengths, *,
                           softmax_scale=None, q_lens=None):
    B, Sq, H, D = q.shape
    _, S, Hk, _ = k_q.shape
    G = H // Hk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if q_lens is None:
        q_lens = jnp.full((B,), Sq, jnp.int32)
    qg = q.reshape(B, Sq, Hk, G, D).astype(jnp.float32)
    s = jnp.einsum("bjhgd,bkhd->bhjgk", qg, k_q.astype(jnp.float32))
    s = s * k_s.transpose(0, 2, 1)[:, :, None, None, :] * scale
    valid = _decode_mask_rows(lengths, q_lens, Sq, S, 0, False)
    s = jnp.where(valid[:, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, :, None, :], p, 0.0)
    pv = jnp.einsum("bhjgk,bkhd->bjhgd",
                    p * v_s.transpose(0, 2, 1)[:, :, None, None, :],
                    v_q.astype(jnp.float32))
    return pv.reshape(B, Sq, H, D).astype(q.dtype)


def paged_gather(pool, table):
    """Gather a slot-contiguous view out of a shared block pool.

    pool (N, bs, ...) + table (B, nb) int32 -> (B, nb*bs, ...): the dense-
    layout cache the paged layout virtualizes (dead entries gather the null
    block's rows, which every consumer masks by length)."""
    B, nb = table.shape
    bs = pool.shape[1]
    return pool[table.reshape(-1)].reshape((B, nb * bs) + pool.shape[2:])


def decode_attention_paged(q, k_pool, v_pool, block_tables, lengths, *,
                           window=0, ring=False, softmax_scale=None,
                           q_lens=None):
    """Paged oracle: gather pool blocks into the dense layout, then attend."""
    return decode_attention(q, paged_gather(k_pool, block_tables),
                            paged_gather(v_pool, block_tables), lengths,
                            window=window, ring=ring,
                            softmax_scale=softmax_scale, q_lens=q_lens)


def decode_attention_paged_quant(q, k_q_pool, k_s_pool, v_q_pool, v_s_pool,
                                 block_tables, lengths, *,
                                 softmax_scale=None, q_lens=None):
    return decode_attention_quant(
        q, paged_gather(k_q_pool, block_tables),
        paged_gather(k_s_pool, block_tables),
        paged_gather(v_q_pool, block_tables),
        paged_gather(v_s_pool, block_tables), lengths,
        softmax_scale=softmax_scale, q_lens=q_lens)


# ---------------------------------------------------------------------------
# MoE router: softmax + top-k (first-occurrence argmax tie-break)
# ---------------------------------------------------------------------------

def moe_router(logits, k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    T, E = probs.shape
    tmp = probs
    gates, idxs = [], []
    iota = jnp.arange(E)
    for _ in range(k):
        m = jnp.max(tmp, axis=-1)
        is_max = tmp == m[:, None]
        idx = jnp.min(jnp.where(is_max, iota, E), axis=-1)
        gates.append(m)
        idxs.append(idx)
        tmp = jnp.where(iota[None] == idx[:, None], -jnp.inf, tmp)
    gates = jnp.stack(gates, -1)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, jnp.stack(idxs, -1).astype(jnp.int32), probs


# ---------------------------------------------------------------------------
# 1-bit gradient compression (paper Eq. 10): sign pack + per-block L1 scale
# layout: g viewed as (8, N/8); packed (N/8,) uint8; one scale per block col-
# chunk of size ``block`` (so scales has N/8/block entries).
# ---------------------------------------------------------------------------

def onebit_quantize(g2d, block: int):
    """g2d: (8, M) f32 -> (packed (M,) uint8, scales (M/block,) f32)."""
    _, M = g2d.shape
    assert M % block == 0
    bits = (g2d >= 0).astype(jnp.int32)                      # (8, M)
    weights = (2 ** jnp.arange(8, dtype=jnp.int32))[:, None]
    packed = jnp.sum(bits * weights, axis=0).astype(jnp.uint8)
    scales = jnp.mean(jnp.abs(g2d.reshape(8, M // block, block)),
                      axis=(0, 2)).astype(jnp.float32)
    return packed, scales


def onebit_dequantize(packed, scales, block: int):
    """packed (M,) uint8, scales (M/block,) -> (8, M) f32 approx gradient."""
    M = packed.shape[0]
    j = jnp.arange(8, dtype=jnp.int32)[:, None]
    bits = (packed.astype(jnp.int32)[None, :] >> j) & 1      # (8, M)
    signs = 2.0 * bits.astype(jnp.float32) - 1.0
    s = jnp.repeat(scales, block)[None, :]
    return signs * s


# ---------------------------------------------------------------------------
# block-local top-k sparsification (paper Eq. 11 semantics: keep |x| >= t,
# t = k-th largest |x| in the block, ties included; residual = x - kept)
# ---------------------------------------------------------------------------

def topk_sparsify(x2d, k: int):
    """x2d: (nb, block) -> (kept, residual), same shapes."""
    a = jnp.abs(x2d)
    t = jnp.sort(a, axis=-1)[:, -k][:, None]
    kept = jnp.where(a >= t, x2d, 0.0)
    return kept, x2d - kept


# ---------------------------------------------------------------------------
# embedding gather / segment-sum scatter-add (the dedup-lookup pair)
# ---------------------------------------------------------------------------

def gather_rows(table, ids):
    """table (V, D), ids (n,) -> (n, D) = table[ids]."""
    return table[ids]


def scatter_add_rows(x, idx, n_rows: int):
    """x (n, D), idx (n,) -> (n_rows, D) with out[idx[i]] += x[i]."""
    return jnp.zeros((n_rows, x.shape[-1]), x.dtype).at[idx].add(x)


# ---------------------------------------------------------------------------
# fused AdamW update
# ---------------------------------------------------------------------------

def adamw_update(p, g, m, v, *, lr, b1, b2, eps, wd, bc1, bc2):
    """bc1/bc2 are bias corrections 1-b^t (precomputed)."""
    m1 = b1 * m + (1 - b1) * g
    v1 = b2 * v + (1 - b2) * jnp.square(g)
    mh = m1 / bc1
    vh = v1 / bc2
    p1 = p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)
    return p1, m1, v1


# ---------------------------------------------------------------------------
# chunked WKV6 oracle: sequential recurrence (layout (B, H, T, hs))
# ---------------------------------------------------------------------------

def wkv6_chunked(r, k, v, w, u):
    """S_t = diag(w_t) S + k_t v_t^T ; o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)."""
    B, H, T, hs = r.shape

    def step(S, inp):
        rt, kt, vt, wt = inp                         # (B,H,hs)
        kv = kt[..., :, None] * vt[..., None, :]
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + u[..., None] * kv)
        S = wt[..., None] * S + kv
        return S, o

    S0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    xs = tuple(t.transpose(2, 0, 1, 3).astype(jnp.float32)
               for t in (r, k, v, w))
    _, out = jax.lax.scan(step, S0, xs)
    return out.transpose(1, 2, 0, 3).astype(r.dtype)
