"""Pallas TPU flash-decode attention: the serving engine's per-step hot path.

One decode step attends a single query token per sequence against that
sequence's resident KV cache.  The dense XLA path streams the **entire
padded** cache ``(B, S_max, Hk, D)`` every step; this kernel streams only
the live prefix.  Grid is ``(B, Hk, S/block_k)`` with the KV axis innermost
("arbitrary"); the per-slot ``lengths`` vector is **scalar-prefetched** so

* the KV BlockSpec index maps clamp every out-of-range block index onto the
  last live block — consecutive grid steps that map to the same block are
  not re-fetched, so the HBM traffic for a slot is ``ceil(len/block_k)``
  blocks instead of ``S_max/block_k`` (the O(B*S_max) -> O(B*len) claim);
* a ``pl.when`` guard skips the online-softmax update for dead blocks, so
  the clamped (re-visited) block is never double-counted.

GQA: q is reshaped to ``(B, Hk, G, D)`` and each grid cell computes all G
query heads of one KV head against one KV block — repeated KV heads are
never materialized.  Running max / sum / accumulator live in VMEM scratch
across KV iterations (same online-softmax recurrence as the prefill flash
kernel in :mod:`repro.kernels.flash_attention`).

Three fused variants share the one kernel body:

* **full** (``window=0``) — mask ``pos < len``; blocks past the length are
  skipped.
* **sliding window** (``window>0, ring=False``) — linear cache, band mask
  ``len-window <= pos < len``; blocks are skipped from *both* ends.
* **ring** (``window>0, ring=True``) — gemma's sliding-window ring buffer:
  row ``r`` holds the latest absolute position ``p < len`` with
  ``p % S == r``, so the valid band *wraps*: a row is attendable iff
  ``r < min(len, S)`` and ``(len-1-r) mod S < window``.  With
  ``window == S`` (the layout :func:`repro.models.transformer.init_cache`
  builds) the wrap band covers every written row and the mask reduces to
  the length clamp — but the kernel handles ``window < S`` exactly.

* **int8** (:func:`flash_decode_attention_quant`) — the cache is int8
  values + per-(position, head) f32 scales; tiles are dequantized *inside*
  the kernel (scores fold ``k_s`` after the matmul, ``v_s`` folds into the
  probabilities before the PV matmul), so the quantized path attends
  without ever materializing a bf16 cache.

**Paged** variants (:func:`flash_decode_attention_paged`,
:func:`flash_decode_attention_paged_quant`) read the same kernel body
against a *shared block pool* ``(num_blocks, block_size, Hk, D)`` plus a
per-slot block table ``(B, blocks_per_slot)``: the block table is scalar-
prefetched alongside ``lengths`` and the KV BlockSpec index map becomes a
table lookup — grid step ``ki`` of slot ``b`` fetches physical block
``tables[b, ki]`` instead of contiguous row-block ``ki``.  Virtual
positions are still ``ki * block_size + iota``, so the full / window / ring
masks and the length-skipping clamp are identical to the dense-layout
kernel; only *where a block's rows live* changes.  Dead table entries point
at the reserved null block 0 and are never touched (the clamp keeps ``ki``
inside the live range).

Empty slots (``len == 0``) produce exactly-zero outputs in every variant —
the semantics the pure-jnp oracle in :mod:`repro.kernels.ref` pins and the
dense paths in :mod:`repro.models.attention` / :mod:`repro.models.kvquant`
share.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

_CompilerParams = compat.pallas_compiler_params()

NEG_INF = -1e30
LANES = 128


def _sublanes(dtype) -> int:
    return 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8


def _live_block_bounds(length, block_k: int, S: int, window: int,
                       ring: bool):
    """(lo, hi) inclusive block-index range holding live KV positions.

    Degenerate slots (length == 0) return (0, 0): block 0 is the one block
    that gets (re-)mapped — fetched at most once — and compute is skipped.
    """
    eff = jnp.minimum(length, S) if ring else length
    hi = jnp.maximum(pl.cdiv(eff, block_k) - 1, 0)
    if window > 0 and not ring:
        lo = jnp.clip(length - window, 0, None) // block_k
        lo = jnp.minimum(lo, hi)
    else:
        lo = jnp.zeros_like(hi)
    return lo, hi


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   ring: bool, block_k: int, n_kv: int, S: int,
                   quant: bool = False, ks_ref=None, vs_ref=None):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    length = lens_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    lo, hi = _live_block_bounds(length, block_k, S, window, ring)
    live = (ki >= lo) & (ki <= hi) & (length > 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G_pad, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (block_k, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if quant:                                            # fold k scales
            s = s * ks_ref[0, 0][None, :]
        s = s * scale                                        # (G_pad, bk)

        g_pad = q.shape[0]
        pos_k = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (g_pad, block_k), 1)
        if ring and window > 0:
            mask = pos_k < jnp.minimum(length, S)
            mask &= jnp.mod(length - 1 - pos_k, S) < window
        else:
            mask = pos_k < length
            if window > 0:
                mask &= pos_k > length - 1 - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]                                 # (G_pad,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scr[:, 0] * corr + jnp.sum(p, axis=-1)
        if quant:                                            # fold v scales
            p = p * vs_ref[0, 0][None, :]
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (block_k, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_scr[:, 0], 1e-30)                  # len==0 -> 0/1
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _prep_q(q, Hk: int):
    """(B, 1, H, D) -> padded (B, Hk, G_pad, D); returns (qg, G, G_pad)."""
    B, one, H, D = q.shape
    assert one == 1, f"decode takes one query token, got Sq={one}"
    G = H // Hk
    qg = q.reshape(B, Hk, G, D)
    sub = _sublanes(q.dtype)
    G_pad = max(sub, ((G + sub - 1) // sub) * sub)
    if G_pad != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, G_pad - G), (0, 0)))
    return qg, G, G_pad


def _pad_kv_len(x, block_k: int):
    pad = (-x.shape[1]) % block_k
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x


def flash_decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                           ring: bool = False, softmax_scale=None,
                           block_k: int = 128, interpret: bool = False):
    """q (B, 1, H, D); k/v (B, S, Hk, D); lengths (B,) int32 live prefix.

    Returns (B, 1, H, D) in q.dtype.  ``window``/``ring`` select the
    masking variant (see module docstring)."""
    B, _, H, D = q.shape
    S = k_cache.shape[1]
    Hk = k_cache.shape[2]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    block_k = min(block_k, S)
    qg, G, G_pad = _prep_q(q, Hk)
    k_cache = _pad_kv_len(k_cache, block_k)
    v_cache = _pad_kv_len(v_cache, block_k)
    S_pad = k_cache.shape[1]
    n_kv = S_pad // block_k
    lengths = lengths.astype(jnp.int32)

    def kv_map(b, h, ki, lens):
        lo, hi = _live_block_bounds(lens[b], block_k, S, window, ring)
        return (b, jnp.clip(ki, lo, hi), h, 0)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, ring=ring,
        block_k=block_k, n_kv=n_kv, S=S)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hk, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G_pad, D), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G_pad, D),
                               lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G_pad, LANES), jnp.float32),
            pltpu.VMEM((G_pad, LANES), jnp.float32),
            pltpu.VMEM((G_pad, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, G_pad, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out[:, :, :G].reshape(B, 1, H, D)


def flash_decode_attention_quant(q, k_q, k_s, v_q, v_s, lengths, *,
                                 softmax_scale=None, block_k: int = 128,
                                 interpret: bool = False):
    """Int8 fused variant: k_q/v_q (B, S, Hk, D) int8; k_s/v_s (B, S, Hk)
    f32 per-(position, head) scales; attends the quantized cache directly
    (tile dequantization inside the kernel, full-cache masking only)."""
    B, _, H, D = q.shape
    S = k_q.shape[1]
    Hk = k_q.shape[2]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    block_k = min(block_k, S)
    qg, G, G_pad = _prep_q(q, Hk)
    k_q = _pad_kv_len(k_q, block_k)
    v_q = _pad_kv_len(v_q, block_k)
    # scales travel as (B, Hk, S): lane-major along the blocked axis
    k_s = _pad_kv_len(k_s, block_k).transpose(0, 2, 1)
    v_s = _pad_kv_len(v_s, block_k).transpose(0, 2, 1)
    S_pad = k_q.shape[1]
    n_kv = S_pad // block_k
    lengths = lengths.astype(jnp.int32)

    def kv_map(b, h, ki, lens):
        lo, hi = _live_block_bounds(lens[b], block_k, S, 0, False)
        return (b, jnp.clip(ki, lo, hi), h, 0)

    def scale_map(b, h, ki, lens):
        lo, hi = _live_block_bounds(lens[b], block_k, S, 0, False)
        return (b, h, jnp.clip(ki, lo, hi))

    def kernel(lens_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
               m_scr, l_scr, acc_scr):
        _decode_kernel(lens_ref, q_ref, kq_ref, vq_ref, o_ref,
                       m_scr, l_scr, acc_scr, scale=scale, window=0,
                       ring=False, block_k=block_k, n_kv=n_kv, S=S,
                       quant=True, ks_ref=ks_ref, vs_ref=vs_ref)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hk, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G_pad, D), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
            pl.BlockSpec((1, 1, block_k), scale_map),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
            pl.BlockSpec((1, 1, block_k), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G_pad, D),
                               lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G_pad, LANES), jnp.float32),
            pltpu.VMEM((G_pad, LANES), jnp.float32),
            pltpu.VMEM((G_pad, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, G_pad, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, qg, k_q, k_s, v_q, v_s)
    return out[:, :, :G].reshape(B, 1, H, D)


def flash_decode_attention_paged(q, k_pool, v_pool, block_tables, lengths, *,
                                 window: int = 0, ring: bool = False,
                                 softmax_scale=None,
                                 interpret: bool = False):
    """Paged flash decode: q (B, 1, H, D); k/v pools (N, bs, Hk, D) shared
    across slots; block_tables (B, nb) int32 physical block ids; lengths
    (B,) live virtual prefix.  The KV tile is one pool block (``block_k ==
    block_size``) and the index map dereferences the prefetched table."""
    B, _, H, D = q.shape
    N, bs, Hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    S = nb * bs                              # virtual position space
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg, G, G_pad = _prep_q(q, Hk)
    lengths = lengths.astype(jnp.int32)
    block_tables = block_tables.astype(jnp.int32)

    def kv_map(b, h, ki, lens, tables):
        lo, hi = _live_block_bounds(lens[b], bs, S, window, ring)
        return (tables[b, jnp.clip(ki, lo, hi)], 0, h, 0)

    kernel_body = functools.partial(
        _decode_kernel, scale=scale, window=window, ring=ring,
        block_k=bs, n_kv=nb, S=S)

    def kernel(lens_ref, tables_ref, q_ref, k_ref, v_ref, o_ref,
               m_scr, l_scr, acc_scr):
        kernel_body(lens_ref, q_ref, k_ref, v_ref, o_ref,
                    m_scr, l_scr, acc_scr)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G_pad, D),
                         lambda b, h, ki, lens, tables: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, bs, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G_pad, D),
                               lambda b, h, ki, lens, tables: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G_pad, LANES), jnp.float32),
            pltpu.VMEM((G_pad, LANES), jnp.float32),
            pltpu.VMEM((G_pad, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, G_pad, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, block_tables, qg, k_pool, v_pool)
    return out[:, :, :G].reshape(B, 1, H, D)


def flash_decode_attention_paged_quant(q, k_q_pool, k_s_pool, v_q_pool,
                                       v_s_pool, block_tables, lengths, *,
                                       softmax_scale=None,
                                       interpret: bool = False):
    """Paged int8 fused variant: value pools (N, bs, Hk, D) int8, scale
    pools (N, bs, Hk) f32; in-kernel tile dequant exactly as the dense-
    layout quant kernel, with the block-table index map of the paged one."""
    B, _, H, D = q.shape
    N, bs, Hk, _ = k_q_pool.shape
    nb = block_tables.shape[1]
    S = nb * bs
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg, G, G_pad = _prep_q(q, Hk)
    lengths = lengths.astype(jnp.int32)
    block_tables = block_tables.astype(jnp.int32)
    # scales travel as (N, Hk, bs): lane-major along the blocked axis
    k_s_pool = k_s_pool.transpose(0, 2, 1)
    v_s_pool = v_s_pool.transpose(0, 2, 1)

    def kv_map(b, h, ki, lens, tables):
        lo, hi = _live_block_bounds(lens[b], bs, S, 0, False)
        return (tables[b, jnp.clip(ki, lo, hi)], 0, h, 0)

    def scale_map(b, h, ki, lens, tables):
        lo, hi = _live_block_bounds(lens[b], bs, S, 0, False)
        return (tables[b, jnp.clip(ki, lo, hi)], h, 0)

    def kernel(lens_ref, tables_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
               o_ref, m_scr, l_scr, acc_scr):
        _decode_kernel(lens_ref, q_ref, kq_ref, vq_ref, o_ref,
                       m_scr, l_scr, acc_scr, scale=scale, window=0,
                       ring=False, block_k=bs, n_kv=nb, S=S,
                       quant=True, ks_ref=ks_ref, vs_ref=vs_ref)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G_pad, D),
                         lambda b, h, ki, lens, tables: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, 1, bs), scale_map),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, 1, bs), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G_pad, D),
                               lambda b, h, ki, lens, tables: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G_pad, LANES), jnp.float32),
            pltpu.VMEM((G_pad, LANES), jnp.float32),
            pltpu.VMEM((G_pad, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, G_pad, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, block_tables, qg, k_q_pool, k_s_pool, v_q_pool, v_s_pool)
    return out[:, :, :G].reshape(B, 1, H, D)
