"""Pallas TPU flash-decode attention: the serving engine's per-step hot path.

One decode step attends a single query token per sequence against that
sequence's resident KV cache.  The dense XLA path streams the **entire
padded** cache ``(B, S_max, Hk, D)`` every step; this kernel streams only
the live prefix.  Grid is ``(B, Hk, S/block_k)`` with the KV axis innermost
("arbitrary"); the per-slot ``lengths`` vector is **scalar-prefetched** so

* the KV BlockSpec index maps clamp every out-of-range block index onto the
  last live block — consecutive grid steps that map to the same block are
  not re-fetched, so the HBM traffic for a slot is ``ceil(len/block_k)``
  blocks instead of ``S_max/block_k`` (the O(B*S_max) -> O(B*len) claim);
* a ``pl.when`` guard skips the online-softmax update for dead blocks, so
  the clamped (re-visited) block is never double-counted.

GQA: q is reshaped to ``(B, Hk, G, D)`` and each grid cell computes all G
query heads of one KV head against one KV block — repeated KV heads are
never materialized.  Running max / sum / accumulator live in VMEM scratch
across KV iterations (same online-softmax recurrence as the prefill flash
kernel in :mod:`repro.kernels.flash_attention`).

Three fused variants share the one kernel body:

* **full** (``window=0``) — mask ``pos < len``; blocks past the length are
  skipped.
* **sliding window** (``window>0, ring=False``) — linear cache, band mask
  ``len-window <= pos < len``; blocks are skipped from *both* ends.
* **ring** (``window>0, ring=True``) — gemma's sliding-window ring buffer:
  row ``r`` holds the latest absolute position ``p < len`` with
  ``p % S == r``, so the valid band *wraps*: a row is attendable iff
  ``r < min(len, S)`` and ``(len-1-r) mod S < window``.  With
  ``window == S`` (the layout :func:`repro.models.transformer.init_cache`
  builds) the wrap band covers every written row and the mask reduces to
  the length clamp — but the kernel handles ``window < S`` exactly.

* **int8** (:func:`flash_decode_attention_quant`) — the cache is int8
  values + per-(position, head) f32 scales; tiles are dequantized *inside*
  the kernel (scores fold ``k_s`` after the matmul, ``v_s`` folds into the
  probabilities before the PV matmul), so the quantized path attends
  without ever materializing a bf16 cache.

**Paged** variants (:func:`flash_decode_attention_paged`,
:func:`flash_decode_attention_paged_quant`) read the same kernel body
against a *shared block pool* ``(num_blocks, block_size, Hk, D)`` plus a
per-slot block table ``(B, blocks_per_slot)``: the block table is scalar-
prefetched alongside ``lengths`` and the KV BlockSpec index map becomes a
table lookup — grid step ``ki`` of slot ``b`` fetches physical block
``tables[b, ki]`` instead of contiguous row-block ``ki``.  Virtual
positions are still ``ki * block_size + iota``, so the full / window / ring
masks and the length-skipping clamp are identical to the dense-layout
kernel; only *where a block's rows live* changes.  Dead table entries point
at the reserved null block 0 and are never touched (the clamp keeps ``ki``
inside the live range).

**Speculative multi-token verification** generalizes every variant from one
query row to ``Sq = k`` draft rows per slot, folded into the kernel's row
axis: q ``(B, Sq, H, D)`` becomes ``(B, Hk, Sq*G_pad, D)`` so draft row
``j`` of KV head ``h`` occupies kernel rows ``[j*G_pad, (j+1)*G_pad)`` and
one grid cell still computes every row of one KV head against one KV
block.  A second scalar-prefetched vector ``q_lens`` (B,) carries the live
draft length per slot — speculation is ragged under continuous batching —
and the in-kernel masks become per-row: row ``j`` attends with *effective
length* ``lengths + j`` (the committed cache, draft rows ``< j``, and its
own freshly written position — the causal intra-draft mask), while rows
``>= q_lens`` attend nothing and produce exactly-zero outputs.  The live-
block clamp extends to ``lengths + q_lens - 1``, so a slot still fetches
only ``ceil((len+k)/block_k)`` blocks.  With ``Sq == 1`` the row index is
identically zero and every variant reduces bit-for-bit to the single-step
kernel above.

Empty slots (``len == 0``) produce exactly-zero outputs in every variant —
the semantics the pure-jnp oracle in :mod:`repro.kernels.ref` pins and the
dense paths in :mod:`repro.models.attention` / :mod:`repro.models.kvquant`
share.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

_CompilerParams = compat.pallas_compiler_params()

NEG_INF = -1e30
LANES = 128


def _sublanes(dtype) -> int:
    return 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8


def _live_block_bounds(length, block_k: int, S: int, window: int,
                       ring: bool, q_len=None):
    """(lo, hi) inclusive block-index range holding live KV positions.

    With ``q_len`` draft rows the last live position is row ``q_len-1``'s
    effective length ``length + q_len - 1``; ``q_len=None`` is the
    single-row decode (identical to ``q_len == 1``).  Degenerate slots
    (no attendable position) return (0, 0): block 0 is the one block that
    gets (re-)mapped — fetched at most once — and compute is skipped.
    """
    last = length if q_len is None else length + q_len - 1
    eff = jnp.minimum(last, S) if ring else last
    hi = jnp.maximum(pl.cdiv(eff, block_k) - 1, 0)
    if window > 0 and not ring:
        # row 0's band starts lowest: pos > length - 1 - window
        lo = jnp.clip(length - window, 0, None) // block_k
        lo = jnp.minimum(lo, hi)
    else:
        lo = jnp.zeros_like(hi)
    return lo, hi


def _decode_kernel(lens_ref, qlens_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   ring: bool, block_k: int, n_kv: int, S: int, g_pad: int,
                   quant: bool = False, ks_ref=None, vs_ref=None):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    length = lens_ref[b]
    q_len = qlens_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    lo, hi = _live_block_bounds(length, block_k, S, window, ring, q_len)
    # single-step (q_len == 1) this is the old ``length > 0`` guard; with
    # drafts, row j > 0 can attend even from an empty cache (eff = j > 0)
    live = (ki >= lo) & (ki <= hi) & (length + q_len > 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (rows, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (block_k, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if quant:                                            # fold k scales
            s = s * ks_ref[0, 0][None, :]
        s = s * scale                                        # (rows, bk)

        rows = q.shape[0]                                    # Sq * g_pad
        row_j = jax.lax.broadcasted_iota(                    # draft index
            jnp.int32, (rows, block_k), 0) // g_pad
        pos_k = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 1)
        eff = length + row_j                         # causal intra-draft
        if ring and window > 0:
            mask = pos_k < jnp.minimum(eff, S)
            mask &= jnp.mod(eff - 1 - pos_k, S) < window
        else:
            mask = pos_k < eff
            if window > 0:
                mask &= pos_k > eff - 1 - window
        mask &= row_j < q_len                        # ragged draft padding
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]                                 # (rows,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scr[:, 0] * corr + jnp.sum(p, axis=-1)
        if quant:                                            # fold v scales
            p = p * vs_ref[0, 0][None, :]
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (block_k, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_scr[:, 0], 1e-30)            # dead rows -> 0/1
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _prep_q(q, Hk: int):
    """(B, Sq, H, D) -> padded (B, Hk, Sq*G_pad, D); returns
    (qg, Sq, G, G_pad).  Draft row ``j`` lands on kernel rows
    ``[j*G_pad, (j+1)*G_pad)`` — the row axis folds drafts and query-head
    groups so one grid cell computes every draft row of one KV head."""
    B, Sq, H, D = q.shape
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, D).transpose(0, 2, 1, 3, 4)
    sub = _sublanes(q.dtype)
    G_pad = max(sub, ((G + sub - 1) // sub) * sub)
    if G_pad != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, G_pad - G), (0, 0)))
    return qg.reshape(B, Hk, Sq * G_pad, D), Sq, G, G_pad


def _unprep_out(out, B: int, Sq: int, H: int, D: int, G: int, G_pad: int,
                Hk: int):
    """(B, Hk, Sq*G_pad, D) kernel output -> (B, Sq, H, D)."""
    out = out.reshape(B, Hk, Sq, G_pad, D)[:, :, :, :G]
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, D)


def _q_lens_or_full(q_lens, B: int, Sq: int):
    if q_lens is None:
        return jnp.full((B,), Sq, jnp.int32)
    return q_lens.astype(jnp.int32)


def _pad_kv_len(x, block_k: int):
    pad = (-x.shape[1]) % block_k
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x


def flash_decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                           ring: bool = False, softmax_scale=None,
                           block_k: int = 128, interpret: bool = False,
                           q_lens=None):
    """q (B, Sq, H, D); k/v (B, S, Hk, D); lengths (B,) int32 live prefix
    for row 0; q_lens (B,) int32 live draft rows (None = all Sq rows).

    Returns (B, Sq, H, D) in q.dtype.  ``window``/``ring`` select the
    masking variant; draft row ``j`` attends with effective length
    ``lengths + j`` (see module docstring)."""
    B, Sq, H, D = q.shape
    S = k_cache.shape[1]
    Hk = k_cache.shape[2]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    block_k = min(block_k, S)
    qg, Sq, G, G_pad = _prep_q(q, Hk)
    k_cache = _pad_kv_len(k_cache, block_k)
    v_cache = _pad_kv_len(v_cache, block_k)
    S_pad = k_cache.shape[1]
    n_kv = S_pad // block_k
    lengths = lengths.astype(jnp.int32)
    q_lens = _q_lens_or_full(q_lens, B, Sq)

    def kv_map(b, h, ki, lens, qlens):
        lo, hi = _live_block_bounds(lens[b], block_k, S, window, ring,
                                    qlens[b])
        return (b, jnp.clip(ki, lo, hi), h, 0)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, ring=ring,
        block_k=block_k, n_kv=n_kv, S=S, g_pad=G_pad)
    rows = Sq * G_pad
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hk, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, rows, D),
                         lambda b, h, ki, lens, qlens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, D),
                               lambda b, h, ki, lens, qlens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, rows, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q_lens, qg, k_cache, v_cache)
    return _unprep_out(out, B, Sq, H, D, G, G_pad, Hk)


def flash_decode_attention_quant(q, k_q, k_s, v_q, v_s, lengths, *,
                                 softmax_scale=None, block_k: int = 128,
                                 interpret: bool = False, q_lens=None):
    """Int8 fused variant: k_q/v_q (B, S, Hk, D) int8; k_s/v_s (B, S, Hk)
    f32 per-(position, head) scales; attends the quantized cache directly
    (tile dequantization inside the kernel, full-cache masking only).
    ``q_lens`` enables k-row speculative verification as in
    :func:`flash_decode_attention`."""
    B, Sq, H, D = q.shape
    S = k_q.shape[1]
    Hk = k_q.shape[2]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    block_k = min(block_k, S)
    qg, Sq, G, G_pad = _prep_q(q, Hk)
    k_q = _pad_kv_len(k_q, block_k)
    v_q = _pad_kv_len(v_q, block_k)
    # scales travel as (B, Hk, S): lane-major along the blocked axis
    k_s = _pad_kv_len(k_s, block_k).transpose(0, 2, 1)
    v_s = _pad_kv_len(v_s, block_k).transpose(0, 2, 1)
    S_pad = k_q.shape[1]
    n_kv = S_pad // block_k
    lengths = lengths.astype(jnp.int32)
    q_lens = _q_lens_or_full(q_lens, B, Sq)

    def kv_map(b, h, ki, lens, qlens):
        lo, hi = _live_block_bounds(lens[b], block_k, S, 0, False, qlens[b])
        return (b, jnp.clip(ki, lo, hi), h, 0)

    def scale_map(b, h, ki, lens, qlens):
        lo, hi = _live_block_bounds(lens[b], block_k, S, 0, False, qlens[b])
        return (b, h, jnp.clip(ki, lo, hi))

    def kernel(lens_ref, qlens_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
               o_ref, m_scr, l_scr, acc_scr):
        _decode_kernel(lens_ref, qlens_ref, q_ref, kq_ref, vq_ref, o_ref,
                       m_scr, l_scr, acc_scr, scale=scale, window=0,
                       ring=False, block_k=block_k, n_kv=n_kv, S=S,
                       g_pad=G_pad, quant=True, ks_ref=ks_ref,
                       vs_ref=vs_ref)

    rows = Sq * G_pad
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hk, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, rows, D),
                         lambda b, h, ki, lens, qlens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
            pl.BlockSpec((1, 1, block_k), scale_map),
            pl.BlockSpec((1, block_k, 1, D), kv_map),
            pl.BlockSpec((1, 1, block_k), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, D),
                               lambda b, h, ki, lens, qlens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, rows, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q_lens, qg, k_q, k_s, v_q, v_s)
    return _unprep_out(out, B, Sq, H, D, G, G_pad, Hk)


def flash_decode_attention_paged(q, k_pool, v_pool, block_tables, lengths, *,
                                 window: int = 0, ring: bool = False,
                                 softmax_scale=None,
                                 interpret: bool = False, q_lens=None):
    """Paged flash decode: q (B, Sq, H, D); k/v pools (N, bs, Hk, D) shared
    across slots; block_tables (B, nb) int32 physical block ids; lengths
    (B,) live virtual prefix.  The KV tile is one pool block (``block_k ==
    block_size``) and the index map dereferences the prefetched table.
    ``q_lens`` enables k-row speculative verification — the live-block
    clamp covers the draft span, so a draft crossing a block boundary
    fetches both touched blocks."""
    B, Sq, H, D = q.shape
    N, bs, Hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    S = nb * bs                              # virtual position space
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg, Sq, G, G_pad = _prep_q(q, Hk)
    lengths = lengths.astype(jnp.int32)
    q_lens = _q_lens_or_full(q_lens, B, Sq)
    block_tables = block_tables.astype(jnp.int32)

    def kv_map(b, h, ki, lens, qlens, tables):
        lo, hi = _live_block_bounds(lens[b], bs, S, window, ring, qlens[b])
        return (tables[b, jnp.clip(ki, lo, hi)], 0, h, 0)

    kernel_body = functools.partial(
        _decode_kernel, scale=scale, window=window, ring=ring,
        block_k=bs, n_kv=nb, S=S, g_pad=G_pad)

    def kernel(lens_ref, qlens_ref, tables_ref, q_ref, k_ref, v_ref, o_ref,
               m_scr, l_scr, acc_scr):
        kernel_body(lens_ref, qlens_ref, q_ref, k_ref, v_ref, o_ref,
                    m_scr, l_scr, acc_scr)

    rows = Sq * G_pad
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rows, D),
                         lambda b, h, ki, lens, qlens, tables: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, bs, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, rows, D),
            lambda b, h, ki, lens, qlens, tables: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, rows, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q_lens, block_tables, qg, k_pool, v_pool)
    return _unprep_out(out, B, Sq, H, D, G, G_pad, Hk)


def flash_decode_attention_paged_quant(q, k_q_pool, k_s_pool, v_q_pool,
                                       v_s_pool, block_tables, lengths, *,
                                       softmax_scale=None,
                                       interpret: bool = False,
                                       q_lens=None):
    """Paged int8 fused variant: value pools (N, bs, Hk, D) int8, scale
    pools (N, bs, Hk) f32; in-kernel tile dequant exactly as the dense-
    layout quant kernel, with the block-table index map of the paged one.
    ``q_lens`` enables k-row speculative verification."""
    B, Sq, H, D = q.shape
    N, bs, Hk, _ = k_q_pool.shape
    nb = block_tables.shape[1]
    S = nb * bs
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg, Sq, G, G_pad = _prep_q(q, Hk)
    lengths = lengths.astype(jnp.int32)
    q_lens = _q_lens_or_full(q_lens, B, Sq)
    block_tables = block_tables.astype(jnp.int32)
    # scales travel as (N, Hk, bs): lane-major along the blocked axis
    k_s_pool = k_s_pool.transpose(0, 2, 1)
    v_s_pool = v_s_pool.transpose(0, 2, 1)

    def kv_map(b, h, ki, lens, qlens, tables):
        lo, hi = _live_block_bounds(lens[b], bs, S, 0, False, qlens[b])
        return (tables[b, jnp.clip(ki, lo, hi)], 0, h, 0)

    def scale_map(b, h, ki, lens, qlens, tables):
        lo, hi = _live_block_bounds(lens[b], bs, S, 0, False, qlens[b])
        return (tables[b, jnp.clip(ki, lo, hi)], h, 0)

    def kernel(lens_ref, qlens_ref, tables_ref, q_ref, kq_ref, ks_ref,
               vq_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr):
        _decode_kernel(lens_ref, qlens_ref, q_ref, kq_ref, vq_ref, o_ref,
                       m_scr, l_scr, acc_scr, scale=scale, window=0,
                       ring=False, block_k=bs, n_kv=nb, S=S, g_pad=G_pad,
                       quant=True, ks_ref=ks_ref, vs_ref=vs_ref)

    rows = Sq * G_pad
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rows, D),
                         lambda b, h, ki, lens, qlens, tables: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, 1, bs), scale_map),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, 1, bs), scale_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, rows, D),
            lambda b, h, ki, lens, qlens, tables: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, rows, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q_lens, block_tables, qg, k_q_pool, k_s_pool, v_q_pool,
      v_s_pool)
    return _unprep_out(out, B, Sq, H, D, G, G_pad, Hk)
