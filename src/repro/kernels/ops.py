"""Public jit'd wrappers around the Pallas kernels.

On a CPU backend (this container) kernels run in ``interpret=True`` mode so
they are validated end-to-end; on TPU they compile natively.  ``impl`` can
force ``"ref"`` (pure-jnp oracle) — the default for *lowering* paths where a
clean HLO matters (dry-run roofline) is chosen by the caller.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _decode
from repro.kernels import embedding_ops as _embed
from repro.kernels import fused_adamw as _adamw
from repro.kernels import wkv6 as _wkv6
from repro.kernels import flash_attention as _flash
from repro.kernels import grad_compress as _gc
from repro.kernels import moe_router as _router
from repro.kernels import ref
from repro.kernels import topk_sparsify as _topk


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


# -- flash attention ---------------------------------------------------------

@partial(jax.jit, static_argnames=("causal", "window", "softmax_scale",
                                   "block_q", "block_k", "impl"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=0,
                         softmax_scale=None, block_q=128, block_k=128,
                         impl="kernel"):
    """Layout (B, H, S, D)."""
    if impl == "ref":
        return ref.flash_attention(q, k, v, causal=causal, window=window,
                                   softmax_scale=softmax_scale)
    return _flash.flash_attention(q, k, v, causal=causal, window=window,
                                  softmax_scale=softmax_scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())


def flash_attention(q, k, v, *, causal=True, window=0, softmax_scale=None,
                    block_q=128, block_k=128, impl="kernel"):
    """Layout (B, S, H, D) — the model-stack layout."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             softmax_scale=softmax_scale, block_q=block_q,
                             block_k=block_k, impl=impl)
    return o.transpose(0, 2, 1, 3)


# -- flash-decode attention ---------------------------------------------------

# Optional observability hook: a callable fed one record dict per
# decode_attention *dispatch* with the kernel route and roofline-modeled
# bytes/FLOPs from the argument shapes.  The body of the jitted entry point
# only runs at trace time (the engine calls it from inside jitted model
# code), so this fires per trace/compile — the honest granularity for a
# dispatch-level hook; per-step utilization is stamped on the engine's
# ``decode_step`` spans from the live lengths instead.
_dispatch_recorder = None


def set_dispatch_recorder(fn):
    """Install (or clear, fn=None) the dispatch recorder; returns the
    previous one so callers can restore it."""
    global _dispatch_recorder
    prev = _dispatch_recorder
    _dispatch_recorder = fn
    return prev


def _nbytes(x) -> int:
    return int(x.size) * jnp.dtype(x.dtype).itemsize


def _record_decode_dispatch(q, cache, layout) -> None:
    if _dispatch_recorder is None:
        return
    kv_keys = [k for k in ("k", "v", "k_q", "k_s", "v_q", "v_s")
               if k in cache]
    kv_bytes = sum(_nbytes(cache[k]) for k in kv_keys)
    B, _, H, D = q.shape
    # cache positions per slot: pool blocks * block_size when paged, else
    # the padded row length
    if layout.paged:
        pool = cache["k" if "k" in cache else "k_q"]
        S = int(cache["block_table"].shape[1]) * layout.block_size
    else:
        S = int(cache["k" if "k" in cache else "k_q"].shape[-3])
    _dispatch_recorder({
        "op": "decode_attention", "impl": layout.impl,
        "kind": layout.kind, "kv_bits": layout.kv_bits,
        "batch": int(B), "heads": int(H), "head_dim": int(D),
        "s_max": S,
        "kv_resident_bytes": kv_bytes,
        # qk^T + attn@v over the padded span (upper bound; the
        # length-aware kernel streams less — see serving.roofline)
        "modeled_flops": 4.0 * B * H * D * S,
    })


def decode_attention(q, cache, lengths, *, layout, softmax_scale=None,
                     q_lens=None):
    """Dispatch-recording wrapper over :func:`_decode_attention_jit` —
    the public entry point every model/backend calls."""
    _record_decode_dispatch(q, cache, layout)
    return _decode_attention_jit(q, cache, lengths, layout=layout,
                                 softmax_scale=softmax_scale, q_lens=q_lens)


@partial(jax.jit, static_argnames=("layout", "softmax_scale"))
def _decode_attention_jit(q, cache, lengths, *, layout, softmax_scale=None,
                          q_lens=None):
    """THE decode-attention entry point, keyed off one
    :class:`repro.cache_layout.CacheLayout` instead of four separate
    wrappers.  ``cache`` is a dict whose keys the layout determines:

    * dense bf16 — ``{"k", "v"}`` with (B, S, Hk, D) per-slot rows;
    * dense int8 — ``{"k_q", "k_s", "v_q", "v_s"}`` (scales (B, S, Hk));
    * paged — the same value keys holding *pool* arrays (N, bs, Hk, D)
      (scales (N, bs, Hk)), plus ``"block_table"`` (B, nb) int32.

    ``layout.impl`` selects ref oracle / dense XLA einsum / Pallas flash
    kernel; ``layout.window`` / ``layout.ring`` the masking variant (int8
    supports full-cache masking only, matching the fused kernels).
    ``q_lens`` (B,) carries live draft rows for speculative k-row
    verification (q (B, Sq, H, D)); None keeps the single-step semantics.
    The legacy ``flash_decode`` / ``flash_decode_quant`` wrappers below
    remain as thin shims over the same kernels."""
    if layout.quantized and (layout.window or layout.ring):
        raise ValueError("int8 decode supports full-cache masking only")
    interp = _interpret()
    if layout.paged:
        table = cache["block_table"]
        if layout.quantized:
            args = (cache["k_q"], cache["k_s"], cache["v_q"], cache["v_s"])
            if layout.impl == "ref":
                return ref.decode_attention_paged_quant(
                    q, *args, table, lengths, softmax_scale=softmax_scale,
                    q_lens=q_lens)
            if layout.impl == "dense":
                from repro.models import kvquant
                return kvquant.decode_attention_quant(
                    q, *(ref.paged_gather(a, table) for a in args), lengths,
                    softmax_scale=softmax_scale, impl="dense",
                    q_lens=q_lens)
            return _decode.flash_decode_attention_paged_quant(
                q, *args, table, lengths, softmax_scale=softmax_scale,
                interpret=interp, q_lens=q_lens)
        if layout.impl == "ref":
            return ref.decode_attention_paged(
                q, cache["k"], cache["v"], table, lengths,
                window=layout.window, ring=layout.ring,
                softmax_scale=softmax_scale, q_lens=q_lens)
        if layout.impl == "dense":
            from repro.models import attention
            return attention.decode_attention(
                q, ref.paged_gather(cache["k"], table),
                ref.paged_gather(cache["v"], table), lengths,
                window=layout.window, ring=layout.ring,
                softmax_scale=softmax_scale, impl="dense", q_lens=q_lens)
        return _decode.flash_decode_attention_paged(
            q, cache["k"], cache["v"], table, lengths, window=layout.window,
            ring=layout.ring, softmax_scale=softmax_scale, interpret=interp,
            q_lens=q_lens)
    if layout.quantized:
        args = (cache["k_q"], cache["k_s"], cache["v_q"], cache["v_s"])
        if layout.impl == "ref":
            return ref.decode_attention_quant(q, *args, lengths,
                                              softmax_scale=softmax_scale,
                                              q_lens=q_lens)
        if layout.impl == "dense":
            from repro.models import kvquant
            return kvquant.decode_attention_quant(
                q, *args, lengths, softmax_scale=softmax_scale, impl="dense",
                q_lens=q_lens)
        return _decode.flash_decode_attention_quant(
            q, *args, lengths, softmax_scale=softmax_scale,
            block_k=layout.block_k, interpret=interp, q_lens=q_lens)
    if layout.impl == "ref":
        return ref.decode_attention(q, cache["k"], cache["v"], lengths,
                                    window=layout.window, ring=layout.ring,
                                    softmax_scale=softmax_scale,
                                    q_lens=q_lens)
    if layout.impl == "dense":
        from repro.models import attention
        return attention.decode_attention(
            q, cache["k"], cache["v"], lengths, window=layout.window,
            ring=layout.ring, softmax_scale=softmax_scale, impl="dense",
            q_lens=q_lens)
    return _decode.flash_decode_attention(
        q, cache["k"], cache["v"], lengths, window=layout.window,
        ring=layout.ring, softmax_scale=softmax_scale,
        block_k=layout.block_k, interpret=interp, q_lens=q_lens)


@partial(jax.jit, static_argnames=("window", "ring", "softmax_scale",
                                   "block_k", "impl"))
def flash_decode(q, k_cache, v_cache, lengths, *, window=0, ring=False,
                 softmax_scale=None, block_k=128, impl="kernel",
                 q_lens=None):
    """Decode over per-slot live cache prefixes.  q (B, Sq, H, D); caches
    (B, S, Hk, D); lengths (B,); q_lens (B,) live draft rows when Sq > 1
    (speculative verification).  Layouts match the model stack's decode
    caches — no transposes on the hot path."""
    if impl == "ref":
        return ref.decode_attention(q, k_cache, v_cache, lengths,
                                    window=window, ring=ring,
                                    softmax_scale=softmax_scale,
                                    q_lens=q_lens)
    return _decode.flash_decode_attention(
        q, k_cache, v_cache, lengths, window=window, ring=ring,
        softmax_scale=softmax_scale, block_k=block_k,
        interpret=_interpret(), q_lens=q_lens)


@partial(jax.jit, static_argnames=("softmax_scale", "block_k", "impl"))
def flash_decode_quant(q, k_q, k_s, v_q, v_s, lengths, *, softmax_scale=None,
                       block_k=128, impl="kernel", q_lens=None):
    """Int8 fused decode: in-kernel tile dequantization of the quantized
    cache (values (B, S, Hk, D) int8, per-(position, head) f32 scales)."""
    if impl == "ref":
        return ref.decode_attention_quant(q, k_q, k_s, v_q, v_s, lengths,
                                          softmax_scale=softmax_scale,
                                          q_lens=q_lens)
    return _decode.flash_decode_attention_quant(
        q, k_q, k_s, v_q, v_s, lengths, softmax_scale=softmax_scale,
        block_k=block_k, interpret=_interpret(), q_lens=q_lens)


# -- MoE router ---------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "impl"))
def moe_router(logits, k: int, impl="kernel"):
    if impl == "ref":
        return ref.moe_router(logits, k)
    return _router.moe_router(logits, k, interpret=_interpret())


# -- 1-bit compression ---------------------------------------------------------

@partial(jax.jit, static_argnames=("block", "impl"))
def onebit_quantize(g: jnp.ndarray, block: int = 512, impl="kernel"):
    """Flat (N,) f32, N % (8*block) == 0 -> (packed (N/8,) u8, scales)."""
    g2d = g.reshape(8, g.shape[0] // 8)
    if impl == "ref":
        return ref.onebit_quantize(g2d, block)
    return _gc.onebit_quantize(g2d, block, interpret=_interpret())


@partial(jax.jit, static_argnames=("block", "impl"))
def onebit_dequantize(packed, scales, block: int = 512, impl="kernel"):
    if impl == "ref":
        g2d = ref.onebit_dequantize(packed, scales, block)
    else:
        g2d = _gc.onebit_dequantize(packed, scales, block,
                                    interpret=_interpret())
    return g2d.reshape(-1)


# -- top-k sparsification -------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "block", "impl"))
def topk_sparsify(g: jnp.ndarray, k: int, block: int = 2048, impl="kernel"):
    """Flat (N,) f32 -> (kept (N,), residual (N,)); block-local top-k."""
    N = g.shape[0]
    assert N % block == 0, (N, block)
    x2d = g.reshape(N // block, block)
    if impl == "ref":
        kept, resid = ref.topk_sparsify(x2d, k)
    else:
        kept, resid = _topk.topk_sparsify(x2d, k, interpret=_interpret())
    return kept.reshape(N), resid.reshape(N)


# -- embedding gather / scatter-add ---------------------------------------------

@partial(jax.jit, static_argnames=("impl",))
def embedding_gather(table, ids, impl="kernel"):
    """table (V, D), ids (n,) -> (n, D) = table[ids] (fused DMA gather)."""
    if impl == "ref":
        return ref.gather_rows(table, ids)
    return _embed.gather_rows(table, ids, interpret=_interpret())


@partial(jax.jit, static_argnames=("n_rows", "impl"))
def embedding_scatter_add(x, idx, n_rows: int, impl="kernel"):
    """x (n, D), idx (n,) -> (n_rows, D) segment-sum (exact duplicates)."""
    if impl == "ref":
        return ref.scatter_add_rows(x, idx, n_rows)
    return _embed.scatter_add_rows(x, idx, n_rows, interpret=_interpret())


# -- fused AdamW -----------------------------------------------------------------

@partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd", "impl"))
def adamw_update(p, g, m, v, lr, bc1, bc2, *, b1=0.9, b2=0.95, eps=1e-8,
                 wd=0.1, impl="kernel"):
    if impl == "ref":
        return ref.adamw_update(p, g, m, v, lr=lr, b1=b1, b2=b2, eps=eps,
                                wd=wd, bc1=bc1, bc2=bc2)
    return _adamw.adamw_update(p, g, m, v, lr=lr, b1=b1, b2=b2, eps=eps,
                               wd=wd, bc1=bc1, bc2=bc2,
                               interpret=_interpret())


# -- chunked WKV6 ---------------------------------------------------------------

@partial(jax.jit, static_argnames=("chunk", "impl"))
def wkv6_chunked(r, k, v, w, u, chunk: int = 32, impl="kernel"):
    """r,k,v,w: (B, H, T, hs) -> (B, H, T, hs); zero initial state."""
    if impl == "ref":
        return ref.wkv6_chunked(r, k, v, w, u)
    return _wkv6.wkv6_chunked(r, k, v, w, u, chunk=chunk,
                              interpret=_interpret())
