"""Pallas TPU kernels for the sparse-embedding subsystem.

* ``gather_rows`` — fused embedding gather.  The row ids are a
  scalar-prefetch operand (:class:`pltpu.PrefetchScalarGridSpec`), so the
  pipeline DMAs exactly the requested table row per grid step straight from
  HBM — the table is never materialized in VMEM.  This is the TPU-idiomatic
  embedding lookup: bytes moved = ``n_ids * D * itemsize``, independent of
  the table size.
* ``scatter_add_rows`` — segment-sum scatter-add, the transpose of the
  gather: accumulates input rows into ``out[idx[i]] += x[i]``.  Runs as a
  single program with the (small, deduped) output resident in VMEM and a
  sequential accumulation loop — duplicate ids are exact, no atomics
  needed.  Output rows must fit VMEM (the dedup path guarantees
  ``n_rows <= n_ids``); the pure-jnp fallback in ``kernels/ref.py`` covers
  arbitrary sizes.

Both are validated in interpret mode against ``kernels/ref.py`` oracles
(tests/test_embeddings.py); on TPU they compile natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, tbl_ref, out_ref):
    del ids_ref                         # consumed by the index maps
    out_ref[...] = tbl_ref[...]


def gather_rows(table: jnp.ndarray, ids: jnp.ndarray,
                interpret: bool = False) -> jnp.ndarray:
    """table (V, D), ids (n,) int32 -> (n, D) = table[ids]."""
    n = ids.shape[0]
    _, D = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, D), lambda i, ids: (ids[i], 0))],
        out_specs=pl.BlockSpec((1, D), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, D), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)


def _scatter_add_kernel(idx_ref, x_ref, out_ref):
    out_ref[...] = jnp.zeros_like(out_ref)

    def body(i, carry):
        out_ref[idx_ref[i], :] += x_ref[i, :]
        return carry

    jax.lax.fori_loop(0, x_ref.shape[0], body, 0)


def scatter_add_rows(x: jnp.ndarray, idx: jnp.ndarray, n_rows: int,
                     interpret: bool = False) -> jnp.ndarray:
    """x (n, D), idx (n,) int32 -> (n_rows, D) with out[idx[i]] += x[i].

    Exact for duplicate ids (sequential accumulation).  Out-of-range ids
    must be pre-clamped by the caller (the dedup path maps its sentinel to
    a dump row it slices off).
    """
    n, D = x.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec((n, D), lambda i, idx: (0, 0))],
        out_specs=pl.BlockSpec((n_rows, D), lambda i, idx: (0, 0)),
    )
    return pl.pallas_call(
        _scatter_add_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, D), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x)
