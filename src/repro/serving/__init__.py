"""Online serving subsystem: continuous-batching engine, recsys traffic
simulator, and SLO-aware latency metrics.

The deployment half of the paper: once a recommendation LLM is trained with
the hybrid-parallel stack, it must serve heavy interactive traffic.  This
package promotes the `examples/serve_lm.py` toy into a first-class engine:

* :mod:`repro.serving.engine`  — fixed-slot continuous batching (static
  shapes, per-slot lengths, prefill-on-arrival, bounded admission queue)
  over a **family registry** of slot backends: every architecture family
  (uniform decoders, gemma ring buffers, jamba/rwkv6 recurrent rows,
  whisper cross-KV) plugs into the same scheduler, with int8-KV as an
  orthogonal composition for any KV-bearing family.
* :mod:`repro.serving.traffic` — reproducible request workloads: Poisson or
  bursty arrivals, Zipfian users and prompt lengths, per-request SLO tiers,
  encoder frames for enc-dec families.
* :mod:`repro.serving.metrics` — throughput, TTFT, per-output-token latency,
  p50/p95/p99, and SLO attainment.
* :mod:`repro.serving.roofline` — modeled TPU-scale decode roofline terms
  (compute vs resident-state memory) for the full architectures.
"""
from repro.serving.engine import (EngineConfig, Int8KVBackend, Int8KVSlots,
                                  NativeBackend, ServingEngine, SlotBackend,
                                  make_backend)
from repro.serving.metrics import RequestRecord, percentile, summarize
from repro.serving.roofline import decode_state_bytes, modeled_decode_step
from repro.serving.traffic import (BATCH_TIER, INTERACTIVE_TIER, Clock,
                                   Request, SLOTier, TrafficConfig, generate)

__all__ = [
    "EngineConfig", "ServingEngine", "SlotBackend", "NativeBackend",
    "Int8KVBackend", "Int8KVSlots", "make_backend",
    "RequestRecord", "percentile", "summarize",
    "decode_state_bytes", "modeled_decode_step",
    "Request", "SLOTier", "TrafficConfig", "generate", "Clock",
    "INTERACTIVE_TIER", "BATCH_TIER",
]
