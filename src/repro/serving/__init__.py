"""Online serving subsystem: continuous-batching engine, recsys traffic
simulator, and SLO-aware latency metrics.

The deployment half of the paper: once a recommendation LLM is trained with
the hybrid-parallel stack, it must serve heavy interactive traffic.  This
package promotes the `examples/serve_lm.py` toy into a first-class engine:

* :mod:`repro.serving.engine`  — fixed-slot continuous batching (static
  shapes, per-slot lengths, prefill-on-arrival, bounded admission queue),
  with a native-dtype KV backend and an int8-quantized KV backend.
* :mod:`repro.serving.traffic` — reproducible request workloads: Poisson or
  bursty arrivals, Zipfian users and prompt lengths, per-request SLO tiers.
* :mod:`repro.serving.metrics` — throughput, TTFT, per-output-token latency,
  p50/p95/p99, and SLO attainment.
"""
from repro.serving.engine import (EngineConfig, Int8KVBackend, NativeBackend,
                                  ServingEngine)
from repro.serving.metrics import RequestRecord, percentile, summarize
from repro.serving.traffic import (BATCH_TIER, INTERACTIVE_TIER, Clock,
                                   Request, SLOTier, TrafficConfig, generate)

__all__ = [
    "EngineConfig", "ServingEngine", "NativeBackend", "Int8KVBackend",
    "RequestRecord", "percentile", "summarize",
    "Request", "SLOTier", "TrafficConfig", "generate", "Clock",
    "INTERACTIVE_TIER", "BATCH_TIER",
]
