"""Online serving subsystem: continuous-batching engine, recsys traffic
simulator, and SLO-aware latency metrics.

The deployment half of the paper: once a recommendation LLM is trained with
the hybrid-parallel stack, it must serve heavy interactive traffic.  This
package promotes the `examples/serve_lm.py` toy into a first-class engine:

* :mod:`repro.serving.engine`  — fixed-slot continuous batching (static
  shapes, per-slot lengths, prefill-on-arrival, bounded admission queue)
  over a **family registry** of slot backends: every architecture family
  (uniform decoders, gemma ring buffers, jamba/rwkv6 recurrent rows,
  whisper cross-KV) plugs into the same scheduler, with int8-KV as an
  orthogonal composition for any KV-bearing family.
* :mod:`repro.serving.traffic` — reproducible request workloads: Poisson or
  bursty arrivals, Zipfian users and prompt lengths, per-request SLO tiers,
  encoder frames for enc-dec families.
* :mod:`repro.serving.block_pool` — the paged cache layout's host half:
  refcounted shared block pool, per-slot read/write block tables, chained
  prefix hashing for prompt sharing, lazy copy-on-write.  Selected (with
  everything else about the cache) by one
  :class:`repro.cache_layout.CacheLayout` on ``EngineConfig.layout``.
* :mod:`repro.serving.metrics` — throughput, TTFT, per-output-token latency,
  p50/p95/p99, and SLO attainment.
* :mod:`repro.serving.roofline` — modeled TPU-scale decode roofline terms
  (compute vs resident-state memory) for the full architectures, including
  the dense-vs-paged admission-capacity model and the prefill/decode
  tier-split comparison.
* :mod:`repro.serving.disagg`   — disaggregated prefill/decode tiers: a
  router load-balancing N engine replicas on live windowed SLO
  percentiles, with token-exact KV handoff over the block pool.
"""
from repro.cache_layout import CacheLayout
from repro.serving.block_pool import BlockPool, SlotTables, prefix_keys
from repro.serving.cf_head import CFConfig, CFHead
from repro.serving.disagg import (DisaggServer, Router, RouterConfig,
                                  build_disagg)
from repro.serving.engine import (EngineConfig, Handoff, Int8KVBackend,
                                  Int8KVSlots, NativeBackend,
                                  PagedInt8Backend, PagedNativeBackend,
                                  PagedSlots, ServingEngine, SlotBackend,
                                  make_backend, serve)
from repro.serving.metrics import (RequestRecord, WindowedLatency,
                                   percentile, summarize)
from repro.serving.roofline import (cf_lookup_bytes, decode_state_bytes,
                                    kv_block_bytes, max_concurrent_slots,
                                    modeled_decode_step,
                                    modeled_prefill_step,
                                    modeled_tier_split, resident_kv_bytes)
from repro.serving.traffic import (BATCH_TIER, INTERACTIVE_TIER, Clock,
                                   PrefillBurstConfig, Request, SLOTier,
                                   TrafficConfig, generate,
                                   generate_prefill_burst)

__all__ = [
    "CacheLayout", "EngineConfig", "ServingEngine", "SlotBackend",
    "NativeBackend", "Int8KVBackend", "Int8KVSlots", "PagedNativeBackend",
    "PagedInt8Backend", "PagedSlots", "make_backend", "serve",
    "BlockPool", "SlotTables", "prefix_keys",
    "DisaggServer", "Router", "RouterConfig", "build_disagg", "Handoff",
    "RequestRecord", "WindowedLatency", "percentile", "summarize",
    "CFConfig", "CFHead", "cf_lookup_bytes",
    "decode_state_bytes", "modeled_decode_step", "modeled_prefill_step",
    "modeled_tier_split", "kv_block_bytes",
    "resident_kv_bytes", "max_concurrent_slots",
    "Request", "SLOTier", "TrafficConfig", "generate", "Clock",
    "PrefillBurstConfig", "generate_prefill_burst",
    "INTERACTIVE_TIER", "BATCH_TIER",
]
