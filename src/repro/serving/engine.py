"""Fixed-slot continuous-batching serving engine.

The TPU-idiomatic version of vLLM-style batching: the decode batch has a
*static* shape of ``n_slots`` cache rows, each slot holds one request, and
per-slot lengths (``cache["len"]``) track where each row's KV frontier is.
Arriving requests wait in a bounded admission queue; a free slot is filled
by a batched prefill of the prompt scattered into that slot's cache row
(prefill-on-arrival), after which every engine step decodes one token for
all occupied slots.  Finished slots (max-new-tokens reached or early EOS)
are refilled immediately (``refill="continuous"``) or only once the whole
batch drains (``refill="static"`` — the classical static-batching baseline
the benchmark compares against).

Admission is SLO-aware: the bounded queue is a two-level priority queue
(``interactive`` before ``batch``), and at saturation an interactive
arrival sheds the newest batch-tier entry rather than being dropped.
Decoding honors per-request sampling params (``temperature`` / ``top_k``
on :class:`~repro.serving.traffic.Request`): each slot carries a
per-request RNG key folded with the token index, so sampled streams are
reproducible regardless of slot placement or batch composition
(temperature 0 = greedy, the default).

The scheduler is **state-layout agnostic**: it only ever calls a backend's
``init_slots`` / ``prefill`` / ``decode`` and treats the slot state as an
opaque pytree.  Backends come from a *family registry*
(:func:`make_backend` dispatches on ``transformer.family(cfg)``), built on
the family-polymorphic DecodeState protocol in
:mod:`repro.models.transformer` — so every architecture family serves
through the same engine: uniform decoders (stacked KV rows), gemma
(sliding-window ring-buffer rows), jamba (per-period KV + mamba recurrent
rows), rwkv6 (wkv state rows), whisper (self-KV + per-slot cross-KV from
each request's encoder frames).

KV precision composes orthogonally: ``kv="int8"`` uses the fused
int8-attention path for the uniform family (:class:`Int8KVBackend`, via
``models.kvquant``) and the generic :class:`Int8KVSlots` composition —
int8 values + per-(position, head) scales around any KV-bearing family's
state — everywhere else (half the cache bytes; the decode roofline's
memory term).

Time is kept on a :class:`~repro.serving.traffic.Clock`: each model call
advances it by measured wall time (or a pinned per-call cost in tests), and
idle waits jump straight to the next arrival, so simulated Poisson load
plays out faithfully without real sleeping.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import kvquant
from repro.models import transformer as tf
from repro.serving import metrics as metrics_lib
from repro.serving.traffic import Clock, Request


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 128
    queue_capacity: int = 64
    refill: str = "continuous"          # continuous | static
    prompt_quantum: int = 8             # prompts pad to multiples (bounds
                                        # the number of prefill recompiles)
    pad_id: int = 0
    sample_seed: int = 0                # base of the per-request RNG keys


def _bucket(n: int, quantum: int, cap: int) -> int:
    return min(cap, ((n + quantum - 1) // quantum) * quantum)


def sample_token(logits_row, temperature: float, top_k: int, key) -> int:
    """One token from a (V,) logits row: greedy when ``temperature <= 0``,
    else softmax(logits/T) restricted to the top-k logits (0 = no cap)."""
    if temperature <= 0.0:
        return int(jnp.argmax(logits_row))
    lg = jnp.asarray(logits_row, jnp.float32)
    if top_k > 0:
        kth = jax.lax.top_k(lg, min(top_k, lg.shape[-1]))[0][-1]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    return int(jax.random.categorical(key, lg / temperature))


def sample_tokens(logits, temperatures, top_ks, keys):
    """Batched :func:`sample_token`: one token per (V,) row of ``logits``
    in a single traced computation — per-row temperature / top-k / RNG key,
    greedy rows (``temperature <= 0``) take the argmax.  Bit-identical to
    calling ``sample_token`` row by row (the kth-largest cut value equals
    ``lax.top_k``'s, and vmapping ``categorical`` over keys preserves each
    key's stream)."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)
    lg = logits.astype(jnp.float32)
    kth = jnp.take_along_axis(
        -jnp.sort(-lg, axis=-1),
        (jnp.clip(top_ks, 1, V) - 1).astype(jnp.int32)[:, None], axis=-1)
    lg = jnp.where((top_ks[:, None] > 0) & (lg < kth), -jnp.inf, lg)
    safe_t = jnp.where(temperatures > 0.0, temperatures, 1.0)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, lg / safe_t)
    return jnp.where(temperatures > 0.0, sampled, greedy)


# module-level jits: every ServingEngine instance (the bench builds dozens)
# shares one compile per (n_slots, V) shape
@jax.jit
def _greedy_tokens(logits):
    return jnp.argmax(logits, axis=-1)


@jax.jit
def _fold_and_sample(logits, temperatures, top_ks, keys, counts):
    keys = jax.vmap(jax.random.fold_in)(keys, counts)
    return sample_tokens(logits, temperatures, top_ks, keys)


class AdmissionQueue:
    """Two-level SLO-priority admission queue (interactive > batch).

    FIFO within a tier; ``popleft`` serves the interactive tier first, and
    ``shed_batch`` evicts the *newest* batch-tier entry to make room for an
    interactive arrival when the bounded queue saturates (shedding the
    request that would have waited longest anyway).
    """

    def __init__(self):
        self._tiers: Dict[bool, Deque] = {True: deque(), False: deque()}

    @staticmethod
    def _interactive(req: Request) -> bool:
        return req.slo.name == "interactive"

    def __len__(self) -> int:
        return len(self._tiers[True]) + len(self._tiers[False])

    def append(self, item) -> None:
        self._tiers[self._interactive(item[0])].append(item)

    def popleft(self):
        for tier in (True, False):
            if self._tiers[tier]:
                return self._tiers[tier].popleft()
        raise IndexError("pop from an empty AdmissionQueue")

    def shed_batch(self):
        """Evict and return the newest batch-tier entry (None if none)."""
        return self._tiers[False].pop() if self._tiers[False] else None


# Which slot-state entries hold scatterable KV rows, per family (the int8
# composition quantizes exactly these; rwkv6 carries no KV at all).
KV_KEYS: Dict[str, tuple] = {
    "uniform": ("k", "v"),
    "gemma": ("k", "v"),
    "jamba": ("k", "v"),
    "whisper": ("k", "v", "cross_k", "cross_v"),
    "rwkv6": (),
}

# family -> backend class; filled by @register_family below.
FAMILY_BACKENDS: Dict[str, type] = {}


def register_family(*families):
    """Class decorator: register a SlotBackend for the given families."""
    def deco(cls):
        for fam in families:
            FAMILY_BACKENDS[fam] = cls
        cls.families = families
        return cls
    return deco


class SlotBackend:
    """Jit wiring over the family-polymorphic DecodeState protocol.

    Subclasses supply ``init_slots`` (slot-indexed state pytree),
    ``_prefill_impl`` (traced: scatter one request's prompt state into one
    slot row, return that slot's last-position logits), and
    ``_decode_impl`` (traced one-token decode for every slot)."""

    families = None                     # set by @register_family (None: any)

    def __init__(self, cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 decode_impl: Optional[str] = None):
        fam = tf.family(cfg)
        if self.families is not None and fam not in self.families:
            raise NotImplementedError(
                f"{type(self).__name__} supports families {self.families}; "
                f"{cfg.name} is {fam}")
        self.cfg, self.params, self.family = cfg, params, fam
        # mrope archs (qwen2-vl) need explicit decode positions: they
        # advance per generated token from the request's text+patch layout
        # rather than equalling the KV frontier
        self.needs_positions = cfg.pos_type == "mrope"
        self.ctx = ctx if ctx is not None else tf.ModelCtx(attn_chunk=8)
        if decode_impl is not None:
            self.ctx = dataclasses.replace(self.ctx, decode_impl=decode_impl)
        # the slot state is consumed and replaced every call: donating it
        # lets XLA update the KV cache in place instead of allocating a
        # fresh multi-MB copy per decode step (no-op on the CPU backend,
        # which would only log a donation warning)
        donate = () if jax.default_backend() == "cpu" else (1,)
        self._decode = jax.jit(self._decode_impl, donate_argnums=donate)
        # the patch grid is layout (shapes the traced position tensor):
        # static arg, one compile per distinct grid — like prompt buckets
        self._prefill = jax.jit(self._prefill_impl, static_argnames="grid",
                                donate_argnums=donate)

    def kv_keys(self) -> tuple:
        return KV_KEYS[self.family]

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        raise NotImplementedError

    # back-compat alias (PR 1/2 name)
    def init_cache(self, n_slots: int, max_len: int) -> Dict:
        return self.init_slots(n_slots, max_len)

    def prefill(self, cache: Dict, tokens: np.ndarray, true_len: int,
                slot: int, frames=None, grid=None):
        """tokens (1, S_pad) -> (last-position logits (V,), cache).
        ``frames`` (F, d) or (1, F, d): encoder input for enc-dec families
        (zeros when omitted — every slot then shares one silent context).
        ``grid`` (gh, gw): vlm prompts' leading patch-token grid (mrope
        position layout)."""
        if self.cfg.encoder_layers:
            if frames is None:
                frames = np.zeros(
                    (1, self.cfg.encoder_frames, self.cfg.d_model),
                    np.float32)
            frames = jnp.asarray(frames, jnp.dtype(self.cfg.dtype))
            if frames.ndim == 2:
                frames = frames[None]
        else:
            frames = None
        return self._prefill(self.params, cache,
                             jnp.asarray(tokens, jnp.int32),
                             jnp.int32(true_len), jnp.int32(slot), frames,
                             grid=grid)

    def decode(self, cache: Dict, tokens, positions=None):
        """tokens (n_slots, 1) -> (logits (n_slots, 1, V), cache).
        ``positions`` (n_slots, 1, 3): per-slot mrope positions (vlm)."""
        if positions is None:
            return self._decode(self.params, cache, tokens)
        return self._decode(self.params, cache, tokens, positions)


@register_family("uniform", "gemma", "jamba", "rwkv6", "whisper")
class NativeBackend(SlotBackend):
    """Model-dtype slot state via the transformer DecodeState protocol
    (``init_slots`` / ``prefill_into_slot`` / ``decode_step``).

    ``prefill_chunk > 0`` streams uniform-family prompts through the
    decode cache-append path in fixed chunks instead of one monolithic
    padded forward (see :func:`transformer.prefill_into_slot`)."""

    def __init__(self, cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 decode_impl: Optional[str] = None, prefill_chunk: int = 0):
        self.prefill_chunk = int(prefill_chunk)
        super().__init__(cfg, params, ctx, decode_impl)

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        return tf.init_slots(self.cfg, n_slots, max_len)

    def _decode_impl(self, params, cache, tokens, positions=None):
        return tf.decode_step(self.cfg, params, cache, tokens, self.ctx,
                              positions=positions)

    def _prefill_impl(self, params, cache, tokens, true_len, slot,
                      frames=None, grid=None):
        return tf.prefill_into_slot(self.cfg, params, cache, tokens,
                                    true_len, slot, self.ctx, frames=frames,
                                    grid=grid, chunk=self.prefill_chunk)


class Int8KVBackend(SlotBackend):
    """Fused int8-KV path for the uniform family (kvquant): the cache is
    int8 values + per-(position, head) scales and the decode score matmul
    runs against the int8 values directly — half the cache bytes per slot
    AND no dequantized copy is ever materialized."""

    families = ("uniform",)

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        return kvquant.init_model_quant_cache(self.cfg, n_slots, max_len)

    def _decode_impl(self, params, cache, tokens, positions=None):
        if positions is not None:
            raise NotImplementedError(
                "fused int8 decode has no mrope positions path; "
                "make_backend routes mrope archs through Int8KVSlots")
        return kvquant.quant_decode_step(self.cfg, params, cache, tokens,
                                         self.ctx)

    def _prefill_impl(self, params, cache, tokens, true_len, slot,
                      frames=None, grid=None):
        logits, (k_q, k_s, v_q, v_s) = kvquant.quant_prefill_kv(
            self.cfg, params, {"tokens": tokens}, self.ctx)
        cache = dict(cache)
        for name, upd in (("k_q", k_q), ("k_s", k_s),
                          ("v_q", v_q), ("v_s", v_s)):
            start = (0, slot) + (0,) * (upd.ndim - 2)
            cache[name] = jax.lax.dynamic_update_slice(
                cache[name], upd.astype(cache[name].dtype), start)
        cache["len"] = cache["len"].at[slot].set(true_len)
        return logits[0, true_len - 1], cache


class Int8KVSlots(SlotBackend):
    """Generic int8-KV composition over any KV-bearing family backend.

    The inner family's slot state keeps its layout, but every KV entry
    (``KV_KEYS`` — stacked rows, gemma ring buffers, whisper cross-KV) is
    *stored* as int8 values + per-(position, head) f32 scales; recurrent
    states (mamba rows, wkv) stay full precision (they are O(1) per slot).
    Each step dequantizes for the family's native decode and requantizes
    the updated state.  Requantizing untouched rows is exact (see
    :func:`repro.models.kvquant.quantize_kv_tree`), so only the newly
    written position actually changes — repeated steps do not drift.  On
    a real accelerator the dequantized working copy is a per-step
    activation; the *resident* per-slot state is the halved int8 form that
    the decode roofline's memory term prices."""

    def __init__(self, inner: SlotBackend):
        self.inner = inner
        super().__init__(inner.cfg, inner.params, inner.ctx)

    def kv_keys(self) -> tuple:
        return self.inner.kv_keys()

    def _quant(self, cache: Dict) -> Dict:
        keys = self.inner.kv_keys()
        q, s = kvquant.quantize_kv_tree({k: cache[k] for k in keys})
        rest = {k: v for k, v in cache.items() if k not in keys}
        return {"kv_q": q, "kv_s": s, "rest": rest}

    def _dequant(self, qcache: Dict) -> Dict:
        kv = kvquant.dequantize_kv_tree(qcache["kv_q"], qcache["kv_s"],
                                        jnp.dtype(self.cfg.dtype))
        return {**qcache["rest"], **kv}

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        return self._quant(self.inner.init_slots(n_slots, max_len))

    def _decode_impl(self, params, qcache, tokens, positions=None):
        logits, cache = self.inner._decode_impl(params,
                                                self._dequant(qcache),
                                                tokens, positions)
        return logits, self._quant(cache)

    def _prefill_impl(self, params, qcache, tokens, true_len, slot,
                      frames=None, grid=None):
        logits, cache = self.inner._prefill_impl(
            params, self._dequant(qcache), tokens, true_len, slot, frames,
            grid=grid)
        return logits, self._quant(cache)


def make_backend(cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 kv: str = "native", decode_impl: Optional[str] = None,
                 prefill_chunk: int = 0):
    """Family-registry dispatch: the backend for ``tf.family(cfg)``, with
    the int8-KV composition applied on request (fused path for uniform,
    :class:`Int8KVSlots` for any other KV-bearing family).

    ``decode_impl`` overrides the decode-attention hot path on the
    backend's :class:`~repro.models.transformer.ModelCtx` (``"dense"`` |
    ``"flash"``); ``prefill_chunk > 0`` enables streaming prefill for
    uniform-family prompts (and routes uniform int8 through the
    :class:`Int8KVSlots` composition, whose inner native prefill chunks)."""
    fam = tf.family(cfg)
    if fam not in FAMILY_BACKENDS:
        raise NotImplementedError(
            f"no serving backend registered for family {fam!r} "
            f"(have {sorted(FAMILY_BACKENDS)})")
    if kv == "native":
        return FAMILY_BACKENDS[fam](cfg, params, ctx, decode_impl,
                                    prefill_chunk)
    if kv == "int8":
        if fam == "uniform" and cfg.pos_type != "mrope" and not prefill_chunk:
            # fused int8 path (whole-prompt quantized prefill).  mrope
            # archs need explicit decode positions and chunked prefill
            # needs the native cache-append path: both take the generic
            # composition below
            return Int8KVBackend(cfg, params, ctx, decode_impl)
        if not KV_KEYS[fam]:
            raise ValueError(
                f"family {fam!r} carries no KV cache; kv='int8' does not "
                f"apply (its recurrent state is O(1) per slot already)")
        return Int8KVSlots(FAMILY_BACKENDS[fam](cfg, params, ctx,
                                                decode_impl, prefill_chunk))
    raise ValueError(f"unknown kv backend {kv!r}")


class ServingEngine:
    """Slot scheduler over any backend exposing init_slots/prefill/decode.

    The scheduler never looks inside the slot state — family layout
    (stacked KV, ring buffers, recurrent rows, cross-KV) is entirely the
    backend's business."""

    def __init__(self, backend, ecfg: EngineConfig = EngineConfig(),
                 clock: Optional[Clock] = None):
        self.backend, self.ecfg = backend, ecfg
        self.clock = clock if clock is not None else Clock()
        n = ecfg.n_slots
        init = getattr(backend, "init_slots", None) or backend.init_cache
        self.cache = init(n, ecfg.max_len)
        self.queue = AdmissionQueue()
        self.slot_req: List[Optional[Request]] = [None] * n
        self.slot_rec: List[Optional[metrics_lib.RequestRecord]] = [None] * n
        self.slot_remaining = np.zeros(n, np.int64)
        self.slot_tokens = np.zeros((n, 1), np.int32)
        # device twin of slot_tokens: on pure decode steps the next tokens
        # are already on device (the sampler's output), so nothing is
        # re-uploaded; only host-side slot writes (prefill) mark it dirty
        self._tokens_dev = None
        self._tokens_dirty = True
        self.slot_key: List = [None] * n    # per-slot sampling RNG keys
        # mrope: the position of each slot's NEXT input token, advanced
        # per generated token from the request's prefill text+patch layout
        self.slot_pos = np.zeros(n, np.int64)
        self.outputs: Dict[int, List[int]] = {}
        self.records: List[metrics_lib.RequestRecord] = []
        self.decode_steps = 0
        self.prefills = 0

    # -- bookkeeping helpers -------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(1 for r in self.slot_req if r is not None)

    def _timed(self, fixed_s: Optional[float], fn):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        self.clock.advance(fixed_s if fixed_s is not None
                           else time.perf_counter() - t0)
        return out

    # -- scheduler ops -------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Enqueue; False (and a rejected record) when the bounded admission
        queue is full or the prompt cannot fit the serving window.  At
        saturation an interactive arrival sheds the newest batch-tier entry
        instead of being dropped (SLO-aware admission)."""
        rec = metrics_lib.RequestRecord(
            rid=req.rid, user_id=req.user_id, prompt_len=len(req.prompt),
            slo_name=req.slo.name, ttft_slo_s=req.slo.ttft_ms / 1e3,
            tpot_slo_s=req.slo.tpot_ms / 1e3, arrival=req.arrival)
        self.records.append(rec)
        if len(req.prompt) >= self.ecfg.max_len:
            rec.rejected = True
            return False
        if req.grid is not None and \
                req.grid[0] * req.grid[1] >= len(req.prompt):
            # a patch grid must leave at least one text token: patches
            # spilling into pad positions would silently corrupt the
            # request's mrope layout (see mrope_prompt_positions)
            rec.rejected = True
            return False
        if len(self.queue) >= self.ecfg.queue_capacity:
            shed = (self.queue.shed_batch()
                    if req.slo.name == "interactive" else None)
            if shed is None:
                rec.rejected = True
                return False
            shed[1].rejected = True         # the batch-tier request it evicts
        self.queue.append((req, rec))
        return True

    def _request_key(self, req: Request):
        """Per-request sampling key: reproducible across runs/slots."""
        return jax.random.fold_in(
            jax.random.PRNGKey(self.ecfg.sample_seed), req.rid)

    def _start(self, slot: int, req: Request,
               rec: metrics_lib.RequestRecord) -> None:
        """Prefill-on-arrival into one slot; the first generated token falls
        out of the prefill logits."""
        rec.admitted = self.clock.now
        prompt = np.asarray(req.prompt, np.int32)
        s_pad = _bucket(len(prompt), self.ecfg.prompt_quantum,
                        self.ecfg.max_len)
        padded = np.full((1, s_pad), self.ecfg.pad_id, np.int32)
        padded[0, :len(prompt)] = prompt
        kwargs = {}
        if req.frames is not None:       # enc-dec: cross-KV at admission
            kwargs["frames"] = np.asarray(req.frames, np.float32)
        if getattr(self.backend, "needs_positions", False):
            kwargs["grid"] = req.grid    # text+patch mrope layout
        logits_row, self.cache = self._timed(
            self.clock.fixed_prefill_s,
            lambda: self.backend.prefill(self.cache, padded,
                                         len(prompt), slot, **kwargs))
        self.prefills += 1
        key = self._request_key(req)
        first = sample_token(logits_row, req.temperature, req.top_k,
                             jax.random.fold_in(key, 0))
        rec.first_token = self.clock.now
        rec.tokens_out = 1
        self.outputs[req.rid] = [first]
        budget = min(req.max_new_tokens, self.ecfg.max_len - len(prompt))
        if first == req.eos_id or budget <= 1:
            rec.finished = self.clock.now       # slot never occupied
            return
        self.slot_req[slot] = req
        self.slot_rec[slot] = rec
        self.slot_remaining[slot] = budget - 1
        self.slot_tokens[slot, 0] = first
        self._tokens_dirty = True           # host wrote a slot: re-upload
        self.slot_key[slot] = np.asarray(key)    # host copy: stacked later
        if getattr(self.backend, "needs_positions", False):
            # the first generated token's mrope position, one past the
            # prompt's layout (text continues all three components)
            self.slot_pos[slot] = tf.mrope_next_position(len(prompt),
                                                         req.grid)

    def _refill(self) -> None:
        free = [s for s in range(self.ecfg.n_slots)
                if self.slot_req[s] is None]
        if self.ecfg.refill == "static" and len(free) < self.ecfg.n_slots:
            return                              # classical batch barrier
        for s in free:
            while self.queue and self.slot_req[s] is None:
                req, rec = self.queue.popleft()
                self._start(s, req, rec)        # may finish instantly (EOS)

    def _decode_once(self) -> None:
        positions = None
        if getattr(self.backend, "needs_positions", False):
            # (n, 1, 3): text decode advances t/h/w together per token
            positions = jnp.asarray(
                np.broadcast_to(self.slot_pos[:, None, None],
                                (self.ecfg.n_slots, 1, 3)), jnp.int32)
        if self._tokens_dirty or self._tokens_dev is None:
            self._tokens_dev = jnp.asarray(self.slot_tokens)
            self._tokens_dirty = False
        tokens = self._tokens_dev
        if positions is None:       # toy/test backends take (cache, tokens)
            call = lambda: self.backend.decode(  # noqa: E731
                self.cache, tokens)
        else:
            call = lambda: self.backend.decode(  # noqa: E731
                self.cache, tokens, positions)
        logits, self.cache = self._timed(self.clock.fixed_decode_s, call)
        self.decode_steps += 1
        self.slot_pos += 1
        n = self.ecfg.n_slots
        any_sampled = any(r is not None and r.temperature > 0.0
                          for r in self.slot_req)
        if not any_sampled:
            nxt_dev = _greedy_tokens(logits[:, 0, :])
            nxt = np.asarray(nxt_dev, np.int32)
        else:
            # batched temperature/top-k/categorical over all slots: one
            # device call, one host sync.  Per-slot keys fold with the
            # token index inside the jit, so slot placement and batch
            # composition never change a request's sampled stream (the
            # semantics the scalar sample_token path established).
            temps = np.zeros(n, np.float32)
            topks = np.zeros(n, np.int32)
            counts = np.zeros(n, np.int32)
            keys = np.zeros((n, 2), np.uint32)
            for s in range(n):
                if self.slot_req[s] is None:
                    continue
                temps[s] = self.slot_req[s].temperature
                topks[s] = self.slot_req[s].top_k
                counts[s] = self.slot_rec[s].tokens_out
                keys[s] = self.slot_key[s]
            nxt_dev = _fold_and_sample(logits[:, 0, :], temps, topks,
                                       keys, counts)
            nxt = np.asarray(nxt_dev, np.int32)
        # the sampled tokens are the next step's inputs and are already on
        # device — keep them there instead of re-uploading from host
        self._tokens_dev = nxt_dev[:, None].astype(jnp.int32)
        for s in range(n):
            req, rec = self.slot_req[s], self.slot_rec[s]
            if req is None:
                continue
            tok = int(nxt[s])
            self.outputs[req.rid].append(tok)
            rec.tokens_out += 1
            self.slot_remaining[s] -= 1
            self.slot_tokens[s, 0] = tok
            if tok == req.eos_id or self.slot_remaining[s] <= 0:
                rec.finished = self.clock.now
                self.slot_req[s] = None
                self.slot_rec[s] = None
                self.slot_key[s] = None

    # -- driver --------------------------------------------------------------

    def run(self, requests: Sequence[Request]):
        """Serve a workload to completion.

        Returns (outputs {rid: [token, ...]}, records, summary-dict)."""
        reqs = sorted(requests, key=lambda r: r.arrival)
        i = 0
        while True:
            while i < len(reqs) and reqs[i].arrival <= self.clock.now:
                self.submit(reqs[i])
                i += 1
            self._refill()
            if self.n_active:
                self._decode_once()
                continue
            if self.queue:
                # every slot free + non-empty queue should have refilled
                raise RuntimeError("scheduler stalled with queued work")
            if i < len(reqs):
                self.clock.advance(reqs[i].arrival - self.clock.now)
                continue
            break
        summary = metrics_lib.summarize(self.records, self.clock.now)
        summary["decode_steps"] = self.decode_steps
        summary["prefills"] = self.prefills
        return self.outputs, self.records, summary


def serve(cfg, params, requests: Sequence[Request],
          ecfg: EngineConfig = EngineConfig(),
          ctx: Optional[tf.ModelCtx] = None, kv: str = "native",
          clock: Optional[Clock] = None):
    """One-call convenience wrapper: build backend + engine, run, report."""
    engine = ServingEngine(make_backend(cfg, params, ctx, kv), ecfg, clock)
    return engine.run(requests)
