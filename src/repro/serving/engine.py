"""Fixed-slot continuous-batching serving engine.

The TPU-idiomatic version of vLLM-style batching: the decode batch has a
*static* shape of ``n_slots`` cache rows, each slot holds one request, and
per-slot lengths (``cache["len"]``) track where each row's KV frontier is.
Arriving requests wait in a bounded admission queue; a free slot is filled
by a batched prefill of the prompt scattered into that slot's cache row
(prefill-on-arrival), after which every engine step decodes one token for
all occupied slots.  Finished slots (max-new-tokens reached or early EOS)
are refilled immediately (``refill="continuous"``) or only once the whole
batch drains (``refill="static"`` — the classical static-batching baseline
the benchmark compares against).

Admission is SLO-aware: the bounded queue is a two-level priority queue
(``interactive`` before ``batch``), and at saturation an interactive
arrival sheds the newest batch-tier entry rather than being dropped.
Decoding honors per-request sampling params (``temperature`` / ``top_k``
on :class:`~repro.serving.traffic.Request`): each slot carries a
per-request RNG key folded with the token index, so sampled streams are
reproducible regardless of slot placement or batch composition
(temperature 0 = greedy, the default).

Two KV-cache backends plug into the same scheduler:

* :class:`NativeBackend` — model-dtype cache via ``transformer.init_cache``
  / ``decode_step``.
* :class:`Int8KVBackend` — int8-quantized cache via ``models.kvquant``
  (half the cache bytes; the decode roofline's memory term).

Time is kept on a :class:`~repro.serving.traffic.Clock`: each model call
advances it by measured wall time (or a pinned per-call cost in tests), and
idle waits jump straight to the next arrival, so simulated Poisson load
plays out faithfully without real sleeping.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import kvquant
from repro.models import transformer as tf
from repro.serving import metrics as metrics_lib
from repro.serving.traffic import Clock, Request


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 128
    queue_capacity: int = 64
    refill: str = "continuous"          # continuous | static
    prompt_quantum: int = 8             # prompts pad to multiples (bounds
                                        # the number of prefill recompiles)
    pad_id: int = 0
    sample_seed: int = 0                # base of the per-request RNG keys


def _bucket(n: int, quantum: int, cap: int) -> int:
    return min(cap, ((n + quantum - 1) // quantum) * quantum)


def sample_token(logits_row, temperature: float, top_k: int, key) -> int:
    """One token from a (V,) logits row: greedy when ``temperature <= 0``,
    else softmax(logits/T) restricted to the top-k logits (0 = no cap)."""
    if temperature <= 0.0:
        return int(jnp.argmax(logits_row))
    lg = jnp.asarray(logits_row, jnp.float32)
    if top_k > 0:
        kth = jax.lax.top_k(lg, min(top_k, lg.shape[-1]))[0][-1]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    return int(jax.random.categorical(key, lg / temperature))


class AdmissionQueue:
    """Two-level SLO-priority admission queue (interactive > batch).

    FIFO within a tier; ``popleft`` serves the interactive tier first, and
    ``shed_batch`` evicts the *newest* batch-tier entry to make room for an
    interactive arrival when the bounded queue saturates (shedding the
    request that would have waited longest anyway).
    """

    def __init__(self):
        self._tiers: Dict[bool, Deque] = {True: deque(), False: deque()}

    @staticmethod
    def _interactive(req: Request) -> bool:
        return req.slo.name == "interactive"

    def __len__(self) -> int:
        return len(self._tiers[True]) + len(self._tiers[False])

    def append(self, item) -> None:
        self._tiers[self._interactive(item[0])].append(item)

    def popleft(self):
        for tier in (True, False):
            if self._tiers[tier]:
                return self._tiers[tier].popleft()
        raise IndexError("pop from an empty AdmissionQueue")

    def shed_batch(self):
        """Evict and return the newest batch-tier entry (None if none)."""
        return self._tiers[False].pop() if self._tiers[False] else None


class _UniformFamilyBackend:
    """Shared jit wiring for slot backends over the uniform decoder family.

    Subclasses supply ``init_cache``, ``_prefill_impl`` (traced: scatter a
    prompt's K/V into one slot, return that slot's last-position logits),
    and ``_decode_impl`` (traced one-token decode for the whole batch)."""

    def __init__(self, cfg, params, ctx: Optional[tf.ModelCtx] = None):
        if tf.family(cfg) != "uniform":
            raise NotImplementedError(
                f"{type(self).__name__} supports the uniform decoder "
                f"family; {cfg.name} is {tf.family(cfg)}")
        self.cfg, self.params = cfg, params
        self.ctx = ctx if ctx is not None else tf.ModelCtx(attn_chunk=8)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    def prefill(self, cache: Dict, tokens: np.ndarray, true_len: int,
                slot: int):
        """tokens (1, S_pad) -> (last-position logits (V,), cache)."""
        return self._prefill(self.params, cache,
                             jnp.asarray(tokens, jnp.int32),
                             jnp.int32(true_len), jnp.int32(slot))

    def decode(self, cache: Dict, tokens):
        """tokens (n_slots, 1) -> (logits (n_slots, 1, V), cache)."""
        return self._decode(self.params, cache, tokens)


class NativeBackend(_UniformFamilyBackend):
    """Model-dtype KV cache via transformer.init_cache/decode_step."""

    def init_cache(self, n_slots: int, max_len: int) -> Dict:
        return tf.init_cache(self.cfg, n_slots, max_len)

    def _decode_impl(self, params, cache, tokens):
        return tf.decode_step(self.cfg, params, cache, tokens, self.ctx)

    def _prefill_impl(self, params, cache, tokens, true_len, slot):
        logits, _, (k, v) = tf.forward(self.cfg, params, {"tokens": tokens},
                                       self.ctx, collect_kv=True)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0, 0))
        cache["len"] = cache["len"].at[slot].set(true_len)
        return logits[0, true_len - 1], cache


class Int8KVBackend(_UniformFamilyBackend):
    """Int8-quantized KV cache (kvquant): half the cache bytes per slot."""

    def init_cache(self, n_slots: int, max_len: int) -> Dict:
        return kvquant.init_model_quant_cache(self.cfg, n_slots, max_len)

    def _decode_impl(self, params, cache, tokens):
        return kvquant.quant_decode_step(self.cfg, params, cache, tokens,
                                         self.ctx)

    def _prefill_impl(self, params, cache, tokens, true_len, slot):
        logits, (k_q, k_s, v_q, v_s) = kvquant.quant_prefill_kv(
            self.cfg, params, {"tokens": tokens}, self.ctx)
        cache = dict(cache)
        for name, upd in (("k_q", k_q), ("k_s", k_s),
                          ("v_q", v_q), ("v_s", v_s)):
            start = (0, slot) + (0,) * (upd.ndim - 2)
            cache[name] = jax.lax.dynamic_update_slice(
                cache[name], upd.astype(cache[name].dtype), start)
        cache["len"] = cache["len"].at[slot].set(true_len)
        return logits[0, true_len - 1], cache


def make_backend(cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 kv: str = "native"):
    if kv == "native":
        return NativeBackend(cfg, params, ctx)
    if kv == "int8":
        return Int8KVBackend(cfg, params, ctx)
    raise ValueError(f"unknown kv backend {kv!r}")


class ServingEngine:
    """Slot scheduler over any backend exposing init_cache/prefill/decode."""

    def __init__(self, backend, ecfg: EngineConfig = EngineConfig(),
                 clock: Optional[Clock] = None):
        self.backend, self.ecfg = backend, ecfg
        self.clock = clock if clock is not None else Clock()
        n = ecfg.n_slots
        self.cache = backend.init_cache(n, ecfg.max_len)
        self.queue = AdmissionQueue()
        self.slot_req: List[Optional[Request]] = [None] * n
        self.slot_rec: List[Optional[metrics_lib.RequestRecord]] = [None] * n
        self.slot_remaining = np.zeros(n, np.int64)
        self.slot_tokens = np.zeros((n, 1), np.int32)
        self.slot_key: List = [None] * n    # per-slot sampling RNG keys
        self.outputs: Dict[int, List[int]] = {}
        self.records: List[metrics_lib.RequestRecord] = []
        self.decode_steps = 0
        self.prefills = 0

    # -- bookkeeping helpers -------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(1 for r in self.slot_req if r is not None)

    def _timed(self, fixed_s: Optional[float], fn):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        self.clock.advance(fixed_s if fixed_s is not None
                           else time.perf_counter() - t0)
        return out

    # -- scheduler ops -------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Enqueue; False (and a rejected record) when the bounded admission
        queue is full or the prompt cannot fit the serving window.  At
        saturation an interactive arrival sheds the newest batch-tier entry
        instead of being dropped (SLO-aware admission)."""
        rec = metrics_lib.RequestRecord(
            rid=req.rid, user_id=req.user_id, prompt_len=len(req.prompt),
            slo_name=req.slo.name, ttft_slo_s=req.slo.ttft_ms / 1e3,
            tpot_slo_s=req.slo.tpot_ms / 1e3, arrival=req.arrival)
        self.records.append(rec)
        if len(req.prompt) >= self.ecfg.max_len:
            rec.rejected = True
            return False
        if len(self.queue) >= self.ecfg.queue_capacity:
            shed = (self.queue.shed_batch()
                    if req.slo.name == "interactive" else None)
            if shed is None:
                rec.rejected = True
                return False
            shed[1].rejected = True         # the batch-tier request it evicts
        self.queue.append((req, rec))
        return True

    def _request_key(self, req: Request):
        """Per-request sampling key: reproducible across runs/slots."""
        return jax.random.fold_in(
            jax.random.PRNGKey(self.ecfg.sample_seed), req.rid)

    def _start(self, slot: int, req: Request,
               rec: metrics_lib.RequestRecord) -> None:
        """Prefill-on-arrival into one slot; the first generated token falls
        out of the prefill logits."""
        rec.admitted = self.clock.now
        prompt = np.asarray(req.prompt, np.int32)
        s_pad = _bucket(len(prompt), self.ecfg.prompt_quantum,
                        self.ecfg.max_len)
        padded = np.full((1, s_pad), self.ecfg.pad_id, np.int32)
        padded[0, :len(prompt)] = prompt
        logits_row, self.cache = self._timed(
            self.clock.fixed_prefill_s,
            lambda: self.backend.prefill(self.cache, padded,
                                         len(prompt), slot))
        self.prefills += 1
        key = self._request_key(req)
        first = sample_token(logits_row, req.temperature, req.top_k,
                             jax.random.fold_in(key, 0))
        rec.first_token = self.clock.now
        rec.tokens_out = 1
        self.outputs[req.rid] = [first]
        budget = min(req.max_new_tokens, self.ecfg.max_len - len(prompt))
        if first == req.eos_id or budget <= 1:
            rec.finished = self.clock.now       # slot never occupied
            return
        self.slot_req[slot] = req
        self.slot_rec[slot] = rec
        self.slot_remaining[slot] = budget - 1
        self.slot_tokens[slot, 0] = first
        self.slot_key[slot] = key

    def _refill(self) -> None:
        free = [s for s in range(self.ecfg.n_slots)
                if self.slot_req[s] is None]
        if self.ecfg.refill == "static" and len(free) < self.ecfg.n_slots:
            return                              # classical batch barrier
        for s in free:
            while self.queue and self.slot_req[s] is None:
                req, rec = self.queue.popleft()
                self._start(s, req, rec)        # may finish instantly (EOS)

    def _decode_once(self) -> None:
        logits, self.cache = self._timed(
            self.clock.fixed_decode_s,
            lambda: self.backend.decode(self.cache,
                                        jnp.asarray(self.slot_tokens)))
        self.decode_steps += 1
        any_greedy = any(r is not None and r.temperature <= 0.0
                         for r in self.slot_req)
        nxt = (np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
               if any_greedy else None)
        for s in range(self.ecfg.n_slots):
            req, rec = self.slot_req[s], self.slot_rec[s]
            if req is None:
                continue
            if req.temperature > 0.0:
                # per-slot RNG key folded with the token index: slot
                # placement and batch composition never change the stream
                tok = sample_token(logits[s, 0, :], req.temperature,
                                   req.top_k,
                                   jax.random.fold_in(self.slot_key[s],
                                                      rec.tokens_out))
            else:
                tok = int(nxt[s])
            self.outputs[req.rid].append(tok)
            rec.tokens_out += 1
            self.slot_remaining[s] -= 1
            self.slot_tokens[s, 0] = tok
            if tok == req.eos_id or self.slot_remaining[s] <= 0:
                rec.finished = self.clock.now
                self.slot_req[s] = None
                self.slot_rec[s] = None
                self.slot_key[s] = None

    # -- driver --------------------------------------------------------------

    def run(self, requests: Sequence[Request]):
        """Serve a workload to completion.

        Returns (outputs {rid: [token, ...]}, records, summary-dict)."""
        reqs = sorted(requests, key=lambda r: r.arrival)
        i = 0
        while True:
            while i < len(reqs) and reqs[i].arrival <= self.clock.now:
                self.submit(reqs[i])
                i += 1
            self._refill()
            if self.n_active:
                self._decode_once()
                continue
            if self.queue:
                # every slot free + non-empty queue should have refilled
                raise RuntimeError("scheduler stalled with queued work")
            if i < len(reqs):
                self.clock.advance(reqs[i].arrival - self.clock.now)
                continue
            break
        summary = metrics_lib.summarize(self.records, self.clock.now)
        summary["decode_steps"] = self.decode_steps
        summary["prefills"] = self.prefills
        return self.outputs, self.records, summary


def serve(cfg, params, requests: Sequence[Request],
          ecfg: EngineConfig = EngineConfig(),
          ctx: Optional[tf.ModelCtx] = None, kv: str = "native",
          clock: Optional[Clock] = None):
    """One-call convenience wrapper: build backend + engine, run, report."""
    engine = ServingEngine(make_backend(cfg, params, ctx, kv), ecfg, clock)
    return engine.run(requests)
