"""Fixed-slot continuous-batching serving engine.

The TPU-idiomatic version of vLLM-style batching: the decode batch has a
*static* shape of ``n_slots`` cache rows, each slot holds one request, and
per-slot lengths (``cache["len"]``) track where each row's KV frontier is.
Arriving requests wait in a bounded admission queue; a free slot is filled
by a batched prefill of the prompt scattered into that slot's cache row
(prefill-on-arrival), after which every engine step decodes one token for
all occupied slots.  Finished slots (max-new-tokens reached or early EOS)
are refilled immediately (``refill="continuous"``) or only once the whole
batch drains (``refill="static"`` — the classical static-batching baseline
the benchmark compares against).

Admission is SLO-aware: the bounded queue is a two-level priority queue
(``interactive`` before ``batch``), and at saturation an interactive
arrival sheds the newest batch-tier entry rather than being dropped.
Decoding honors per-request sampling params (``temperature`` / ``top_k``
on :class:`~repro.serving.traffic.Request`): each slot carries a
per-request RNG key folded with the token index, so sampled streams are
reproducible regardless of slot placement or batch composition
(temperature 0 = greedy, the default).

The scheduler is **state-layout agnostic**: it only ever calls a backend's
``init_slots`` / ``prefill`` / ``decode`` and treats the slot state as an
opaque pytree.  Backends come from a *family registry*
(:func:`make_backend` dispatches on ``transformer.family(cfg)``), built on
the family-polymorphic DecodeState protocol in
:mod:`repro.models.transformer` — so every architecture family serves
through the same engine: uniform decoders (stacked KV rows), gemma
(sliding-window ring-buffer rows), jamba (per-period KV + mamba recurrent
rows), rwkv6 (wkv state rows), whisper (self-KV + per-slot cross-KV from
each request's encoder frames).

The cache layout is one explicit spec — :class:`repro.cache_layout
.CacheLayout` on :class:`EngineConfig` — consumed by :func:`make_backend`,
the kernels, and the launch flags alike.  Precision (``kv_bits=8``: fused
int8 attention for uniform via ``models.kvquant``, the generic
:class:`Int8KVSlots` composition elsewhere) and placement (``kind="paged"``:
a shared block pool + per-slot block tables instead of per-slot padded
rows) compose orthogonally.  The layout IS the spec — the pre-layout
``kv=`` / ``decode_impl=`` kwargs were removed after their one-release
deprecation window and now raise ``TypeError``.

The engine is instrumented (see :mod:`repro.obs` and README
"Observability"): hand :class:`ServingEngine` a ``tracer`` and/or
``metrics`` registry and it pins both to its simulated clock, emits
per-request phase spans (``req.queue_wait`` / ``req.prefill`` /
``req.decode`` on one track per slot) built from the *same*
:class:`~repro.serving.metrics.RequestRecord` timestamps the TTFT/TPOT
report reads, per-step ``decode_step`` spans carrying modeled
bytes/FLOPs/utilization from the roofline models, scheduler instants
(``sched.admit`` / ``sched.reject`` / ``sched.shed`` /
``sched.pushback``), and live block-pool gauges/counters.

Paged serving adds three scheduler-side pieces (see
:mod:`repro.serving.block_pool`): admission maps a request's virtual
blocks onto pooled physical blocks — adopting hash-matched *sealed* prefix
blocks from earlier identical prompts instead of allocating; decode
guarantees every active slot's frontier block is exclusively owned before
the step (**copy-on-write** at the first divergent token of a shared
tail); retirement releases refcounts, returning blocks to the free list.
Pool exhaustion degrades to queueing: a request that cannot map its span
goes back to the head of the admission queue and waits for retirements.
All five families page: attention KV rows move into the pool (uniform and
jamba stacked rows, whisper self-KV, gemma global layers), while per-slot
recurrent and ring state (mamba, wkv, gemma sliding-window rings, whisper
cross-KV) stays slot-resident — it is already live-bounded, which is the
entire point of paging the linearly growing rows.

Time is kept on a :class:`~repro.serving.traffic.Clock`: each model call
advances it by measured wall time (or a pinned per-call cost in tests), and
idle waits jump straight to the next arrival, so simulated Poisson load
plays out faithfully without real sleeping.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache_layout import (CacheLayout, blocks_per_slot,
                                resolved_num_blocks)
from repro.models import kvquant
from repro.models import transformer as tf
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer, or_null
from repro.serving import metrics as metrics_lib
from repro.serving.block_pool import BlockPool, SlotTables, prefix_keys
from repro.serving.traffic import Clock, Request


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 128
    queue_capacity: int = 64
    refill: str = "continuous"          # continuous | static
    prompt_quantum: int = 8             # prompts pad to multiples (bounds
                                        # the number of prefill recompiles)
    pad_id: int = 0
    sample_seed: int = 0                # base of the per-request RNG keys
    layout: CacheLayout = CacheLayout()  # cache layout spec (kind/bits/impl)
    prefill_chunk: int = 0              # uniform streaming prefill chunk
    spec_k: int = 1                     # speculative decode: rows verified
                                        # per step (1 = classic one-token)
    spec_draft: str = "ngram"           # self-speculative draft source


def _bucket(n: int, quantum: int, cap: int) -> int:
    return min(cap, ((n + quantum - 1) // quantum) * quantum)


def sample_token(logits_row, temperature: float, top_k: int, key) -> int:
    """One token from a (V,) logits row: greedy when ``temperature <= 0``,
    else softmax(logits/T) restricted to the top-k logits (0 = no cap)."""
    if temperature <= 0.0:
        return int(jnp.argmax(logits_row))
    lg = jnp.asarray(logits_row, jnp.float32)
    if top_k > 0:
        kth = jax.lax.top_k(lg, min(top_k, lg.shape[-1]))[0][-1]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    return int(jax.random.categorical(key, lg / temperature))


def sample_tokens(logits, temperatures, top_ks, keys):
    """Batched :func:`sample_token`: one token per (V,) row of ``logits``
    in a single traced computation — per-row temperature / top-k / RNG key,
    greedy rows (``temperature <= 0``) take the argmax.  Bit-identical to
    calling ``sample_token`` row by row (the kth-largest cut value equals
    ``lax.top_k``'s, and vmapping ``categorical`` over keys preserves each
    key's stream)."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)
    lg = logits.astype(jnp.float32)
    kth = jnp.take_along_axis(
        -jnp.sort(-lg, axis=-1),
        (jnp.clip(top_ks, 1, V) - 1).astype(jnp.int32)[:, None], axis=-1)
    lg = jnp.where((top_ks[:, None] > 0) & (lg < kth), -jnp.inf, lg)
    safe_t = jnp.where(temperatures > 0.0, temperatures, 1.0)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, lg / safe_t)
    return jnp.where(temperatures > 0.0, sampled, greedy)


# module-level jits: every ServingEngine instance (the bench builds dozens)
# shares one compile per (n_slots, V) shape
@jax.jit
def _greedy_tokens(logits):
    return jnp.argmax(logits, axis=-1)


@jax.jit
def _fold_and_sample(logits, temperatures, top_ks, keys, counts):
    keys = jax.vmap(jax.random.fold_in)(keys, counts)
    return sample_tokens(logits, temperatures, top_ks, keys)


def ngram_draft(history, need: int, lookback: int = 64) -> List[int]:
    """Self-speculative n-gram draft (prompt-lookup style): propose up to
    ``need`` continuation tokens by matching the tail of ``history``
    (prompt + generated so far) against its own recent past — bigram match
    first, unigram fallback, empty when nothing recurs.  No second model:
    the k-row verification step prices wrong drafts at zero extra
    cache-read bytes, so even a weak drafter only ever helps.  ``lookback``
    bounds the backward scan so drafting stays O(1) per step."""
    if need <= 0 or len(history) < 2:
        return []

    def match_once(h, want):
        for width in (2, 1):
            if len(h) <= width:
                continue
            pat = h[-width:]
            start = max(0, len(h) - 1 - lookback)
            for i in range(len(h) - 1 - width, start - 1, -1):
                if h[i:i + width] == pat:
                    cont = h[i + width:i + width + want]
                    if cont:
                        return [int(t) for t in cont]
        return []

    # Autoregressive extension: a match near the tail (e.g. a repeated run
    # "... x x x") yields a continuation truncated by the end of history.
    # Re-matching against history + draft-so-far fills the budget, so runs
    # and short cycles draft the full k-1 instead of one token.
    h, out = list(history), []
    while len(out) < need:
        step = match_once(h, need - len(out))
        if not step:
            break
        out.extend(step)
        h.extend(step)
    return out


class AdmissionQueue:
    """Two-level SLO-priority admission queue (interactive > batch).

    FIFO within a tier; ``popleft`` serves the interactive tier first, and
    ``shed_batch`` evicts the *newest* batch-tier entry to make room for an
    interactive arrival when the bounded queue saturates (shedding the
    request that would have waited longest anyway).
    """

    def __init__(self):
        self._tiers: Dict[bool, Deque] = {True: deque(), False: deque()}

    @staticmethod
    def _interactive(req: Request) -> bool:
        return req.slo.name == "interactive"

    def __len__(self) -> int:
        return len(self._tiers[True]) + len(self._tiers[False])

    def append(self, item) -> None:
        self._tiers[self._interactive(item[0])].append(item)

    def popleft(self):
        for tier in (True, False):
            if self._tiers[tier]:
                return self._tiers[tier].popleft()
        raise IndexError("pop from an empty AdmissionQueue")

    def shed_batch(self):
        """Evict and return the newest batch-tier entry (None if none)."""
        return self._tiers[False].pop() if self._tiers[False] else None

    def pushback(self, item) -> None:
        """Return an item to the *head* of its tier — used when paged
        admission fails on pool exhaustion: the request keeps its place in
        line and retries after retirements free blocks."""
        self._tiers[self._interactive(item[0])].appendleft(item)


# Which slot-state entries hold scatterable KV rows, per family (the int8
# composition quantizes exactly these; rwkv6 carries no KV at all).
KV_KEYS: Dict[str, tuple] = {
    "uniform": ("k", "v"),
    "gemma": ("k", "v"),
    "jamba": ("k", "v"),
    "whisper": ("k", "v", "cross_k", "cross_v"),
    "rwkv6": (),
}

# family -> backend class; filled by @register_family below.
FAMILY_BACKENDS: Dict[str, type] = {}


def register_family(*families):
    """Class decorator: register a SlotBackend for the given families."""
    def deco(cls):
        for fam in families:
            FAMILY_BACKENDS[fam] = cls
        cls.families = families
        return cls
    return deco


class SlotBackend:
    """Jit wiring over the family-polymorphic DecodeState protocol.

    Subclasses supply ``init_slots`` (slot-indexed state pytree),
    ``_prefill_impl`` (traced: scatter one request's prompt state into one
    slot row, return that slot's last-position logits), and
    ``_decode_impl`` (traced one-token decode for every slot)."""

    families = None                     # set by @register_family (None: any)
    # speculative decode rows per step.  The engine stamps the resolved
    # value BEFORE init_slots so state that depends on it (gemma local
    # rings, sized window + spec_k - 1 for mid-draft wraparound exactness)
    # is built to match; composition backends forward it to their inner.
    spec_k = 1

    def __init__(self, cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 decode_impl: Optional[str] = None):
        fam = tf.family(cfg)
        if self.families is not None and fam not in self.families:
            raise NotImplementedError(
                f"{type(self).__name__} supports families {self.families}; "
                f"{cfg.name} is {fam}")
        self.cfg, self.params, self.family = cfg, params, fam
        # mrope archs (qwen2-vl) need explicit decode positions: they
        # advance per generated token from the request's text+patch layout
        # rather than equalling the KV frontier
        self.needs_positions = cfg.pos_type == "mrope"
        self.ctx = ctx if ctx is not None else tf.ModelCtx(attn_chunk=8)
        if decode_impl is not None:
            self.ctx = dataclasses.replace(self.ctx, decode_impl=decode_impl)
        # the slot state is consumed and replaced every call: donating it
        # lets XLA update the KV cache in place instead of allocating a
        # fresh multi-MB copy per decode step (no-op on the CPU backend,
        # which would only log a donation warning)
        donate = () if jax.default_backend() == "cpu" else (1,)
        self._decode = jax.jit(self._decode_impl, donate_argnums=donate)
        # the patch grid is layout (shapes the traced position tensor):
        # static arg, one compile per distinct grid — like prompt buckets
        self._prefill = jax.jit(self._prefill_impl, static_argnames="grid",
                                donate_argnums=donate)
        # the layout this backend realizes (paged backends overwrite it
        # with the full spec; make_backend stamps the resolved one)
        if not hasattr(self, "layout"):
            self.layout = CacheLayout(impl=self.ctx.decode_impl)
        if hasattr(self, "_copy_impl"):
            self._copy = jax.jit(self._copy_impl)
        if hasattr(self, "_decode_spec_impl"):
            self._decode_spec = jax.jit(self._decode_spec_impl,
                                        donate_argnums=donate)
            self._decode_spec_packed = jax.jit(self._packed_spec_impl,
                                               donate_argnums=donate)

    def kv_keys(self) -> tuple:
        return KV_KEYS[self.family]

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        raise NotImplementedError

    # back-compat alias (PR 1/2 name)
    def init_cache(self, n_slots: int, max_len: int) -> Dict:
        return self.init_slots(n_slots, max_len)

    def prefill(self, cache: Dict, tokens: np.ndarray, true_len: int,
                slot: int, frames=None, grid=None):
        """tokens (1, S_pad) -> (last-position logits (V,), cache).
        ``frames`` (F, d) or (1, F, d): encoder input for enc-dec families
        (zeros when omitted — every slot then shares one silent context).
        ``grid`` (gh, gw): vlm prompts' leading patch-token grid (mrope
        position layout)."""
        if self.cfg.encoder_layers:
            if frames is None:
                frames = np.zeros(
                    (1, self.cfg.encoder_frames, self.cfg.d_model),
                    np.float32)
            frames = jnp.asarray(frames, jnp.dtype(self.cfg.dtype))
            if frames.ndim == 2:
                frames = frames[None]
        else:
            frames = None
        return self._prefill(self.params, cache,
                             jnp.asarray(tokens, jnp.int32),
                             jnp.int32(true_len), jnp.int32(slot), frames,
                             grid=grid)

    def decode(self, cache: Dict, tokens, positions=None):
        """tokens (n_slots, 1) -> (logits (n_slots, 1, V), cache).
        ``positions`` (n_slots, 1, 3): per-slot mrope positions (vlm)."""
        if positions is None:
            return self._decode(self.params, cache, tokens)
        return self._decode(self.params, cache, tokens, positions)

    def decode_spec(self, cache: Dict, tokens, q_lens, positions=None):
        """Speculative k-row step: tokens (n_slots, k) — row 0 the last
        committed token, rows 1.. self-drafted — verified greedily in one
        fused pass.  Returns (logits (n_slots, k, V), accepts (n_slots,),
        committed cache).  ``positions`` (n_slots, k, 3): mrope."""
        if not hasattr(self, "_decode_spec"):
            raise NotImplementedError(
                f"{type(self).__name__} has no speculative decode path")
        if positions is None:
            return self._decode_spec(self.params, cache, tokens, q_lens)
        return self._decode_spec(self.params, cache, tokens, q_lens,
                                 positions)

    def _packed_spec_impl(self, params, cache, packed, positions=None):
        tokens, q_lens = packed[:, :-1], packed[:, -1]
        if positions is None:
            return self._decode_spec_impl(params, cache, tokens, q_lens)
        return self._decode_spec_impl(params, cache, tokens, q_lens,
                                      positions)

    def decode_spec_packed(self, cache: Dict, packed, positions=None):
        """:meth:`decode_spec` minus one host->device put: ``packed``
        (n_slots, k + 1) int32 carries the draft rows with ``q_lens`` in
        the last column, uploaded as a single array and split inside the
        jitted step.  On CPU-sized models the second upload is a
        measurable share of a decode step, so the engine hot loop prefers
        this entry point."""
        if not hasattr(self, "_decode_spec_packed"):
            raise NotImplementedError(
                f"{type(self).__name__} has no speculative decode path")
        packed = jnp.asarray(packed, jnp.int32)
        if positions is None:
            return self._decode_spec_packed(self.params, cache, packed)
        return self._decode_spec_packed(self.params, cache, packed,
                                        positions)


@register_family("uniform", "gemma", "jamba", "rwkv6", "whisper")
class NativeBackend(SlotBackend):
    """Model-dtype slot state via the transformer DecodeState protocol
    (``init_slots`` / ``prefill_into_slot`` / ``decode_step``).

    ``prefill_chunk > 0`` streams uniform-family prompts through the
    decode cache-append path in fixed chunks instead of one monolithic
    padded forward (see :func:`transformer.prefill_into_slot`)."""

    def __init__(self, cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 decode_impl: Optional[str] = None, prefill_chunk: int = 0):
        self.prefill_chunk = int(prefill_chunk)
        super().__init__(cfg, params, ctx, decode_impl)

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        return tf.init_slots(self.cfg, n_slots, max_len,
                             spec_margin=self.spec_k - 1)

    def _decode_impl(self, params, cache, tokens, positions=None):
        return tf.decode_step(self.cfg, params, cache, tokens, self.ctx,
                              positions=positions)

    def _decode_spec_impl(self, params, cache, tokens, q_lens,
                          positions=None):
        return tf.decode_spec(self.cfg, params, cache, tokens, self.ctx,
                              q_lens=q_lens, positions=positions)

    def _prefill_impl(self, params, cache, tokens, true_len, slot,
                      frames=None, grid=None):
        return tf.prefill_into_slot(self.cfg, params, cache, tokens,
                                    true_len, slot, self.ctx, frames=frames,
                                    grid=grid, chunk=self.prefill_chunk)


class Int8KVBackend(SlotBackend):
    """Fused int8-KV path for the uniform family (kvquant): the cache is
    int8 values + per-(position, head) scales and the decode score matmul
    runs against the int8 values directly — half the cache bytes per slot
    AND no dequantized copy is ever materialized."""

    families = ("uniform",)

    def __init__(self, cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 decode_impl: Optional[str] = None):
        super().__init__(cfg, params, ctx, decode_impl)
        self.layout = self.layout.replace(kv_bits=8)

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        return kvquant.init_model_quant_cache(self.cfg, n_slots, max_len)

    def _decode_impl(self, params, cache, tokens, positions=None):
        if positions is not None:
            raise NotImplementedError(
                "fused int8 decode has no mrope positions path; "
                "make_backend routes mrope archs through Int8KVSlots")
        return kvquant.quant_decode_step(self.cfg, params, cache, tokens,
                                         self.ctx)

    def _decode_spec_impl(self, params, cache, tokens, q_lens,
                          positions=None):
        if positions is not None:
            raise NotImplementedError(
                "fused int8 decode has no mrope positions path; "
                "make_backend routes mrope archs through Int8KVSlots")
        return kvquant.quant_decode_spec(self.cfg, params, cache, tokens,
                                         self.ctx, q_lens=q_lens)

    def _prefill_impl(self, params, cache, tokens, true_len, slot,
                      frames=None, grid=None):
        logits, (k_q, k_s, v_q, v_s) = kvquant.quant_prefill_kv(
            self.cfg, params, {"tokens": tokens}, self.ctx)
        cache = dict(cache)
        for name, upd in (("k_q", k_q), ("k_s", k_s),
                          ("v_q", v_q), ("v_s", v_s)):
            start = (0, slot) + (0,) * (upd.ndim - 2)
            cache[name] = jax.lax.dynamic_update_slice(
                cache[name], upd.astype(cache[name].dtype), start)
        cache["len"] = cache["len"].at[slot].set(true_len)
        return logits[0, true_len - 1], cache


class Int8KVSlots(SlotBackend):
    """Generic int8-KV composition over any KV-bearing family backend.

    The inner family's slot state keeps its layout, but every KV entry
    (``KV_KEYS`` — stacked rows, gemma ring buffers, whisper cross-KV) is
    *stored* as int8 values + per-(position, head) f32 scales; recurrent
    states (mamba rows, wkv) stay full precision (they are O(1) per slot).
    Each step dequantizes for the family's native decode and requantizes
    the updated state.  Requantizing untouched rows is exact (see
    :func:`repro.models.kvquant.quantize_kv_tree`), so only the newly
    written position actually changes — repeated steps do not drift.  On
    a real accelerator the dequantized working copy is a per-step
    activation; the *resident* per-slot state is the halved int8 form that
    the decode roofline's memory term prices."""

    def __init__(self, inner: SlotBackend):
        self.inner = inner
        super().__init__(inner.cfg, inner.params, inner.ctx)
        self.layout = self.layout.replace(kv_bits=8)

    def kv_keys(self) -> tuple:
        return self.inner.kv_keys()

    def _quant(self, cache: Dict) -> Dict:
        keys = self.inner.kv_keys()
        q, s = kvquant.quantize_kv_tree({k: cache[k] for k in keys})
        rest = {k: v for k, v in cache.items() if k not in keys}
        return {"kv_q": q, "kv_s": s, "rest": rest}

    def _dequant(self, qcache: Dict) -> Dict:
        kv = kvquant.dequantize_kv_tree(qcache["kv_q"], qcache["kv_s"],
                                        jnp.dtype(self.cfg.dtype))
        return {**qcache["rest"], **kv}

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        self.inner.spec_k = self.spec_k     # sizes gemma rings in the inner
        return self._quant(self.inner.init_slots(n_slots, max_len))

    def _decode_impl(self, params, qcache, tokens, positions=None):
        logits, cache = self.inner._decode_impl(params,
                                                self._dequant(qcache),
                                                tokens, positions)
        return logits, self._quant(cache)

    def _decode_spec_impl(self, params, qcache, tokens, q_lens,
                          positions=None):
        # requantizing untouched rows is exact (the max element pins the
        # scale), so dequant -> inner k-row verify -> requant preserves
        # the inner path's token-exactness guarantee
        logits, accepts, cache = self.inner._decode_spec_impl(
            params, self._dequant(qcache), tokens, q_lens, positions)
        return logits, accepts, self._quant(cache)

    def _prefill_impl(self, params, qcache, tokens, true_len, slot,
                      frames=None, grid=None):
        logits, cache = self.inner._prefill_impl(
            params, self._dequant(qcache), tokens, true_len, slot, frames,
            grid=grid)
        return logits, self._quant(cache)


_TABLE_KEYS = ("block_table", "write_table")


class _PagedBackendMixin:
    """Shared device-side plumbing of the paged backends.

    ``supports_prefix_sharing`` marks backends whose prompt block content
    is a pure function of (prompt, engine constants) — the precondition
    for the hash index being sound.  ``set_tables`` uploads the host
    read/write tables; ``copy_block`` is the device half of copy-on-write
    (duplicate one physical block's rows across every pooled leaf).

    The ``gather_block_values`` / ``scatter_block_values`` /
    ``export_slot_state`` / ``import_slot_state`` quartet is the device
    half of prefill→decode handoff: snapshot the pooled rows of an
    exported block chain (plus the slot's non-pooled per-slot state) out
    of one engine's cache, and land them in another engine's cache at
    freshly mapped physical blocks.  Pure data movement — bit-exact — so
    a handed-off request decodes token-identically to one that never
    moved.  ``_pool_leaves`` names the pooled leaf arrays (block axis 1)
    for the fused uniform-family backends; :class:`PagedSlots` overrides
    the quartet to walk its generic leaf specs instead."""

    supports_prefix_sharing = True
    _pool_leaves: tuple = ()

    def set_tables(self, cache: Dict, read: np.ndarray,
                   write: np.ndarray) -> Dict:
        cache = dict(cache)
        cache["block_table"] = jnp.asarray(read, jnp.int32)
        cache["write_table"] = jnp.asarray(write, jnp.int32)
        return cache

    def copy_block(self, cache: Dict, src: int, dst: int) -> Dict:
        return self._copy(cache, jnp.int32(src), jnp.int32(dst))

    def gather_block_values(self, cache: Dict,
                            blocks: Sequence[int]) -> Dict:
        """Snapshot the pooled rows of ``blocks`` (physical ids, in
        virtual order) — the payload of a cross-pool handoff."""
        idx = jnp.asarray(np.asarray(blocks, np.int32))
        return {n: cache[n][:, idx] for n in self._pool_leaves}

    def scatter_block_values(self, cache: Dict, blocks: Sequence[int],
                             values: Dict,
                             rows: Optional[Sequence[int]] = None) -> Dict:
        """Write a gathered snapshot into ``blocks`` of this cache;
        ``rows`` selects which rows of the snapshot to use (virtual block
        indices the import actually copied — dedupe-adopted blocks are
        skipped)."""
        cache = dict(cache)
        idx = jnp.asarray(np.asarray(blocks, np.int32))
        sel = (None if rows is None
               else jnp.asarray(np.asarray(rows, np.int32)))
        for n in self._pool_leaves:
            v = values[n]
            if sel is not None:
                v = v[:, sel]
            cache[n] = cache[n].at[:, idx].set(v.astype(cache[n].dtype))
        return cache

    def export_slot_state(self, cache: Dict, slot: int) -> Dict:
        """Non-pooled per-slot state riding along with a handoff (for the
        fused uniform backends that's just the KV frontier length)."""
        return {"len": cache["len"][slot]}

    def import_slot_state(self, cache: Dict, slot: int,
                          state: Dict) -> Dict:
        cache = dict(cache)
        cache["len"] = cache["len"].at[slot].set(state["len"])
        return cache


class PagedNativeBackend(_PagedBackendMixin, SlotBackend):
    """Native paged path for the uniform family: stacked per-layer KV in a
    shared pool ``(L, N, bs, Hk, D)``; decode appends through the write
    table and attends through the read table with the paged flash-decode
    kernel (or its dense-gather twin) — see
    :func:`transformer.init_paged_slots` / :func:`attn_decode_paged`."""

    families = ("uniform",)
    _pool_leaves = ("k", "v")

    def __init__(self, cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 layout: CacheLayout = CacheLayout(kind="paged")):
        self.layout = layout
        super().__init__(cfg, params, ctx, layout.impl)

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        return tf.init_paged_slots(
            self.cfg, n_slots, max_len,
            num_blocks=resolved_num_blocks(self.layout, n_slots, max_len),
            block_size=self.layout.block_size)

    def _decode_impl(self, params, cache, tokens, positions=None):
        return tf.decode_step(self.cfg, params, cache, tokens, self.ctx,
                              positions=positions)

    def _decode_spec_impl(self, params, cache, tokens, q_lens,
                          positions=None):
        return tf.decode_spec(self.cfg, params, cache, tokens, self.ctx,
                              q_lens=q_lens, positions=positions)

    def _prefill_impl(self, params, cache, tokens, true_len, slot,
                      frames=None, grid=None):
        return tf.prefill_into_slot(self.cfg, params, cache, tokens,
                                    true_len, slot, self.ctx, frames=frames,
                                    grid=grid)

    def _copy_impl(self, cache, src, dst):
        cache = dict(cache)
        for name in ("k", "v"):
            cache[name] = cache[name].at[:, dst].set(cache[name][:, src])
        return cache


class PagedInt8Backend(_PagedBackendMixin, SlotBackend):
    """Fused paged int8 path (uniform family): pooled int8 values + pooled
    per-(position, head) scales, in-kernel tile dequantization through the
    block-table index map (``models.kvquant`` paged twins)."""

    families = ("uniform",)
    _pool_leaves = ("k_q", "k_s", "v_q", "v_s")

    def __init__(self, cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 layout: CacheLayout = CacheLayout(kind="paged", kv_bits=8)):
        self.layout = layout
        super().__init__(cfg, params, ctx, layout.impl)

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        return kvquant.init_paged_quant_cache(
            self.cfg, n_slots, max_len,
            num_blocks=resolved_num_blocks(self.layout, n_slots, max_len),
            block_size=self.layout.block_size)

    def _decode_impl(self, params, cache, tokens, positions=None):
        if positions is not None:
            raise NotImplementedError(
                "fused int8 decode has no mrope positions path; "
                "make_backend routes mrope archs through the composition")
        return kvquant.quant_decode_step(self.cfg, params, cache, tokens,
                                         self.ctx)

    def _decode_spec_impl(self, params, cache, tokens, q_lens,
                          positions=None):
        if positions is not None:
            raise NotImplementedError(
                "fused int8 decode has no mrope positions path; "
                "make_backend routes mrope archs through the composition")
        return kvquant.quant_decode_spec(self.cfg, params, cache, tokens,
                                         self.ctx, q_lens=q_lens)

    def _prefill_impl(self, params, cache, tokens, true_len, slot,
                      frames=None, grid=None):
        logits, (k_q, k_s, v_q, v_s) = kvquant.quant_prefill_kv(
            self.cfg, params, {"tokens": tokens}, self.ctx)
        bs = self.layout.block_size
        S_p = tokens.shape[1]
        pad = (-S_p) % bs
        nbp = (S_p + pad) // bs
        wt = cache["write_table"][slot][:nbp]
        cache = dict(cache)
        for name, upd in (("k_q", k_q), ("k_s", k_s),
                          ("v_q", v_q), ("v_s", v_s)):
            if pad:
                upd = jnp.pad(upd, ((0, 0), (0, 0), (0, pad))
                              + ((0, 0),) * (upd.ndim - 3))
            vals = upd[:, 0].reshape((upd.shape[0], nbp, bs)
                                     + upd.shape[3:])
            cache[name] = cache[name].at[:, wt].set(
                vals.astype(cache[name].dtype))
        cache["len"] = cache["len"].at[slot].set(true_len)
        return logits[0, true_len - 1], cache

    def _copy_impl(self, cache, src, dst):
        cache = dict(cache)
        for name in ("k_q", "k_s", "v_q", "v_s"):
            cache[name] = cache[name].at[:, dst].set(cache[name][:, src])
        return cache


class PagedSlots(_PagedBackendMixin, SlotBackend):
    """Generic paged composition over ANY family backend — how gemma,
    jamba, rwkv6, whisper (and compositions like int8-over-native) page
    without family-specific pool code.

    At ``init_slots`` the inner backend's dense slot state is used as a
    *template*: every array leaf under a self-attention KV key ("k"/"v",
    including gemma's per-layer tuple elements and the int8 composition's
    ``kv_q``/``kv_s`` subtrees) whose per-slot length dimension equals
    ``max_len`` is replaced by a shared pool ``(..., N, bs, ...)``.
    Everything else — mamba conv/ssm rows, wkv state, gemma sliding-window
    rings shorter than the serving window, whisper cross-KV — stays
    slot-resident: that state is already live-bounded (O(1) or
    O(window)), so paging it would add indirection without reclaiming
    memory.  rwkv6 pages zero leaves and degenerates to the identity
    composition (block tables exist but no pool), which keeps the five
    families behind one code path.

    Each traced step *gathers* pooled leaves into the inner backend's
    dense layout through the read table, runs the inner family step
    unchanged, and *scatters* updated rows back through the write table
    (rows of shared or unmapped blocks land in the null block 0).  The
    gather/scatter round trip is pure data movement — bit-exact — so
    paged serving is token-exact against the dense backend by
    construction; for the int8 composition, exact requantization of
    untouched rows (:func:`kvquant.quantize_kv_tree`) preserves the same
    guarantee.  On an accelerator the gathered working set is a per-step
    activation; the *resident* state is the pool, which is what the
    admission model prices."""

    def __init__(self, inner: SlotBackend, layout: CacheLayout):
        self.inner = inner
        self.layout = layout
        self._specs = None
        self._state_axes = None
        super().__init__(inner.cfg, inner.params, inner.ctx)

    def kv_keys(self) -> tuple:
        return self.inner.kv_keys()

    def init_slots(self, n_slots: int, max_len: int) -> Dict:
        # forward spec_k before building the template: margined gemma
        # rings (window + spec_k - 1 != max_len) stay slot-resident
        self.inner.spec_k = self.spec_k
        template = self.inner.init_slots(n_slots, max_len)
        bs = self.layout.block_size
        nb = blocks_per_slot(self.layout, max_len)
        num_blocks = resolved_num_blocks(self.layout, n_slots, max_len)
        paths, leaves = zip(*jax.tree_util.tree_flatten_with_path(
            template)[0])
        # slot axis of each slot-resident leaf (handoff transfers that
        # row): probe a phantom (n_slots + 1)-slot template through
        # eval_shape — zero allocation — and take the axis whose size
        # moved.  Exact for every family layout (mamba rows keep the
        # slot on axis 2), unlike any shape-matching heuristic.
        probe = jax.eval_shape(
            lambda: self.inner.init_slots(n_slots + 1, max_len))
        probe_leaves = jax.tree_util.tree_leaves(probe)
        specs, pooled, state_axes = [], [], []
        for path, leaf, pleaf in zip(paths, leaves, probe_leaves):
            ax = self._slot_axis(path, leaf, n_slots, max_len)
            specs.append(ax)
            if ax is None:
                pooled.append(leaf)
                diff = [i for i, (a, b) in enumerate(
                    zip(leaf.shape, pleaf.shape)) if a != b]
                state_axes.append(diff[0] if diff else None)
            else:
                shape = list(leaf.shape)
                shape[ax], shape[ax + 1] = num_blocks, bs
                pooled.append(jnp.zeros(tuple(shape), leaf.dtype))
                state_axes.append(None)
        self._specs = tuple(specs)
        self._state_axes = tuple(state_axes)
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), pooled)
        state = dict(state)
        tbl = jnp.zeros((n_slots, nb), jnp.int32)
        state["block_table"] = tbl
        state["write_table"] = tbl
        return state

    @staticmethod
    def _slot_axis(path, leaf, n_slots: int, max_len: int):
        """Slot axis of a pageable leaf, or None.  Pageable = an array
        under a "k"/"v" path key (self-attention KV; excludes cross_k/v,
        mamba, wkv) whose length dim is exactly ``max_len`` — linear
        append-at-``len`` semantics.  Shorter ring buffers stay resident.
        Slot axis is 0 for per-layer tuple elements (n, S, ...) and 1 for
        stacked (L, n, S, ...) entries."""
        keyed = any(getattr(p, "key", None) in ("k", "v") for p in path)
        if not keyed or not hasattr(leaf, "ndim"):
            return None
        if leaf.ndim >= 2 and leaf.shape[0] == n_slots \
                and leaf.shape[1] == max_len:
            return 0
        if leaf.ndim >= 3 and leaf.shape[1] == n_slots \
                and leaf.shape[2] == max_len:
            return 1
        return None

    def _split(self, cache: Dict):
        inner = {k: v for k, v in cache.items() if k not in _TABLE_KEYS}
        flat, treedef = jax.tree_util.tree_flatten(inner)
        return flat, treedef

    def _gather(self, cache: Dict) -> Dict:
        """Pooled state -> the inner backend's dense slot layout."""
        rt = cache["block_table"]
        n, nb = rt.shape
        bs = self.layout.block_size
        flat, treedef = self._split(cache)
        idx = rt.reshape(-1)
        out = []
        for leaf, ax in zip(flat, self._specs):
            if ax is None:
                out.append(leaf)
            elif ax == 0:
                g = leaf[idx].reshape((n, nb * bs) + leaf.shape[2:])
                out.append(g)
            else:
                g = leaf[:, idx].reshape(
                    (leaf.shape[0], n, nb * bs) + leaf.shape[3:])
                out.append(g)
        return jax.tree_util.tree_unflatten(treedef, out)

    def _repool(self, cache: Dict, dense: Dict) -> Dict:
        """Scatter an updated dense state back into the pools (write
        table: shared/unmapped rows -> null block), keep non-paged leaves
        from the inner result, carry the tables through."""
        wt = cache["write_table"]
        n, nb = wt.shape
        bs = self.layout.block_size
        pooled_flat, _ = self._split(cache)
        dense_flat, treedef = jax.tree_util.tree_flatten(
            {k: v for k, v in dense.items() if k not in _TABLE_KEYS})
        idx = wt.reshape(-1)
        out = []
        for pool, leaf, ax in zip(pooled_flat, dense_flat, self._specs):
            if ax is None:
                out.append(leaf)
            elif ax == 0:
                vals = leaf.reshape((n * nb, bs) + leaf.shape[2:])
                out.append(pool.at[idx].set(vals.astype(pool.dtype)))
            else:
                vals = leaf.reshape((leaf.shape[0], n * nb, bs)
                                    + leaf.shape[3:])
                out.append(pool.at[:, idx].set(vals.astype(pool.dtype)))
        state = dict(jax.tree_util.tree_unflatten(treedef, out))
        state["block_table"] = cache["block_table"]
        state["write_table"] = cache["write_table"]
        return state

    def _decode_impl(self, params, cache, tokens, positions=None):
        logits, dense = self.inner._decode_impl(params, self._gather(cache),
                                                tokens, positions)
        return logits, self._repool(cache, dense)

    def _decode_spec_impl(self, params, cache, tokens, q_lens,
                          positions=None):
        # gather -> inner k-row verify -> repool is pure data movement:
        # rejected rows land as garbage at dead positions of exclusively
        # owned blocks (the engine COWs the whole span first)
        logits, accepts, dense = self.inner._decode_spec_impl(
            params, self._gather(cache), tokens, q_lens, positions)
        return logits, accepts, self._repool(cache, dense)

    def _prefill_impl(self, params, cache, tokens, true_len, slot,
                      frames=None, grid=None):
        logits, dense = self.inner._prefill_impl(
            params, self._gather(cache), tokens, true_len, slot, frames,
            grid=grid)
        return logits, self._repool(cache, dense)

    def _copy_impl(self, cache, src, dst):
        flat, treedef = self._split(cache)
        out = []
        for leaf, ax in zip(flat, self._specs):
            if ax is None:
                out.append(leaf)
            elif ax == 0:
                out.append(leaf.at[dst].set(leaf[src]))
            else:
                out.append(leaf.at[:, dst].set(leaf[:, src]))
        state = dict(jax.tree_util.tree_unflatten(treedef, out))
        state["block_table"] = cache["block_table"]
        state["write_table"] = cache["write_table"]
        return state

    # -- handoff (block-value + slot-state transfer) -----------------------

    def gather_block_values(self, cache: Dict,
                            blocks: Sequence[int]) -> Dict:
        """Pooled-leaf rows of ``blocks``, keyed by flat leaf index.
        rwkv6 pages zero leaves and returns {} — its whole live state
        rides :meth:`export_slot_state` instead."""
        idx = jnp.asarray(np.asarray(blocks, np.int32))
        flat, _ = self._split(cache)
        vals = {}
        for j, (leaf, ax) in enumerate(zip(flat, self._specs)):
            if ax is None:
                continue
            vals[j] = leaf[idx] if ax == 0 else leaf[:, idx]
        return vals

    def scatter_block_values(self, cache: Dict, blocks: Sequence[int],
                             values: Dict,
                             rows: Optional[Sequence[int]] = None) -> Dict:
        idx = jnp.asarray(np.asarray(blocks, np.int32))
        sel = (None if rows is None
               else jnp.asarray(np.asarray(rows, np.int32)))
        flat, treedef = self._split(cache)
        out = list(flat)
        for j, v in values.items():
            ax = self._specs[j]
            if sel is not None:
                v = v[sel] if ax == 0 else v[:, sel]
            if ax == 0:
                out[j] = flat[j].at[idx].set(v.astype(flat[j].dtype))
            else:
                out[j] = flat[j].at[:, idx].set(v.astype(flat[j].dtype))
        state = dict(jax.tree_util.tree_unflatten(treedef, out))
        state["block_table"] = cache["block_table"]
        state["write_table"] = cache["write_table"]
        return state

    def export_slot_state(self, cache: Dict, slot: int) -> Dict:
        """Every slot-resident (non-pooled) leaf's row for ``slot``: the
        KV frontier length plus whatever the family keeps outside the
        pool — mamba conv/ssm rows, wkv state, gemma short rings, whisper
        cross-KV."""
        flat, _ = self._split(cache)
        st = {}
        for j, (leaf, ax, sax) in enumerate(
                zip(flat, self._specs, self._state_axes)):
            if ax is not None or sax is None:
                continue
            st[j] = leaf[(slice(None),) * sax + (slot,)]
        return st

    def import_slot_state(self, cache: Dict, slot: int,
                          state: Dict) -> Dict:
        flat, treedef = self._split(cache)
        out = list(flat)
        for j, v in state.items():
            sel = (slice(None),) * self._state_axes[j] + (slot,)
            out[j] = flat[j].at[sel].set(v.astype(flat[j].dtype))
        st = dict(jax.tree_util.tree_unflatten(treedef, out))
        st["block_table"] = cache["block_table"]
        st["write_table"] = cache["write_table"]
        return st


def make_backend(cfg, params, ctx: Optional[tf.ModelCtx] = None,
                 prefill_chunk: int = 0, *,
                 layout: Optional[CacheLayout] = None):
    """Family-registry dispatch keyed off one :class:`CacheLayout`.

    The layout picks the whole backend matrix: dense/bf16 ->
    :class:`NativeBackend`; dense/int8 -> fused :class:`Int8KVBackend`
    (uniform, whole-prompt prefill) or the :class:`Int8KVSlots`
    composition; paged/bf16 -> native :class:`PagedNativeBackend`
    (uniform) or the generic :class:`PagedSlots` composition; paged/int8
    -> fused :class:`PagedInt8Backend` (uniform) or
    ``PagedSlots(Int8KVSlots(native))``.  ``layout.impl`` overrides the
    decode-attention hot path on the backend's ModelCtx when it differs
    from the default.  ``prefill_chunk > 0`` enables streaming prefill for
    uniform-family prompts (which forces composition backends — the fused
    paths need the whole-prompt forward).

    The pre-layout ``kv=`` / ``decode_impl=`` kwargs were removed (PR-6
    deprecation window closed); passing them raises ``TypeError`` — use
    ``layout=CacheLayout(kv_bits=8, impl="flash")``."""
    explicit = layout is not None
    if layout is None:
        layout = CacheLayout()
    fam = tf.family(cfg)
    if fam not in FAMILY_BACKENDS:
        raise NotImplementedError(
            f"no serving backend registered for family {fam!r} "
            f"(have {sorted(FAMILY_BACKENDS)})")
    if layout.quantized and not KV_KEYS[fam]:
        raise ValueError(
            f"family {fam!r} carries no KV cache; int8 KV does not "
            f"apply (its recurrent state is O(1) per slot already)")
    # only override a caller-supplied ModelCtx's decode impl when the
    # layout (or legacy kwarg) explicitly asked for one
    impl = layout.impl if explicit else None
    if not layout.paged:
        if not layout.quantized:
            return FAMILY_BACKENDS[fam](cfg, params, ctx, impl,
                                        prefill_chunk)
        if fam == "uniform" and cfg.pos_type != "mrope" and not prefill_chunk:
            # fused int8 path (whole-prompt quantized prefill).  mrope
            # archs need explicit decode positions and chunked prefill
            # needs the native cache-append path: both take the generic
            # composition below
            backend = Int8KVBackend(cfg, params, ctx, impl)
        else:
            backend = Int8KVSlots(FAMILY_BACKENDS[fam](
                cfg, params, ctx, impl, prefill_chunk))
        backend.layout = layout.replace(kv_bits=8)
        return backend
    if fam == "uniform" and not prefill_chunk:
        if layout.quantized:
            if cfg.pos_type != "mrope":
                return PagedInt8Backend(cfg, params, ctx, layout)
        else:
            return PagedNativeBackend(cfg, params, ctx, layout)
    if layout.quantized:
        inner = Int8KVSlots(FAMILY_BACKENDS[fam](cfg, params, ctx, impl,
                                                 prefill_chunk))
    else:
        inner = FAMILY_BACKENDS[fam](cfg, params, ctx, impl, prefill_chunk)
    return PagedSlots(inner, layout)


@dataclasses.dataclass
class Handoff:
    """A prefilled request in flight from a prefill-tier engine to a
    decode-tier engine.

    Self-contained: the exported block chain (physical ids valid in the
    *source* pool, sealed content keys for dedupe), the gathered pooled
    block values (device snapshots — immutable, so the source slot can be
    released immediately), the non-pooled slot state, and the scheduler
    fields the decode engine needs to continue the stream exactly where
    prefill left it (last emitted token, remaining budget, sampling key,
    mrope position).  ``ready_at`` models the transfer latency
    (``Clock.fixed_handoff_s``); the record and output list are shared
    objects, so TTFT/TPOT and the token stream accumulate across tiers
    without any merge step."""

    req: Request
    rec: metrics_lib.RequestRecord
    last_token: int
    budget: int                     # generation budget incl. the first token
    key: np.ndarray                 # per-request sampling PRNG key
    live_tokens: int                # KV rows filled (= prompt length)
    blocks: List[int]               # exported chain (source-pool physical)
    keys: List[Optional[int]]       # sealed content key per block (or None)
    values: Dict                    # gathered pooled rows of blocks[:n_live]
    slot_state: Dict                # non-pooled per-slot rows
    src_pool: Optional[BlockPool]   # identity only (shared-pool detection)
    src: str                        # source engine name
    exported_at: float
    ready_at: float
    out: List[int]                  # the request's (shared) output list
    pos: int = 0                    # mrope: next input token's position


class ServingEngine:
    """Slot scheduler over any backend exposing init_slots/prefill/decode.

    The scheduler never looks inside the slot state — family layout
    (stacked KV, ring buffers, recurrent rows, cross-KV) is entirely the
    backend's business.  With a paged backend the engine additionally owns
    the host-side block accounting: a :class:`BlockPool` +
    :class:`SlotTables` pair whose read/write tables it uploads to the
    cache whenever they change, prefix-sharing admission keyed by
    :func:`prefix_keys`, and the per-step copy-on-write walk
    (:meth:`SlotTables.ensure_writable` -> ``backend.copy_block``)."""

    def __init__(self, backend, ecfg: EngineConfig = EngineConfig(),
                 clock: Optional[Clock] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None, *,
                 name: str = "engine", role: str = "both",
                 cf_head=None):
        if role not in ("both", "prefill", "decode"):
            raise ValueError(f"unknown engine role {role!r} "
                             "(both | prefill | decode)")
        self.backend, self.ecfg = backend, ecfg
        self.name = name
        self.role = role
        self.clock = clock if clock is not None else Clock()
        # observability: spans/instants + pool gauges, both pinned to the
        # engine's (simulated) clock so per-request span durations reconcile
        # with the TTFT/TPOT report by construction
        self.tracer = or_null(tracer)
        self.tracer.clock = lambda: self.clock.now
        self.metrics = metrics
        if metrics is not None:
            metrics.clock = lambda: self.clock.now
        n = ecfg.n_slots
        self.layout = getattr(backend, "layout", None) or ecfg.layout
        self.pool: Optional[BlockPool] = None
        self.tables: Optional[SlotTables] = None
        self.prefix_sharing = False
        if self.layout.paged and hasattr(backend, "set_tables"):
            self.pool = BlockPool(
                resolved_num_blocks(self.layout, n, ecfg.max_len),
                self.layout.block_size)
            self.tables = SlotTables(
                self.pool, n, blocks_per_slot(self.layout, ecfg.max_len))
            self.prefix_sharing = (
                self.layout.prefix_sharing
                and getattr(backend, "supports_prefix_sharing", False))
            if metrics is not None:
                self.pool.attach_metrics(
                    metrics,
                    prefix="pool" if name == "engine" else f"{name}.pool",
                    clock=lambda: self.clock.now)
        if role != "both" and self.tables is None:
            raise ValueError(
                f"engine role {role!r} needs a paged layout — prefill/"
                "decode handoff rides the block pool (layout=CacheLayout("
                "kind='paged'))")
        # disaggregated serving: handoffs exported by a prefill-tier
        # engine (drained by the DisaggServer driver) and the inbox of
        # handoffs awaiting a free slot on a decode-tier engine
        self.pending_handoffs: Deque[Handoff] = deque()
        self.handoff_inbox: Deque[Handoff] = deque()
        self.handoffs_out = 0
        self.handoffs_in = 0
        # sliding-window TTFT/TPOT percentiles (router routing signal)
        self.win = (metrics_lib.WindowedLatency(metrics, name)
                    if metrics is not None else None)
        # speculative decode: k rows verified per scheduler step
        self.spec_k = max(1, int(ecfg.spec_k))
        if self.spec_k > 1:
            if ecfg.spec_draft != "ngram":
                raise ValueError(
                    f"unknown spec_draft {ecfg.spec_draft!r}; the engine "
                    "is self-speculative (draft='ngram', no second model)")
            fam = getattr(backend, "family", None)
            if fam is not None and fam not in tf.SPEC_FAMILIES:
                raise ValueError(
                    f"speculative decode (spec_k={self.spec_k}) needs a "
                    f"pure-KV cache family {tf.SPEC_FAMILIES}; {fam!r} "
                    "carries recurrent per-token state that cannot rewind "
                    "a rejected draft — serve it with spec_k=1")
            has_spec = (hasattr(backend, "_decode_spec")
                        or hasattr(backend, "_decode_spec_impl")
                        or (not isinstance(backend, SlotBackend)
                            and hasattr(backend, "decode_spec")))
            if not has_spec:
                raise ValueError(
                    f"{type(backend).__name__} has no speculative decode "
                    "path; serve it with spec_k=1")
            # stamp BEFORE init_slots: gemma local rings must be sized
            # window + spec_k - 1 for mid-draft wraparound exactness.
            # max() keeps a shared backend's rings large enough for every
            # engine using it (single-step on a margined ring is exact)
            backend.spec_k = max(getattr(backend, "spec_k", 1), self.spec_k)
        init = getattr(backend, "init_slots", None) or backend.init_cache
        self.cache = init(n, ecfg.max_len)
        self.queue = AdmissionQueue()
        self.slot_req: List[Optional[Request]] = [None] * n
        self.slot_rec: List[Optional[metrics_lib.RequestRecord]] = [None] * n
        self.slot_remaining = np.zeros(n, np.int64)
        self.slot_tokens = np.zeros((n, 1), np.int32)
        # device twin of slot_tokens: on pure decode steps the next tokens
        # are already on device (the sampler's output), so nothing is
        # re-uploaded; only host-side slot writes (prefill) mark it dirty
        self._tokens_dev = None
        self._tokens_dirty = True
        self.slot_key: List = [None] * n    # per-slot sampling RNG keys
        # mrope: the position of each slot's NEXT input token, advanced
        # per generated token from the request's prefill text+patch layout
        self.slot_pos = np.zeros(n, np.int64)
        self.outputs: Dict[int, List[int]] = {}
        self.records: List[metrics_lib.RequestRecord] = []
        self.decode_steps = 0
        self.prefills = 0
        # KV frontier per slot (= rows filled: prompt + generated so far);
        # the paged write path makes position _slot_len[s] writable before
        # each decode step lands a token there
        self._slot_len = np.zeros(n, np.int64)
        # serve-artifact metrics: peak batch occupancy and resident KV
        # bytes integrated over decode steps (modeled via roofline)
        self.max_concurrent = 0
        self._kv_bytes_sum = 0.0
        # speculative accounting: tokens emitted by decode steps (not
        # scheduler steps) over live slot-steps, so accepted_tokens/step
        # is per slot (classic single-step decode == exactly 1.0)
        self.spec_tokens = 0
        self.spec_slot_steps = 0
        self.spec_rows = 0      # verify rows run (drafting intensity)
        # recsys serving: CF head (sharded cf_user/cf_item scoring with
        # the hot-row replica) — requests carrying a candidate set are
        # scored at prefill, inside the req.prefill span
        self.cf_head = cf_head
        self.cf_results: Dict[int, Dict] = {}
        self.cf_scored = 0

    # -- bookkeeping helpers -------------------------------------------------

    def _sync_tables(self) -> None:
        if self.tables is not None and self.tables.dirty:
            self.cache = self.backend.set_tables(
                self.cache, self.tables.read, self.tables.write)
            self.tables.dirty = False

    def _share_seed(self, req: Request):
        """Cache-namespace seed for prefix hashing: everything besides the
        prompt tokens that shapes a prompt's KV rows (model + backend +
        numerics config; encoder frames and the vlm patch grid for the
        families whose self-KV depends on them)."""
        parts: List = [getattr(self.backend.cfg, "name", ""),
                       self.layout.kv_bits,
                       type(self.backend).__name__,
                       type(getattr(self.backend, "inner", None)).__name__,
                       repr(getattr(self.backend, "ctx", None)),
                       self.ecfg.prefill_chunk]
        if req.frames is not None:
            fb = np.ascontiguousarray(np.asarray(req.frames, np.float32))
            parts.append(hashlib.blake2b(fb.tobytes(),
                                         digest_size=8).hexdigest())
        if req.grid is not None:
            parts.append(tuple(req.grid))
        return tuple(parts)

    def _resident_kv_bytes(self) -> float:
        """Modeled resident decode-state bytes right now (paged: pool
        occupancy; dense: every slot pinned at max_len)."""
        cfg = getattr(self.backend, "cfg", None)
        if cfg is None or not hasattr(cfg, "layer_kinds"):
            return 0.0
        from repro.serving import roofline
        if self.pool is not None:
            return roofline.resident_kv_bytes(
                cfg, self.ecfg.n_slots, self.ecfg.max_len, self.layout,
                used_blocks=self.pool.used_blocks)
        return self.ecfg.n_slots * roofline.decode_state_bytes(
            cfg, self.ecfg.max_len, kv_bits=self.layout.kv_bits)

    def _track(self, base: str) -> str:
        """Trace track name: bare for the default single engine (keeps
        existing traces/tests byte-identical), ``{name}.{base}`` when this
        engine is a named replica sharing a timeline with others."""
        return base if self.name == "engine" else f"{self.name}.{base}"

    def _trace_request(self, rec: metrics_lib.RequestRecord,
                       slot: int) -> None:
        """Retroactive per-request phase spans on track ``slot{N}``, built
        from the exact RequestRecord timestamps the metrics report reads:
        ``ttft == queue_wait.dur + prefill.dur`` and
        ``tpot == decode.dur / (tokens_out - 1)`` hold identically."""
        tr = self.tracer
        if not tr.enabled or rec.finished is None:
            return
        track = self._track(f"slot{slot}")
        tr.complete("req.queue_wait", rec.arrival, rec.admitted, track=track,
                    rid=rec.rid, slo=rec.slo_name)
        tr.complete("req.prefill", rec.admitted, rec.first_token, track=track,
                    rid=rec.rid, prompt_len=rec.prompt_len)
        tr.complete("req.decode", rec.first_token, rec.finished, track=track,
                    rid=rec.rid, tokens_out=rec.tokens_out)

    def _decode_model_args(self) -> Dict:
        """Modeled bytes/FLOPs/utilization for one decode step (roofline
        models over the live per-slot lengths) — the args a traced
        ``decode_step`` span carries so the timeline shows utilization,
        not just wall time.  Empty for toy/test backends without a full
        ArchConfig."""
        cfg = getattr(self.backend, "cfg", None)
        if cfg is None or not hasattr(cfg, "layer_kinds"):
            return {}
        from repro.core.hybrid import decode_model_flops
        from repro.serving import roofline
        lengths = [int(self._slot_len[s]) for s in range(self.ecfg.n_slots)
                   if self.slot_req[s] is not None]
        if not lengths:
            return {}
        rb = roofline.decode_attn_read_bytes(
            cfg, lengths, self.ecfg.max_len,
            impl=self.layout.impl or "dense", kv_bits=self.layout.kv_bits)
        return {
            "n_active": len(lengths),
            "attn_read_bytes": rb["attn_read_bytes_per_step"],
            "mean_utilization": rb["mean_utilization"],
            "model_flops": decode_model_flops(
                cfg, max(lengths), len(lengths)),
        }

    @property
    def n_active(self) -> int:
        return sum(1 for r in self.slot_req if r is not None)

    def _timed(self, fixed_s: Optional[float], fn):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        self.clock.advance(fixed_s if fixed_s is not None
                           else time.perf_counter() - t0)
        return out

    # -- scheduler ops -------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Enqueue; False (and a rejected record) when the bounded admission
        queue is full or the prompt cannot fit the serving window.  At
        saturation an interactive arrival sheds the newest batch-tier entry
        instead of being dropped (SLO-aware admission)."""
        rec = metrics_lib.RequestRecord(
            rid=req.rid, user_id=req.user_id, prompt_len=len(req.prompt),
            slo_name=req.slo.name, ttft_slo_s=req.slo.ttft_ms / 1e3,
            tpot_slo_s=req.slo.tpot_ms / 1e3, arrival=req.arrival)
        self.records.append(rec)
        if len(req.prompt) >= self.ecfg.max_len:
            rec.rejected = True
            self.tracer.instant("sched.reject", track=self._track("sched"),
                                rid=req.rid, reason="prompt_too_long")
            return False
        if req.grid is not None and \
                req.grid[0] * req.grid[1] >= len(req.prompt):
            # a patch grid must leave at least one text token: patches
            # spilling into pad positions would silently corrupt the
            # request's mrope layout (see mrope_prompt_positions)
            rec.rejected = True
            self.tracer.instant("sched.reject", track=self._track("sched"),
                                rid=req.rid, reason="grid_overflow")
            return False
        if len(self.queue) >= self.ecfg.queue_capacity:
            shed = (self.queue.shed_batch()
                    if req.slo.name == "interactive" else None)
            if shed is None:
                rec.rejected = True
                self.tracer.instant("sched.reject", track=self._track("sched"),
                                    rid=req.rid, reason="queue_full")
                return False
            shed[1].rejected = True         # the batch-tier request it evicts
            self.tracer.instant("sched.shed", track=self._track("sched"),
                                rid=shed[0].rid, for_rid=req.rid)
        self.queue.append((req, rec))
        self._note_load()
        return True

    def _request_key(self, req: Request):
        """Per-request sampling key: reproducible across runs/slots."""
        return jax.random.fold_in(
            jax.random.PRNGKey(self.ecfg.sample_seed), req.rid)

    def _start(self, slot: int, req: Request,
               rec: metrics_lib.RequestRecord) -> bool:
        """Prefill-on-arrival into one slot; the first generated token falls
        out of the prefill logits.  Returns False — request untouched — when
        the block pool cannot map the request yet (paged admission): the
        caller requeues it behind the blocks that retiring slots free."""
        prompt = np.asarray(req.prompt, np.int32)
        if self.tables is not None:
            bs = self.layout.block_size
            if self.role == "prefill":
                # tier advantage: a prefill engine maps only the prompt's
                # blocks — the decode budget is reserved by the decode
                # tier at import (pad-row writes past the prompt sink
                # into the null block)
                span = -(-len(prompt) // bs)
            else:
                span = -(-min(len(prompt) + req.max_new_tokens,
                              self.ecfg.max_len) // bs)
            if self.prefix_sharing:
                keys, tail = prefix_keys(req.prompt, bs,
                                         self._share_seed(req))
            else:
                keys, tail = [], None
            if not self.tables.admit(slot, keys, tail, span):
                return False
            self._sync_tables()
        rec.admitted = self.clock.now
        self.tracer.instant("sched.admit", track=self._track("sched"),
                            rid=req.rid, slot=slot,
                            queue_wait=rec.admitted - rec.arrival)
        s_pad = _bucket(len(prompt), self.ecfg.prompt_quantum,
                        self.ecfg.max_len)
        padded = np.full((1, s_pad), self.ecfg.pad_id, np.int32)
        padded[0, :len(prompt)] = prompt
        kwargs = {}
        if req.frames is not None:       # enc-dec: cross-KV at admission
            kwargs["frames"] = np.asarray(req.frames, np.float32)
        if getattr(self.backend, "needs_positions", False):
            kwargs["grid"] = req.grid    # text+patch mrope layout
        logits_row, self.cache = self._timed(
            self.clock.fixed_prefill_s,
            lambda: self.backend.prefill(self.cache, padded,
                                         len(prompt), slot, **kwargs))
        self.prefills += 1
        self._slot_len[slot] = len(prompt)
        if self.tables is not None:
            # publish this prompt's self-computed blocks for later sharers
            self.tables.seal_prompt(slot)
        if self.cf_head is not None and req.candidates:
            # retrieval->rank: score the candidate set through the sharded
            # CF tables and fuse with the prompt's last-position logits.
            # Runs between prefill and the first-token stamp, so the CF
            # time lands inside the req.prefill span and the TTFT/span
            # reconciliation holds unchanged.
            t_cf = self.clock.now
            res = self._timed(
                getattr(self.clock, "fixed_cf_s", None),
                lambda: self.cf_head.score(req.user_id, req.candidates,
                                           lm_logits_row=logits_row))
            self.cf_results[req.rid] = res
            self.cf_scored += 1
            self.tracer.complete("cf.lookup", t_cf, self.clock.now,
                                 track=self._track(f"slot{slot}"),
                                 rid=req.rid, hits=res["hits"],
                                 misses=res["misses"],
                                 candidates=len(req.candidates))
            if self.metrics is not None:
                self.metrics.counter("cf_cache.hits").inc(res["hits"])
                self.metrics.counter("cf_cache.misses").inc(res["misses"])
                self.metrics.gauge("cf_cache.hit_rate").set(
                    self.cf_head.hit_rate)
                self.metrics.gauge("cf_cache.rows").set(
                    self.cf_head.cache_rows_live)
        key = self._request_key(req)
        first = sample_token(logits_row, req.temperature, req.top_k,
                             jax.random.fold_in(key, 0))
        rec.first_token = self.clock.now
        rec.tokens_out = 1
        if self.win is not None:
            self.win.observe_ttft(rec.first_token - rec.arrival)
        self.outputs[req.rid] = [first]
        budget = min(req.max_new_tokens, self.ecfg.max_len - len(prompt))
        if first == req.eos_id or budget <= 1:
            rec.finished = self.clock.now       # slot never occupied
            if self.tables is not None:
                self.tables.release(slot)
            self._trace_request(rec, slot)
            self._note_finish(rec)
            return True
        if self.role == "prefill":
            # hand the sealed prompt blocks + slot state to the decode
            # tier; this slot frees immediately, so the next queued
            # prompt prefills back-to-back (the tier's whole point)
            self._export_request(slot, req, rec, first, np.asarray(key),
                                 budget)
            return True
        self.slot_req[slot] = req
        self.slot_rec[slot] = rec
        self.slot_remaining[slot] = budget - 1
        self.slot_tokens[slot, 0] = first
        self._tokens_dirty = True           # host wrote a slot: re-upload
        self.slot_key[slot] = np.asarray(key)    # host copy: stacked later
        if getattr(self.backend, "needs_positions", False):
            # the first generated token's mrope position, one past the
            # prompt's layout (text continues all three components)
            self.slot_pos[slot] = tf.mrope_next_position(len(prompt),
                                                         req.grid)
        return True

    # -- disaggregated handoff ----------------------------------------------

    def _export_request(self, slot: int, req: Request,
                        rec: metrics_lib.RequestRecord, first: int,
                        key: np.ndarray, budget: int) -> None:
        """Package the just-prefilled request for the decode tier: snapshot
        the slot's block chain (values + sealed keys) and slot state, then
        release the slot.  The snapshot arrays are immutable, so the blocks
        can be reused here before the decode tier lands the import."""
        bs = self.layout.block_size
        live = len(req.prompt)
        blocks, keys = self.tables.export_slot(slot)
        n_live = -(-live // bs)
        values = self.backend.gather_block_values(self.cache,
                                                  blocks[:n_live])
        state = self.backend.export_slot_state(self.cache, slot)
        pos = 0
        if getattr(self.backend, "needs_positions", False):
            pos = int(tf.mrope_next_position(live, req.grid))
        now = self.clock.now
        h = Handoff(
            req=req, rec=rec, last_token=first, budget=budget, key=key,
            live_tokens=live, blocks=blocks[:n_live], keys=keys[:n_live],
            values=values, slot_state=state, src_pool=self.pool,
            src=self.name, exported_at=now,
            ready_at=now + (self.clock.fixed_handoff_s or 0.0),
            out=self.outputs[req.rid], pos=pos)
        self.tables.release(slot)
        self.pending_handoffs.append(h)
        self.handoffs_out += 1
        self.tracer.instant("pool.handoff", track=self._track("pool"),
                            rid=req.rid, dir="out", blocks=n_live,
                            live_tokens=live)
        if self.metrics is not None:
            self.metrics.counter(f"{self.name}.handoffs_out").inc()
        self._note_load()

    def import_handoff(self, h: Handoff) -> bool:
        """Land a handoff in a free slot: map the exported chain into this
        pool (dedupe via sealed keys / re-refcount when pools are shared),
        scatter the copied block values, restore slot state, and resume
        the request mid-stream.  False when no slot or not enough blocks
        are free yet — the caller retries after retirements."""
        slot = next((s for s in range(self.ecfg.n_slots)
                     if self.slot_req[s] is None), None)
        if slot is None:
            return False
        bs = self.layout.block_size
        span = -(-min(h.live_tokens + h.budget, self.ecfg.max_len) // bs)
        copies = self.tables.import_slot(
            slot, h.blocks, h.keys, h.live_tokens,
            src_pool=h.src_pool, span_blocks=span)
        if copies is None:
            if self.pool.used_blocks == 0:
                raise RuntimeError(
                    f"decode tier pool too small for handoff rid="
                    f"{h.req.rid} ({span} blocks needed, "
                    f"{self.pool.num_blocks} in pool)")
            return False
        if copies:
            self.cache = self.backend.scatter_block_values(
                self.cache, [d for _, d in copies], h.values,
                rows=[i for i, _ in copies])
        self.cache = self.backend.import_slot_state(self.cache, slot,
                                                    h.slot_state)
        self._sync_tables()
        req, rec = h.req, h.rec
        self.outputs[req.rid] = h.out
        self.slot_req[slot] = req
        self.slot_rec[slot] = rec
        self.slot_remaining[slot] = h.budget - 1
        self.slot_tokens[slot, 0] = h.last_token
        self._tokens_dirty = True
        self.slot_key[slot] = h.key
        self._slot_len[slot] = h.live_tokens
        if getattr(self.backend, "needs_positions", False):
            self.slot_pos[slot] = h.pos
        self.handoffs_in += 1
        self.tracer.instant("pool.handoff", track=self._track("pool"),
                            rid=req.rid, dir="in", slot=slot,
                            copied=len(copies), adopted=len(h.blocks) -
                            len(copies))
        # the handoff span sits inside req.decode on the destination slot
        # track: TTFT closed at prefill (first token came from the prefill
        # tier); the transfer is decode-side latency the TPOT report pays
        self.tracer.complete("req.handoff", h.exported_at, self.clock.now,
                             track=self._track(f"slot{slot}"), rid=req.rid,
                             src=h.src, blocks=len(h.blocks))
        if self.metrics is not None:
            self.metrics.counter(f"{self.name}.handoffs_in").inc()
        self._note_load()
        self._note_occupancy()
        return True

    def _drain_inbox(self) -> bool:
        progressed = False
        while self.handoff_inbox:
            if not self.import_handoff(self.handoff_inbox[0]):
                break
            self.handoff_inbox.popleft()
            progressed = True
        if progressed:
            self._note_load()
        return progressed

    @property
    def has_work(self) -> bool:
        return bool(self.n_active or self.queue or self.handoff_inbox)

    def tick(self) -> bool:
        """One non-blocking scheduler step for the multi-engine driver:
        land ready handoffs, refill free slots from the queue, decode once
        if anything is active.  Returns False when nothing moved (the
        engine is blocked waiting on blocks or deliveries)."""
        before = (self.prefills, self.decode_steps, self.handoffs_in,
                  len(self.queue), len(self.handoff_inbox))
        self._drain_inbox()
        self._refill()
        if self.n_active:
            self._decode_once()
        after = (self.prefills, self.decode_steps, self.handoffs_in,
                 len(self.queue), len(self.handoff_inbox))
        return after != before

    # -- refill -------------------------------------------------------------

    def _refill(self) -> None:
        free = [s for s in range(self.ecfg.n_slots)
                if self.slot_req[s] is None]
        if self.ecfg.refill == "static" and len(free) < self.ecfg.n_slots:
            return                              # classical batch barrier
        for s in free:
            while self.queue and self.slot_req[s] is None:
                req, rec = self.queue.popleft()
                if self._start(s, req, rec):    # may finish instantly (EOS)
                    continue
                # paged admission failed: not enough free blocks.  An empty
                # pool that still can't cover the request never will —
                # reject; otherwise park it at the queue head until
                # retiring slots return their blocks (graceful queueing,
                # never corruption)
                if self.pool is not None and self.pool.used_blocks == 0:
                    rec.rejected = True
                    self.tracer.instant("sched.reject",
                                        track=self._track("sched"),
                                        rid=req.rid, reason="pool_too_small")
                    continue
                self.queue.pushback((req, rec))
                self.tracer.instant("sched.pushback",
                                    track=self._track("sched"), rid=req.rid,
                                    free_blocks=self.pool.free_blocks
                                    if self.pool is not None else 0)
                self._note_occupancy()
                return
        self._note_occupancy()

    def _note_occupancy(self) -> None:
        active = self.n_active
        self.max_concurrent = max(self.max_concurrent, active)
        if self.metrics is not None:
            self.metrics.gauge(f"{self.name}.active_slots").set(
                active, t=self.clock.now)

    def _note_load(self) -> None:
        """Per-replica load gauges the router scores on: queued work
        (admission queue + handoff inbox) and the decode tokens still owed
        by active slots.  Stamped with this engine's clock explicitly, so
        N engines sharing one registry keep coherent series."""
        if self.metrics is None:
            return
        t = self.clock.now
        self.metrics.gauge(f"{self.name}.queue_depth").set(
            len(self.queue) + len(self.handoff_inbox), t=t)
        inflight = int(sum(int(self.slot_remaining[s])
                           for s in range(self.ecfg.n_slots)
                           if self.slot_req[s] is not None))
        self.metrics.gauge(f"{self.name}.in_flight_tokens").set(
            inflight, t=t)

    def _note_finish(self, rec: metrics_lib.RequestRecord) -> None:
        if self.win is not None and rec.tpot is not None:
            self.win.observe_tpot(rec.tpot)

    def _decode_once(self) -> None:
        if self.spec_k > 1:
            return self._spec_decode_once()
        if self.tables is not None:
            # make every active slot's KV frontier exclusively owned before
            # the step writes there: COW off shared tails, claim sole-owner
            # sealed blocks, then upload the changed tables once
            for s in range(self.ecfg.n_slots):
                if self.slot_req[s] is None:
                    continue
                cow = self.tables.ensure_writable(s, int(self._slot_len[s]))
                if cow is not None:
                    self.cache = self.backend.copy_block(self.cache, *cow)
                    self.tracer.instant("pool.cow",
                                        track=self._track("pool"), slot=s,
                                        src=cow[0], dst=cow[1])
            self._sync_tables()
        positions = None
        if getattr(self.backend, "needs_positions", False):
            # (n, 1, 3): text decode advances t/h/w together per token
            positions = jnp.asarray(
                np.broadcast_to(self.slot_pos[:, None, None],
                                (self.ecfg.n_slots, 1, 3)), jnp.int32)
        if self._tokens_dirty or self._tokens_dev is None:
            self._tokens_dev = jnp.asarray(self.slot_tokens)
            self._tokens_dirty = False
        tokens = self._tokens_dev
        if positions is None:       # toy/test backends take (cache, tokens)
            call = lambda: self.backend.decode(  # noqa: E731
                self.cache, tokens)
        else:
            call = lambda: self.backend.decode(  # noqa: E731
                self.cache, tokens, positions)
        # span args (roofline-modeled bytes/FLOPs) are only computed when
        # the tracer is live — the disabled path stays one attribute check
        step_t0 = self.clock.now
        step_args = self._decode_model_args() if self.tracer.enabled else None
        logits, self.cache = self._timed(self.clock.fixed_decode_s, call)
        if step_args is not None:
            self.tracer.complete("decode_step", step_t0, self.clock.now,
                                 track=self._track("engine"),
                                 step=self.decode_steps, **step_args)
        self.decode_steps += 1
        self._kv_bytes_sum += self._resident_kv_bytes()
        self.slot_pos += 1
        n = self.ecfg.n_slots
        any_sampled = any(r is not None and r.temperature > 0.0
                          for r in self.slot_req)
        if not any_sampled:
            nxt_dev = _greedy_tokens(logits[:, 0, :])
            nxt = np.asarray(nxt_dev, np.int32)
        else:
            # batched temperature/top-k/categorical over all slots: one
            # device call, one host sync.  Per-slot keys fold with the
            # token index inside the jit, so slot placement and batch
            # composition never change a request's sampled stream (the
            # semantics the scalar sample_token path established).
            temps = np.zeros(n, np.float32)
            topks = np.zeros(n, np.int32)
            counts = np.zeros(n, np.int32)
            keys = np.zeros((n, 2), np.uint32)
            for s in range(n):
                if self.slot_req[s] is None:
                    continue
                temps[s] = self.slot_req[s].temperature
                topks[s] = self.slot_req[s].top_k
                counts[s] = self.slot_rec[s].tokens_out
                keys[s] = self.slot_key[s]
            nxt_dev = _fold_and_sample(logits[:, 0, :], temps, topks,
                                       keys, counts)
            nxt = np.asarray(nxt_dev, np.int32)
        # the sampled tokens are the next step's inputs and are already on
        # device — keep them there instead of re-uploading from host
        self._tokens_dev = nxt_dev[:, None].astype(jnp.int32)
        for s in range(n):
            req, rec = self.slot_req[s], self.slot_rec[s]
            if req is None:
                continue
            tok = int(nxt[s])
            self.outputs[req.rid].append(tok)
            rec.tokens_out += 1
            self.slot_remaining[s] -= 1
            self.slot_tokens[s, 0] = tok
            self._slot_len[s] += 1          # this step's token landed
            if tok == req.eos_id or self.slot_remaining[s] <= 0:
                rec.finished = self.clock.now
                self.slot_req[s] = None
                self.slot_rec[s] = None
                self.slot_key[s] = None
                if self.tables is not None:
                    self.tables.release(s)  # refcounts back to the pool
                self._trace_request(rec, s)
                self._note_finish(rec)
        self._note_load()

    def _spec_decode_once(self) -> None:
        """One speculative scheduler step: self-draft up to ``spec_k - 1``
        continuation tokens per greedy slot, verify all rows in one fused
        k-row decode, commit per-slot accepted prefixes.  Token streams are
        identical to single-step decode by construction (greedy
        verification accepts exactly the prefix row-by-row decode would
        have emitted); sampled slots fall back to one token per step."""
        n, k = self.ecfg.n_slots, self.spec_k
        rows = np.full((n, k), self.ecfg.pad_id, np.int32)
        rows[:, 0] = self.slot_tokens[:, 0]
        q_lens = np.ones(n, np.int64)
        for s in range(n):
            req = self.slot_req[s]
            if req is None:
                continue
            # draft cap: the step writes q_len KV rows at len..len+q_len-1
            # (must fit max_len) and can emit at most the slot's remaining
            # token budget; sampled streams verify nothing — draft 0
            cap = min(k - 1, int(self.slot_remaining[s]) - 1,
                      self.ecfg.max_len - 1 - int(self._slot_len[s]))
            if req.temperature > 0.0:
                cap = 0
            if cap > 0:
                draft = ngram_draft(
                    list(req.prompt) + self.outputs[req.rid], cap)
                rows[s, 1:1 + len(draft)] = draft
                q_lens[s] = 1 + len(draft)
        # shape-bucketed verify: run this step at the smallest power-of-two
        # row count covering the longest draft (1, 2, ... up to spec_k), so
        # short-draft steps pay near single-row cost instead of the full
        # k-row shape.  Each bucket jit-compiles once and is then cached.
        k_step = 1
        while k_step < int(q_lens.max()):
            k_step *= 2
        k_step = min(k_step, k)
        rows = rows[:, :k_step]
        if self.tables is not None:
            # own the whole write span up front: one pass per touched
            # block regardless of k (batched COW)
            for s in range(n):
                if self.slot_req[s] is None:
                    continue
                for src, dst in self.tables.ensure_writable_span(
                        s, int(self._slot_len[s]), int(q_lens[s])):
                    self.cache = self.backend.copy_block(self.cache,
                                                         src, dst)
                    self.tracer.instant("pool.cow",
                                        track=self._track("pool"), slot=s,
                                        src=src, dst=dst)
            self._sync_tables()
        positions = None
        if getattr(self.backend, "needs_positions", False):
            # (n, k_step, 3): text decode advances t/h/w together per row
            pos = self.slot_pos[:, None] + np.arange(k_step)[None, :]
            positions = jnp.asarray(
                np.broadcast_to(pos[:, :, None], (n, k_step, 3)), jnp.int32)
        if hasattr(self.backend, "_decode_spec_packed"):
            # one upload for rows + q_lens (last column), done before the
            # timed call — like the classic path's device-resident tokens,
            # the clock prices the model step, not the host handoff
            packed = jnp.asarray(np.concatenate(
                [rows, q_lens[:, None].astype(np.int32)], axis=1))
            call = lambda: self.backend.decode_spec_packed(  # noqa: E731
                self.cache, packed, positions)
        else:
            tokens = jnp.asarray(rows)
            q_dev = jnp.asarray(q_lens, jnp.int32)
            call = lambda: self.backend.decode_spec(  # noqa: E731
                self.cache, tokens, q_dev, positions)
        step_t0 = self.clock.now
        step_args = self._decode_model_args() if self.tracer.enabled else None
        live_rows = int(q_lens[[s for s in range(n)
                                if self.slot_req[s] is not None]].sum())
        if step_args:
            # the verify pass runs q_len rows per slot through the model:
            # FLOPs scale with live rows, while attn_read_bytes stays the
            # single-step figure (the cache streams once per STEP — the
            # perf win speculative decode is buying)
            step_args["model_flops"] *= live_rows / step_args["n_active"]
            step_args["spec_q_rows"] = live_rows
        logits, accepts_dev, self.cache = self._timed(
            self.clock.fixed_decode_s, call)
        self.decode_steps += 1
        self._kv_bytes_sum += self._resident_kv_bytes()
        emitted_np = np.asarray(_greedy_tokens(logits), np.int64)  # (n, k)
        accepts = np.asarray(accepts_dev, np.int64)
        sampled = None
        if any(r is not None and r.temperature > 0.0
               for r in self.slot_req):
            temps = np.zeros(n, np.float32)
            topks = np.zeros(n, np.int32)
            counts = np.zeros(n, np.int32)
            keys = np.zeros((n, 2), np.uint32)
            for s in range(n):
                if self.slot_req[s] is None:
                    continue
                temps[s] = self.slot_req[s].temperature
                topks[s] = self.slot_req[s].top_k
                counts[s] = self.slot_rec[s].tokens_out
                keys[s] = self.slot_key[s]
            sampled = np.asarray(_fold_and_sample(logits[:, 0, :], temps,
                                                  topks, keys, counts),
                                 np.int32)
        self._tokens_dirty = True       # host builds next step's draft rows
        step_emitted = 0
        self.spec_slot_steps += sum(r is not None for r in self.slot_req)
        self.spec_rows += live_rows
        for s in range(n):
            req, rec = self.slot_req[s], self.slot_rec[s]
            if req is None:
                continue
            a = int(accepts[s])
            if req.temperature > 0.0:
                toks = [int(sampled[s])]       # a == 1 (q_len was 1)
            else:
                toks = [int(t) for t in emitted_np[s, :a]]
            # stop at the first EOS (the device cache over-commits the
            # rows behind it, but a finishing slot's state is discarded)
            eos_at = next((j for j, t in enumerate(toks)
                           if t == req.eos_id), None)
            if eos_at is not None:
                toks = toks[:eos_at + 1]
            self.outputs[req.rid].extend(toks)
            rec.tokens_out += len(toks)
            step_emitted += len(toks)
            self.slot_remaining[s] -= len(toks)
            self._slot_len[s] += a          # device KV frontier: accepts
            self.slot_pos[s] += a
            self.slot_tokens[s, 0] = toks[-1]
            if eos_at is not None or self.slot_remaining[s] <= 0:
                rec.finished = self.clock.now
                self.slot_req[s] = None
                self.slot_rec[s] = None
                self.slot_key[s] = None
                if self.tables is not None:
                    self.tables.release(s)
                self._trace_request(rec, s)
                self._note_finish(rec)
        self._note_load()
        self.spec_tokens += step_emitted
        if step_args is not None:
            self.tracer.complete("decode_step", step_t0, self.clock.now,
                                 track=self._track("engine"),
                                 step=self.decode_steps - 1,
                                 tokens_emitted=step_emitted, **step_args)
        if self.metrics is not None:
            self.metrics.counter("engine.spec_tokens").inc(step_emitted)

    # -- driver --------------------------------------------------------------

    def run(self, requests: Sequence[Request]):
        """Serve a workload to completion.

        Returns (outputs {rid: [token, ...]}, records, summary-dict)."""
        reqs = sorted(requests, key=lambda r: r.arrival)
        i = 0
        while True:
            while i < len(reqs) and reqs[i].arrival <= self.clock.now:
                self.submit(reqs[i])
                i += 1
            self._refill()
            if self.n_active:
                self._decode_once()
                continue
            if self.queue:
                # every slot free + non-empty queue should have refilled
                raise RuntimeError("scheduler stalled with queued work")
            if i < len(reqs):
                self.clock.advance(reqs[i].arrival - self.clock.now)
                continue
            break
        summary = metrics_lib.summarize(self.records, self.clock.now)
        summary["decode_steps"] = self.decode_steps
        summary["prefills"] = self.prefills
        summary["max_concurrent_slots"] = self.max_concurrent
        summary["kv_bytes_per_step"] = (
            self._kv_bytes_sum / max(self.decode_steps, 1))
        if self.spec_k > 1:
            summary["spec"] = {
                "k": self.spec_k,
                "draft": self.ecfg.spec_draft,
                "spec_tokens": self.spec_tokens,
                # per live slot-step: classic decode == 1.0 by definition,
                # so anything above 1 is pure multi-token win
                "accepted_tokens_per_step": (
                    self.spec_tokens / max(self.spec_slot_steps, 1)),
                # verify rows run per live slot-step (1 + mean draft len):
                # the compute-side price the accepts above were bought at
                "verify_rows_per_step": (
                    self.spec_rows / max(self.spec_slot_steps, 1)),
            }
        if self.cf_head is not None:
            summary["cf"] = self.cf_head.summary()
            summary["cf"]["requests_scored_here"] = self.cf_scored
        if self.pool is not None:
            summary["paged"] = {
                "num_blocks": self.pool.num_blocks,
                "block_size": self.pool.block_size,
                "peak_used_blocks": self.pool.peak_used,
                "shared_hits": self.pool.shared_hits,
                "cow_events": self.pool.cow_events,
                "seal_count": self.pool.seal_count,
            }
        if self.tracer.enabled or self.metrics is not None:
            obs: Dict = {}
            if self.tracer.enabled:
                obs["span_counts"] = self.tracer.span_names()
                obs["trace_events"] = len(self.tracer.events)
            if self.metrics is not None:
                obs["metrics"] = self.metrics.snapshot()
            summary["obs"] = obs
        return self.outputs, self.records, summary


def serve(cfg, params, requests: Sequence[Request],
          ecfg: EngineConfig = EngineConfig(),
          ctx: Optional[tf.ModelCtx] = None,
          clock: Optional[Clock] = None,
          tracer: Optional[Tracer] = None,
          metrics: Optional[MetricsRegistry] = None):
    """One-call convenience wrapper: build backend + engine, run, report.

    The cache layout comes from ``ecfg.layout`` (dense/paged, bf16/int8,
    decode impl); ``ecfg.prefill_chunk`` selects streaming prefill.
    ``tracer`` / ``metrics`` flow through to :class:`ServingEngine`.  The
    legacy ``kv=`` kwarg was removed with the PR-6 deprecation shims —
    set ``EngineConfig.layout=CacheLayout(kv_bits=8)``."""
    layout = ecfg.layout
    # only hand make_backend an explicit layout when one was actually
    # chosen — a default layout must not override a caller ctx's decode_impl
    explicit = layout != CacheLayout()
    backend = make_backend(cfg, params, ctx,
                           layout=layout if explicit else None,
                           prefill_chunk=ecfg.prefill_chunk)
    engine = ServingEngine(backend, ecfg, clock, tracer=tracer,
                           metrics=metrics)
    return engine.run(requests)
