"""Recsys request-traffic simulator: reproducible "millions of users"
scenarios scaled down to whatever the host can serve.

Real recommendation traffic is far from i.i.d.:

* arrivals are Poisson at quiet hours but *bursty* around pushes and sales
  events — modeled as a two-state modulated Poisson process (ON periods
  arrive ``burst_factor`` x faster than OFF periods);
* user popularity is Zipfian (a head of power users dominates), so the same
  user histories recur — prompts for one user share a seeded history prefix,
  which is what makes request-level caching worthwhile downstream;
* prompt lengths (user-history length) are Zipf-distributed with a long
  tail clipped to the serving window;
* requests carry an SLO tier: ``interactive`` ranking calls with tight
  TTFT, and ``batch`` re-scoring calls that only care about completion.

Everything is driven by one seed; two calls to :func:`generate` with the
same config produce identical workloads.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SLOTier:
    name: str
    ttft_ms: float
    tpot_ms: float


INTERACTIVE_TIER = SLOTier("interactive", ttft_ms=500.0, tpot_ms=100.0)
BATCH_TIER = SLOTier("batch", ttft_ms=5_000.0, tpot_ms=1_000.0)


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    user_id: int
    prompt: Tuple[int, ...]
    max_new_tokens: int
    arrival: float                      # seconds since sim start
    slo: SLOTier = BATCH_TIER
    eos_id: int = -1                    # -1: never stop early
    temperature: float = 0.0            # <= 0: greedy decode
    top_k: int = 0                      # 0: no top-k filtering
    # encoder-decoder families (whisper): per-request encoder frames
    # (F, d_model) as nested tuples so Request stays hashable/comparable;
    # the engine computes the slot's cross-KV from these at admission.
    frames: Optional[Tuple[Tuple[float, ...], ...]] = None
    # vlm prompts (qwen2-vl): the prompt's leading image-patch grid
    # (grid_h, grid_w) — grid_h*grid_w patch tokens precede the text.
    # Drives the request's multimodal-RoPE position layout at prefill and
    # the per-token position advance at decode.
    grid: Optional[Tuple[int, int]] = None
    # recsys retrieval->rank: the candidate item ids this request asks to
    # be scored (CF head + LM fusion); None = plain LM request.
    candidates: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 64
    rate: float = 32.0                  # mean requests/s
    process: str = "poisson"            # poisson | bursty
    burst_factor: float = 6.0           # ON-state rate multiplier
    burst_switch_p: float = 0.15        # per-arrival state-flip probability
    n_users: int = 10_000
    zipf_users: float = 1.2             # user-popularity skew (>1)
    prompt_min: int = 4
    prompt_max: int = 48
    zipf_prompt: float = 1.4            # prompt-length tail (>1)
    new_tokens_min: int = 4
    new_tokens_max: int = 24
    interactive_fraction: float = 0.75
    vocab_size: int = 256
    eos_id: int = -1
    temperature: float = 0.0            # per-request sampling (0 = greedy)
    top_k: int = 0
    encoder_frames: int = 0             # >0: attach (F, frame_dim) frames
    frame_dim: int = 0                  # (enc-dec families, e.g. whisper)
    frame_scale: float = 0.02
    image_grid: Tuple[int, int] = ()    # (gh, gw): vlm requests carry a
                                        # gh x gw patch-token prompt prefix
    image_fraction: float = 1.0         # share of requests with an image
    # recsys retrieval->rank: candidate ids per request (0 = none).
    # Head-heavy (Zipfian item popularity) — the distribution that makes
    # the hot-row cache pay — and drawn from a separate per-request rng
    # stream, so the base workload stays byte-identical with candidates
    # on or off.
    candidates: int = 0
    zipf_items: float = 1.3             # candidate-popularity skew (>1)
    seed: int = 0


def _bounded_zipf(rng: np.random.Generator, a: float, lo: int, hi: int,
                  size: int) -> np.ndarray:
    """Zipf(a) shifted to [lo, hi] by rejection-free clipping."""
    x = lo - 1 + rng.zipf(a, size=size)
    return np.clip(x, lo, hi)


def _arrival_times(cfg: TrafficConfig, rng: np.random.Generator) -> np.ndarray:
    if cfg.process == "poisson":
        gaps = rng.exponential(1.0 / cfg.rate, size=cfg.n_requests)
    elif cfg.process == "bursty":
        # two-state modulated Poisson: per-arrival geometric state dwell
        gaps = np.empty(cfg.n_requests)
        on = False
        for i in range(cfg.n_requests):
            if rng.random() < cfg.burst_switch_p:
                on = not on
            r = cfg.rate * cfg.burst_factor if on else cfg.rate / 2.0
            gaps[i] = rng.exponential(1.0 / r)
    else:
        raise ValueError(f"unknown arrival process {cfg.process!r}")
    return np.cumsum(gaps)


def _candidate_set(cfg: TrafficConfig, rid: int) -> Tuple[int, ...]:
    """Head-heavy candidate item ids for one request.

    Zipf(``zipf_items``) over the item vocabulary: a popularity-biased
    retrieval stage mostly proposes the same head of hot items across
    requests (repeats across — and occasionally within — a set are the
    point).  The rng is seeded from (seed, rid) alone, never the shared
    workload stream, so turning candidates on/off cannot perturb
    arrivals, users, prompts, or SLO assignment.
    """
    crng = np.random.default_rng((cfg.seed, 0x5EED5, rid))
    ids = _bounded_zipf(crng, cfg.zipf_items, 1, cfg.vocab_size,
                        cfg.candidates) - 1
    return tuple(int(i) for i in ids)


def _user_prompt(cfg: TrafficConfig, user_id: int, length: int,
                 rng: np.random.Generator) -> Tuple[int, ...]:
    """User-history prompt: a per-user deterministic history stream plus a
    fresh per-request suffix (the "new interactions since last visit")."""
    hist_rng = np.random.default_rng(cfg.seed * 1_000_003 + user_id)
    history = hist_rng.integers(3, cfg.vocab_size,
                                size=max(cfg.prompt_max, length))
    fresh = max(1, length // 4)
    suffix = rng.integers(3, cfg.vocab_size, size=fresh)
    tokens = np.concatenate([history[:length - fresh], suffix])
    return tuple(int(t) for t in tokens)


def generate(cfg: TrafficConfig) -> List[Request]:
    """The full workload, sorted by arrival time."""
    if cfg.prompt_max < cfg.prompt_min:
        raise ValueError(f"prompt_max {cfg.prompt_max} < prompt_min "
                         f"{cfg.prompt_min}")
    if cfg.new_tokens_max < cfg.new_tokens_min:
        raise ValueError(f"new_tokens_max {cfg.new_tokens_max} < "
                         f"new_tokens_min {cfg.new_tokens_min}")
    rng = np.random.default_rng(cfg.seed)
    arrivals = _arrival_times(cfg, rng)
    users = _bounded_zipf(rng, cfg.zipf_users, 1, cfg.n_users,
                          cfg.n_requests) - 1
    lengths = _bounded_zipf(rng, cfg.zipf_prompt, cfg.prompt_min,
                            cfg.prompt_max, cfg.n_requests)
    new_tokens = rng.integers(cfg.new_tokens_min, cfg.new_tokens_max + 1,
                              size=cfg.n_requests)
    interactive = rng.random(cfg.n_requests) < cfg.interactive_fraction

    reqs = []
    for i in range(cfg.n_requests):
        frames = None
        if cfg.encoder_frames and cfg.frame_dim:
            f = rng.normal(0.0, cfg.frame_scale,
                           (cfg.encoder_frames, cfg.frame_dim))
            frames = tuple(tuple(float(x) for x in row) for row in f)
        grid = None
        if cfg.image_grid and rng.random() < cfg.image_fraction:
            gh, gw = cfg.image_grid
            if gh * gw < int(lengths[i]):   # patches must leave text room
                grid = (int(gh), int(gw))
        reqs.append(Request(
            rid=i,
            user_id=int(users[i]),
            prompt=_user_prompt(cfg, int(users[i]), int(lengths[i]), rng),
            max_new_tokens=int(new_tokens[i]),
            arrival=float(arrivals[i]),
            slo=INTERACTIVE_TIER if interactive[i] else BATCH_TIER,
            eos_id=cfg.eos_id,
            temperature=cfg.temperature,
            top_k=cfg.top_k,
            frames=frames,
            grid=grid,
            candidates=(_candidate_set(cfg, i) if cfg.candidates > 0
                        else None),
        ))
    return reqs


@dataclasses.dataclass(frozen=True)
class PrefillBurstConfig:
    """Prefill-burst scenario: a steady decode-heavy Zipfian background
    (short prompts, long generations) with a seeded burst of long prompts
    dropped on top at ``burst_start`` — the workload that stalls an
    interleaved engine's in-flight decodes and that disaggregation is
    supposed to absorb.  Burst requests are interactive (tight TTFT) and
    get rids after every background rid so the two streams stay
    distinguishable in traces."""

    background: TrafficConfig = TrafficConfig(
        n_requests=48, rate=24.0, process="poisson",
        prompt_min=4, prompt_max=8,
        new_tokens_min=16, new_tokens_max=24,
        interactive_fraction=0.0)
    burst_n: int = 8                    # long prompts in the burst
    burst_start: float = 0.25           # seconds since sim start
    burst_rate: float = 64.0            # arrivals/s inside the burst
    burst_prompt_min: int = 32
    burst_prompt_max: int = 48
    burst_new_tokens: int = 8
    seed: int = 0


def generate_prefill_burst(cfg: PrefillBurstConfig) -> List[Request]:
    """Background + burst merged and sorted by arrival; fully determined
    by ``cfg`` (the background stream is byte-identical to
    ``generate(cfg.background)`` aside from rid/SLO bookkeeping)."""
    if cfg.burst_prompt_max < cfg.burst_prompt_min:
        raise ValueError(f"burst_prompt_max {cfg.burst_prompt_max} < "
                         f"burst_prompt_min {cfg.burst_prompt_min}")
    background = generate(
        dataclasses.replace(cfg.background, seed=cfg.background.seed))
    rng = np.random.default_rng(cfg.seed + 0x9E3779B9)
    gaps = rng.exponential(1.0 / cfg.burst_rate, size=cfg.burst_n)
    arrivals = cfg.burst_start + np.cumsum(gaps)
    lengths = rng.integers(cfg.burst_prompt_min, cfg.burst_prompt_max + 1,
                           size=cfg.burst_n)
    base_rid = len(background)
    burst = [Request(
        rid=base_rid + i,
        user_id=cfg.background.n_users + i,   # fresh users: no prefix reuse
        prompt=_user_prompt(cfg.background, cfg.background.n_users + i,
                            int(lengths[i]), rng),
        max_new_tokens=cfg.burst_new_tokens,
        arrival=float(arrivals[i]),
        slo=INTERACTIVE_TIER,
        eos_id=cfg.background.eos_id,
        temperature=cfg.background.temperature,
        top_k=cfg.background.top_k,
    ) for i in range(cfg.burst_n)]
    return sorted(background + burst, key=lambda r: (r.arrival, r.rid))


class Clock:
    """Simulated clock the engine advances: by measured model wall time for
    each compute call, and by arbitrary jumps when idle-waiting for the next
    arrival.  Tests can pin per-call costs to get deterministic timelines."""

    def __init__(self, fixed_decode_s: Optional[float] = None,
                 fixed_prefill_s: Optional[float] = None,
                 fixed_handoff_s: Optional[float] = None,
                 fixed_cf_s: Optional[float] = None):
        self.now = 0.0
        self.fixed_decode_s = fixed_decode_s
        self.fixed_prefill_s = fixed_prefill_s
        self.fixed_handoff_s = fixed_handoff_s
        self.fixed_cf_s = fixed_cf_s

    def advance(self, dt: float) -> None:
        assert dt >= 0.0
        self.now += dt
