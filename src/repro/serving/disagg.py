"""Disaggregated prefill/decode serving: N engine replicas + SLO router.

One interleaved engine pays for long prompts twice: the chunk-scan prefill
occupies the same scheduler loop that in-flight decodes depend on, so a
burst of long prompts stalls every active stream and blows p99 TTFT.  The
fix — the topology-aware split of the Disaggregated Multi-Tower paper
applied to LLM serving — is to match tiers to their bottleneck:

* a **prefill tier** (compute-bound: chunked prompt scans, slots free the
  moment the prompt's KV is sealed and exported), and
* a **decode tier** (bandwidth-bound: flash/spec decode over resident KV),

with the KV handoff riding the paged block pool: the prefill engine seals
the prompt's blocks, exports the block chain + pooled values + slot state
(:class:`~repro.serving.engine.Handoff`), and the decode engine maps it
into its own pool — adopting sealed-key matches (prefix dedupe survives
the transfer) and copying the rest — so handoff is O(block-table) and
**token-exact**: the resumed stream is bit-identical to the same request
served by a single interleaved engine.

The :class:`Router` load-balances across replicas using the two-level SLO
admission queue's own signals plus live *windowed* TTFT/TPOT percentiles
(:class:`~repro.serving.metrics.WindowedLatency`, backed by the obs
histogram sample window).  :class:`DisaggServer` advances N engines + the
router coherently on simulated clocks: a conservative event loop always
steps the lowest-clock engine that has work, delivers handoffs only once
the destination clock passes ``ready_at`` (``Clock.fixed_handoff_s``
models the transfer), and jumps idle engines to the next event — so a
pinned-cost run is fully deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer, or_null
from repro.serving import metrics as metrics_lib
from repro.serving.engine import (EngineConfig, Handoff, ServingEngine,
                                  make_backend)
from repro.serving.traffic import Clock, Request

__all__ = ["RouterConfig", "Router", "DisaggServer", "build_disagg",
           "Handoff"]


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Routing policy knobs.

    ``slo`` (default) scores replicas by normalized load plus the
    windowed p99 of the latency the tier is accountable for (TTFT for
    prefill placement, TPOT for decode placement) — a replica whose
    recent tail latency is drifting gets deprioritized before its queue
    even grows.  ``least_loaded`` uses the load term alone;
    ``round_robin`` ignores state entirely.  Ties break on replica
    order, so routing is deterministic."""

    policy: str = "slo"                 # slo | least_loaded | round_robin
    window: int = 64                    # recent samples per percentile
    ttft_weight: float = 1.0            # score-seconds per p99-TTFT second
    tpot_weight: float = 10.0           # score-seconds per p99-TPOT second

    def __post_init__(self):
        if self.policy not in ("slo", "least_loaded", "round_robin"):
            raise ValueError(f"unknown router policy {self.policy!r}")


class Router:
    """Places arrivals on prefill-capable replicas and handoffs on
    decode-capable replicas."""

    def __init__(self, engines: Sequence[ServingEngine],
                 cfg: RouterConfig = RouterConfig()):
        self.cfg = cfg
        self.prefill = [e for e in engines if e.role in ("both", "prefill")]
        self.decode = [e for e in engines if e.role in ("both", "decode")]
        if not self.prefill:
            raise ValueError("router needs at least one prefill-capable "
                             "replica")
        self._rr_p = 0
        self._rr_d = 0

    @staticmethod
    def _p(win, which: str, q: float) -> float:
        if win is None:
            return 0.0
        v = win.ttft_p(q) if which == "ttft" else win.tpot_p(q)
        return 0.0 if v != v else v          # NaN -> no signal yet

    def _prefill_score(self, e: ServingEngine) -> float:
        load = (len(e.queue) + e.n_active) / max(e.ecfg.n_slots, 1)
        return load + self.cfg.ttft_weight * self._p(e.win, "ttft", 99)

    def _decode_score(self, e: ServingEngine) -> float:
        inflight = sum(int(e.slot_remaining[s])
                       for s in range(e.ecfg.n_slots)
                       if e.slot_req[s] is not None)
        inflight += sum(h.budget for h in e.handoff_inbox)
        load = inflight / max(e.ecfg.n_slots * e.ecfg.max_len, 1)
        return load + self.cfg.tpot_weight * self._p(e.win, "tpot", 99)

    def route(self, req: Request) -> ServingEngine:
        """Pick the prefill replica for a new arrival."""
        if self.cfg.policy == "round_robin":
            e = self.prefill[self._rr_p % len(self.prefill)]
            self._rr_p += 1
            return e
        if self.cfg.policy == "least_loaded":
            return min(self.prefill,
                       key=lambda e: (len(e.queue) + e.n_active, e.name))
        return min(self.prefill,
                   key=lambda e: (self._prefill_score(e), e.name))

    def route_decode(self, h: Handoff) -> ServingEngine:
        """Pick the decode replica for a finished prefill."""
        if not self.decode:
            raise RuntimeError("handoff produced but no decode-capable "
                               "replica exists")
        if self.cfg.policy == "round_robin":
            e = self.decode[self._rr_d % len(self.decode)]
            self._rr_d += 1
            return e
        if self.cfg.policy == "least_loaded":
            return min(self.decode,
                       key=lambda e: (e.n_active + len(e.handoff_inbox),
                                      e.name))
        return min(self.decode,
                   key=lambda e: (self._decode_score(e), e.name))


class DisaggServer:
    """Coherent driver over N engine replicas + one router.

    Engines arrive prebuilt (see :func:`build_disagg`), each with its own
    simulated :class:`Clock` and (optionally) its own child
    :class:`Tracer`; ``tracer`` is the main timeline the children merge
    into after the run, and ``metrics`` is the one shared registry every
    replica publishes its ``{name}.*`` gauges into.

    The event loop is conservative discrete-event simulation:

    1. deliver every in-flight handoff whose destination clock has
       reached ``ready_at``;
    2. submit arrivals up to the *frontier* (the minimum engine clock) —
       routing decisions therefore see replica state no older than the
       slowest replica, and never see the future;
    3. step the lowest-clock engine that has work (``tick`` = land
       handoffs, refill, one decode step);
    4. if nothing moved, jump idle clocks to the next event (arrival or
       handoff delivery) — or stop when no work remains.
    """

    def __init__(self, engines: Sequence[ServingEngine],
                 router_cfg: RouterConfig = RouterConfig(),
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if not engines:
            raise ValueError("DisaggServer needs at least one engine")
        names = [e.name for e in engines]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique, got {names}")
        self.engines = list(engines)
        self.router = Router(engines, router_cfg)
        self.tracer = or_null(tracer)
        self.metrics = metrics
        self.handoffs = 0

    def _collect(self, e: ServingEngine,
                 inflight: List[Tuple[Handoff, ServingEngine]]) -> None:
        while e.pending_handoffs:
            h = e.pending_handoffs.popleft()
            target = self.router.route_decode(h)
            self.handoffs += 1
            inflight.append((h, target))

    def run(self, requests: Sequence[Request]):
        """Serve a workload to completion across all replicas.

        Returns (outputs, records, summary) exactly like
        :meth:`ServingEngine.run`, with a ``disagg`` summary section."""
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        inflight: List[Tuple[Handoff, ServingEngine]] = []
        engines = self.engines
        while True:
            progressed = False
            # 1. deliver handoffs whose transfer has completed
            for pair in list(inflight):
                h, d = pair
                if d.clock.now >= h.ready_at:
                    d.handoff_inbox.append(h)
                    d._note_load()
                    inflight.remove(pair)
                    progressed = True
            # 2. route arrivals up to the frontier
            frontier = min(e.clock.now for e in engines)
            while i < len(reqs) and reqs[i].arrival <= frontier:
                self.router.route(reqs[i]).submit(reqs[i])
                i += 1
                progressed = True
            # 3. step the lowest-clock engine with work
            for e in sorted((e for e in engines if e.has_work),
                            key=lambda e: (e.clock.now, e.name)):
                if e.tick():
                    self._collect(e, inflight)
                    progressed = True
                    break
            if progressed:
                continue
            # 4. idle: jump to the next event
            events = [h.ready_at for h, _ in inflight]
            if i < len(reqs):
                events.append(reqs[i].arrival)
            if not events:
                if any(e.has_work for e in engines):
                    raise RuntimeError(
                        "disagg scheduler stalled with queued work")
                break
            t = min(events)
            for e in engines:
                if e.clock.now < t:
                    e.clock.advance(t - e.clock.now)
        return self._finalize()

    def _finalize(self):
        outputs: Dict[int, List[int]] = {}
        records: List[metrics_lib.RequestRecord] = []
        for e in self.engines:
            outputs.update(e.outputs)
            records.extend(e.records)
        records.sort(key=lambda r: r.rid)
        elapsed = max(e.clock.now for e in self.engines)
        summary = metrics_lib.summarize(records, elapsed)
        summary["decode_steps"] = sum(e.decode_steps for e in self.engines)
        summary["prefills"] = sum(e.prefills for e in self.engines)
        summary["max_concurrent_slots"] = max(e.max_concurrent
                                              for e in self.engines)
        per_replica = {}
        for e in self.engines:
            entry = {
                "role": e.role,
                "prefills": e.prefills,
                "decode_steps": e.decode_steps,
                "handoffs_out": e.handoffs_out,
                "handoffs_in": e.handoffs_in,
                "max_concurrent_slots": e.max_concurrent,
                "clock_s": e.clock.now,
            }
            if e.pool is not None:
                entry["paged"] = {
                    "num_blocks": e.pool.num_blocks,
                    "peak_used_blocks": e.pool.peak_used,
                    "shared_hits": e.pool.shared_hits,
                    "cow_events": e.pool.cow_events,
                }
            per_replica[e.name] = entry
        summary["disagg"] = {
            "handoffs": self.handoffs,
            "router_policy": self.router.cfg.policy,
            "replicas": per_replica,
        }
        # merge each replica's child timeline into the main tracer
        if self.tracer.enabled:
            for e in self.engines:
                if e.tracer is not self.tracer and e.tracer.enabled:
                    self.tracer.extend(e.tracer.events)
        if self.tracer.enabled or self.metrics is not None:
            obs: Dict = {}
            if self.tracer.enabled:
                obs["span_counts"] = self.tracer.span_names()
                obs["trace_events"] = len(self.tracer.events)
            if self.metrics is not None:
                obs["metrics"] = self.metrics.snapshot()
            summary["obs"] = obs
        return outputs, records, summary


def build_disagg(cfg, params, *, n_prefill: int = 1, n_decode: int = 1,
                 ecfg: EngineConfig = EngineConfig(),
                 decode_ecfg: Optional[EngineConfig] = None,
                 router_cfg: RouterConfig = RouterConfig(),
                 ctx=None, clock: Optional[Clock] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None) -> DisaggServer:
    """Build a prefill tier + decode tier over one model.

    ``ecfg`` configures the prefill replicas (``decode_ecfg`` defaults to
    the same config for the decode tier — size them apart to match the
    tiers' different bottlenecks).  ``clock`` is a *template*: its pinned
    per-call costs (``fixed_prefill_s`` / ``fixed_decode_s`` /
    ``fixed_handoff_s``) are copied into each replica's private clock.
    Requires a paged layout — the handoff rides the block pool.

    ``n_decode=0`` builds interleaved ``role="both"`` replicas (pure
    multi-replica routing, no tier split)."""
    if not ecfg.layout.paged:
        raise ValueError("disaggregated serving needs a paged layout "
                         "(EngineConfig.layout=CacheLayout(kind='paged'))")
    decode_ecfg = decode_ecfg if decode_ecfg is not None else ecfg
    metrics = metrics if metrics is not None else MetricsRegistry()
    main = or_null(tracer)

    def _clock() -> Clock:
        if clock is None:
            return Clock()
        return Clock(fixed_decode_s=clock.fixed_decode_s,
                     fixed_prefill_s=clock.fixed_prefill_s,
                     fixed_handoff_s=clock.fixed_handoff_s)

    def _tracer() -> Optional[Tracer]:
        return Tracer(enabled=True) if main.enabled else None

    def _engine(name: str, role: str, e: EngineConfig) -> ServingEngine:
        backend = make_backend(cfg, params, ctx, layout=e.layout,
                               prefill_chunk=e.prefill_chunk)
        return ServingEngine(backend, e, _clock(), tracer=_tracer(),
                             metrics=metrics, name=name, role=role)

    engines = []
    if n_decode <= 0:
        engines += [_engine(f"replica{p}", "both", ecfg)
                    for p in range(max(n_prefill, 1))]
    else:
        engines += [_engine(f"prefill{p}", "prefill", ecfg)
                    for p in range(max(n_prefill, 1))]
        engines += [_engine(f"decode{d}", "decode", decode_ecfg)
                    for d in range(n_decode)]
    return DisaggServer(engines, router_cfg, tracer=main, metrics=metrics)
