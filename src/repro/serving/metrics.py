"""Serving latency/throughput metrics with SLO attainment.

All times are seconds on the engine's clock (simulated or wall).  The two
latency quantities mirror standard LLM-serving dashboards:

* TTFT  — time to first token: ``first_token - arrival`` (includes queue
  wait and prefill).
* TPOT  — time per output token after the first:
  ``(finished - first_token) / (tokens_out - 1)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.obs import metrics as obs_metrics


@dataclasses.dataclass
class RequestRecord:
    """Per-request lifecycle timestamps filled in by the engine."""

    rid: int
    user_id: int = 0
    prompt_len: int = 0
    slo_name: str = ""
    ttft_slo_s: float = math.inf
    tpot_slo_s: float = math.inf
    arrival: float = 0.0
    admitted: Optional[float] = None      # prefill started
    first_token: Optional[float] = None   # first generated token emitted
    finished: Optional[float] = None
    tokens_out: int = 0
    rejected: bool = False                # bounded admission queue was full

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.finished is None or self.tokens_out < 2:
            return None
        return (self.finished - self.first_token) / (self.tokens_out - 1)

    @property
    def slo_met(self) -> Optional[bool]:
        if self.finished is None:
            return None
        ok = self.ttft <= self.ttft_slo_s
        if self.tpot is not None:
            ok = ok and self.tpot <= self.tpot_slo_s
        return bool(ok)


# Percentile math lives in repro.obs.metrics now; re-exported here because
# serving callers and tests address it as serving.metrics.percentile.
percentile = obs_metrics.percentile


class WindowedLatency:
    """Sliding-window TTFT/TPOT percentiles over the most recent
    observations, built on the obs histogram's exact sample window.

    The full-run percentiles above summarize everything a run produced;
    a router deciding where to place the *next* request needs the load
    picture of the last few seconds instead.  Each replica owns one of
    these, backed by two registry histograms (``<name>.ttft_window`` /
    ``<name>.tpot_window``) whose ``max_samples`` caps the window, so
    the same numbers show up in the registry snapshot that the trace
    exporter dumps.  While fewer than ``window`` samples have been
    observed the readout is bit-identical to ``np.percentile`` over the
    observed list (the obs histogram stays in exact mode until samples
    age out)."""

    def __init__(self, registry: "obs_metrics.MetricsRegistry",
                 name: str, window: int = 64):
        self.window = int(window)
        self._ttft = registry.histogram(f"{name}.ttft_window",
                                        max_samples=self.window)
        self._tpot = registry.histogram(f"{name}.tpot_window",
                                        max_samples=self.window)

    def observe_ttft(self, s: float) -> None:
        self._ttft.observe(s)

    def observe_tpot(self, s: float) -> None:
        self._tpot.observe(s)

    def ttft_p(self, q: float) -> float:
        """Windowed TTFT percentile; NaN before any sample."""
        return percentile(self._ttft.samples, q) if self._ttft.count else \
            float("nan")

    def tpot_p(self, q: float) -> float:
        """Windowed TPOT percentile; NaN before any sample."""
        return percentile(self._tpot.samples, q) if self._tpot.count else \
            float("nan")


def _dist(xs: List[float]) -> Dict[str, float]:
    """Distribution summary via the obs histogram readout — exact while the
    sample window holds everything, which it always does for serve runs."""
    h = obs_metrics.Histogram()
    for x in xs:
        h.observe(x)
    return h.summary()


def summarize(records: Sequence[RequestRecord],
              elapsed_s: float) -> Dict:
    """Aggregate a serve run into the report printed by the launcher and
    saved by the `serve` benchmark artifact."""
    finished = [r for r in records if r.finished is not None]
    rejected = [r for r in records if r.rejected]
    tokens = sum(r.tokens_out for r in finished)
    ttfts = [r.ttft for r in finished]
    tpots = [r.tpot for r in finished if r.tpot is not None]
    waits = [r.admitted - r.arrival for r in finished
             if r.admitted is not None]

    slo: Dict[str, Dict[str, float]] = {}
    for tier in sorted({r.slo_name for r in finished if r.slo_name}):
        tier_reqs = [r for r in finished if r.slo_name == tier]
        met = sum(1 for r in tier_reqs if r.slo_met)
        slo[tier] = {"requests": len(tier_reqs),
                     "attainment": met / len(tier_reqs)}

    return {
        "requests": len(records),
        "finished": len(finished),
        "rejected": len(rejected),
        "elapsed_s": elapsed_s,
        "tokens_out": tokens,
        "throughput_tok_s": tokens / elapsed_s if elapsed_s > 0 else 0.0,
        "requests_per_s": (len(finished) / elapsed_s
                           if elapsed_s > 0 else 0.0),
        "ttft_s": _dist(ttfts),
        "tpot_s": _dist(tpots),
        "queue_wait_s": _dist(waits),
        "slo": slo,
    }


def format_report(summary: Dict, title: str = "serve") -> str:
    """Human-readable one-screen report."""
    t, p = summary["ttft_s"], summary["tpot_s"]
    lines = [
        f"[{title}] {summary['finished']}/{summary['requests']} requests "
        f"({summary['rejected']} rejected), "
        f"{summary['tokens_out']} tokens in {summary['elapsed_s']:.2f}s",
        f"  throughput  {summary['throughput_tok_s']:.1f} tok/s, "
        f"{summary['requests_per_s']:.1f} req/s",
        f"  ttft  p50 {t['p50'] * 1e3:.1f}ms  p95 {t['p95'] * 1e3:.1f}ms  "
        f"p99 {t['p99'] * 1e3:.1f}ms",
        f"  tpot  p50 {p['p50'] * 1e3:.1f}ms  p95 {p['p95'] * 1e3:.1f}ms  "
        f"p99 {p['p99'] * 1e3:.1f}ms",
    ]
    for tier, s in summary["slo"].items():
        lines.append(f"  slo[{tier}]  {s['attainment'] * 100:.0f}% "
                     f"of {s['requests']} requests")
    return "\n".join(lines)
