"""Shared KV block pool with refcounted prefix sharing and copy-on-write.

The paged cache layout splits a slot's KV rows into fixed-size blocks that
live in one shared pool array ``(num_blocks, block_size, ...)`` per pooled
leaf; each serving slot owns only a *block table* — a row of physical block
ids covering its virtual positions.  Three host-side pieces implement the
vLLM-style management:

:class:`BlockPool`
    alloc/free with per-block refcounts, plus a hash index over *sealed*
    blocks (immutable, content-addressed by a chained prompt-block hash) so
    a new request whose prompt prefix was already prefetched can adopt the
    existing physical blocks instead of recomputing and re-storing them.

:class:`SlotTables`
    the per-slot **read** and **write** tables.  The read table is what the
    attention kernels consume; the write table redirects any store into a
    block the slot does not exclusively own to the reserved *null block 0*
    (a garbage sink — sealed prefix blocks are therefore physically
    immutable while shared).  Copy-on-write happens lazily at the first
    divergent token: :meth:`SlotTables.ensure_writable` notices the frontier
    block is shared, allocates a private copy destination, and reports the
    ``(src, dst)`` pair for the device-side block copy.

:func:`prefix_keys`
    the chained content hash: block ``i``'s key commits to every token of
    blocks ``0..i`` (and a model seed), so equal keys imply equal live KV
    content given the deterministic prefill path.  A *tail key* covering
    the whole prompt lets two requests with identical complete prompts
    share even the final partial block — the case that exercises COW on the
    very first generated token.

Everything here is plain Python/numpy on the host; the device only ever
sees the (n_slots, blocks_per_slot) int32 tables and pooled leaf arrays.
"""
from __future__ import annotations

import hashlib
from collections import deque
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["BlockPool", "SlotTables", "prefix_keys"]

NULL_BLOCK = 0


def _chain(prev: int, payload) -> int:
    h = hashlib.blake2b(repr((prev, payload)).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


def prefix_keys(prompt: Sequence[int], block_size: int,
                seed: object = None) -> Tuple[List[int], Optional[int]]:
    """Content keys for a prompt: one per *complete* block (chained, so key
    ``i`` commits to all tokens ``<= (i+1)*block_size``), plus a tail key
    covering the whole prompt when it ends mid-block (None on an exact
    block boundary).  ``seed`` distinguishes cache namespaces — model
    identity, and for encoder-decoder families a digest of the encoder
    frames, since whisper's self-KV rows depend on the prompt alone but
    live alongside per-request cross-state the scheduler must not mix."""
    prompt = [int(t) for t in prompt]
    acc = _chain(0, seed)
    keys = []
    n_full = len(prompt) // block_size
    for i in range(n_full):
        acc = _chain(acc, tuple(prompt[i * block_size:(i + 1) * block_size]))
        keys.append(acc)
    rem = prompt[n_full * block_size:]
    tail = _chain(acc, ("tail", tuple(rem))) if rem else None
    return keys, tail


class BlockPool:
    """Refcounted allocator over ``num_blocks`` physical blocks.

    Block 0 is the reserved null sink: never allocated, never freed; dead
    or redirected table entries point at it.  ``cow_debt`` counts shared
    *tail* adoptions whose private copy has not happened yet — each one
    will need a block at its first divergent token, so :meth:`can_alloc`
    holds that many blocks back to make the deferred copy infallible."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is the null sink)")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.refcount = np.zeros(self.num_blocks, np.int32)
        self.refcount[NULL_BLOCK] = 1        # permanently resident
        self._free = deque(range(1, self.num_blocks))
        self._by_hash = {}                   # key -> sealed block id
        self._hash_of = {}                   # sealed block id -> key
        self.cow_debt = 0
        # stats (surfaced in the serve artifact)
        self.peak_used = 0
        self.shared_hits = 0
        self.cow_events = 0
        self.seal_count = 0
        # optional obs registry mirror (attach_metrics)
        self._metrics = None
        self._mprefix = "pool"
        self._mclock = None

    def attach_metrics(self, registry, prefix: str = "pool",
                       clock=None) -> None:
        """Mirror pool occupancy and sharing stats into an obs
        :class:`~repro.obs.metrics.MetricsRegistry`: a ``{prefix}.used_blocks``
        gauge (its ``peak`` tracks ``peak_used``) plus
        ``shared_hits`` / ``cow_events`` / ``seal_count`` counters.  The
        gauge series is stamped by the registry's clock — the engine pins
        that to its simulated clock, so the occupancy timeline aligns with
        the request spans.  ``clock`` overrides the registry clock for the
        gauge stamps (several engines sharing one registry each pass their
        own simulated clock)."""
        self._metrics = registry
        self._mprefix = prefix
        self._mclock = clock
        self._sync_metrics()

    def _sync_metrics(self) -> None:
        m, p = self._metrics, self._mprefix
        if m is None:
            return
        m.gauge(f"{p}.used_blocks").set(
            self.used_blocks,
            t=self._mclock() if self._mclock is not None else None)
        m.counter(f"{p}.shared_hits").value = float(self.shared_hits)
        m.counter(f"{p}.cow_events").value = float(self.cow_events)
        m.counter(f"{p}.seal_count").value = float(self.seal_count)

    def note_shared_hit(self) -> None:
        """One prefix-share adoption (called by :class:`SlotTables`)."""
        self.shared_hits += 1
        self._sync_metrics()

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) - self.cow_debt >= n

    def alloc(self, *, for_cow: bool = False) -> int:
        """Pop one free block at refcount 1.  ``for_cow=True`` spends a
        reserved debt slot (always succeeds while the invariant holds)."""
        if not self._free:
            raise RuntimeError("block pool exhausted (reservation bug)")
        b = self._free.popleft()
        self.refcount[b] = 1
        self.peak_used = max(self.peak_used, self.used_blocks)
        if for_cow:
            self.cow_events += 1
        self._sync_metrics()
        return b

    def incref(self, b: int) -> None:
        if b != NULL_BLOCK:
            self.refcount[b] += 1

    def decref(self, b: int) -> None:
        if b == NULL_BLOCK:
            return
        self.refcount[b] -= 1
        if self.refcount[b] < 0:
            raise RuntimeError(f"refcount underflow on block {b}")
        if self.refcount[b] == 0:
            key = self._hash_of.pop(b, None)
            if key is not None and self._by_hash.get(key) == b:
                del self._by_hash[key]
            self._free.append(b)
            self._sync_metrics()

    def seal(self, b: int, key: int) -> None:
        """Publish block ``b`` under content ``key`` (first writer wins;
        a racing duplicate simply stays private and retires normally)."""
        if key not in self._by_hash and b not in self._hash_of:
            self._by_hash[key] = b
            self._hash_of[b] = key
            self.seal_count += 1
            self._sync_metrics()

    def lookup(self, key: int) -> Optional[int]:
        return self._by_hash.get(key)

    def is_sealed(self, b: int) -> bool:
        return b in self._hash_of


class SlotTables:
    """Per-slot read/write block tables over one :class:`BlockPool`.

    ``read[s, i]`` is the physical block backing slot ``s``'s virtual block
    ``i`` — what the paged attention kernels index.  ``write[s, i]`` is
    where *stores* for that virtual block go: equal to ``read`` when the
    slot exclusively owns the block, else :data:`NULL_BLOCK` so scatters
    into shared (sealed) blocks land in the garbage sink.  ``dirty`` flips
    whenever either table changes, so the engine re-uploads to device only
    on mutation."""

    def __init__(self, pool: BlockPool, n_slots: int, blocks_per_slot: int):
        self.pool = pool
        self.n_slots = int(n_slots)
        self.blocks_per_slot = int(blocks_per_slot)
        self.read = np.full((n_slots, blocks_per_slot), NULL_BLOCK, np.int32)
        self.write = np.full((n_slots, blocks_per_slot), NULL_BLOCK, np.int32)
        # virtual-block index of a shared tail adopted at admit() and not
        # yet resolved (COW'd / claimed); -1 when none.  Each pending tail
        # accounts for one unit of pool.cow_debt.
        self._pending_tail = np.full(n_slots, -1, np.int64)
        # keys of blocks this slot computed itself, sealed after prefill
        self._own_keys = [None] * n_slots
        self.dirty = True

    # -- admission ---------------------------------------------------------

    def admit(self, slot: int, full_keys: Sequence[int],
              tail_key: Optional[int], span_blocks: int) -> bool:
        """Map ``span_blocks`` virtual blocks for ``slot``: adopt the
        longest sealed prefix chain (shared, read-only), then allocate
        private blocks for the rest.  Returns False — with *nothing*
        mutated — when the pool cannot cover the private blocks plus the
        standing COW reservation; the engine requeues the request."""
        assert span_blocks <= self.blocks_per_slot
        shared = 0
        for k in full_keys:
            if self.pool.lookup(k) is None:
                break
            shared += 1
        tail_block = None
        if (tail_key is not None and shared == len(full_keys)
                and shared < span_blocks):
            tail_block = self.pool.lookup(tail_key)
        # a shared tail trades an alloc now for one unit of cow_debt, so the
        # net requirement is unchanged: span - shared full blocks
        new_needed = span_blocks - shared - (1 if tail_block is not None else 0)
        reserve = 1 if tail_block is not None else 0
        if len(self.pool._free) - self.pool.cow_debt < new_needed + reserve:
            return False
        row_r, row_w = self.read[slot], self.write[slot]
        for i in range(shared):
            b = self.pool.lookup(full_keys[i])
            self.pool.incref(b)
            row_r[i], row_w[i] = b, NULL_BLOCK
            self.pool.note_shared_hit()
        nxt = shared
        if tail_block is not None:
            self.pool.incref(tail_block)
            row_r[nxt], row_w[nxt] = tail_block, NULL_BLOCK
            self._pending_tail[slot] = nxt
            self.pool.cow_debt += 1
            self.pool.note_shared_hit()
            nxt += 1
        for i in range(nxt, span_blocks):
            b = self.pool.alloc()
            row_r[i], row_w[i] = b, b
        self._own_keys[slot] = (list(full_keys[shared:]),
                                tail_key if tail_block is None else None,
                                shared, span_blocks)
        self.dirty = True
        return True

    def seal_prompt(self, slot: int) -> None:
        """After prefill lands, publish this slot's self-computed complete
        prompt blocks (and whole-prompt tail) in the pool's hash index so
        later identical prefixes share them."""
        if self._own_keys[slot] is None:
            return
        keys, tail_key, start, span = self._own_keys[slot]
        row = self.read[slot]
        for j, k in enumerate(keys):
            self.pool.seal(int(row[start + j]), k)
        if tail_key is not None and start + len(keys) < span:
            self.pool.seal(int(row[start + len(keys)]), tail_key)
        self._own_keys[slot] = None

    # -- write path --------------------------------------------------------

    def ensure_writable(self, slot: int,
                        pos: int) -> Optional[Tuple[int, int]]:
        """Make virtual position ``pos`` of ``slot`` writable before the
        next token lands there.  Three cases:

        * already exclusively owned — no-op, returns None;
        * shared with others (refcount > 1) — **copy-on-write**: allocate a
          private block from the COW reserve and return ``(src, dst)`` so
          the engine copies the block's rows on device before redirecting;
        * sole owner of a previously-shared block (other sharers retired or
          COW'd away) — claim it in place, no copy needed.
        """
        return self._ensure_block(slot, pos // self.pool.block_size)

    def ensure_writable_span(self, slot: int, start: int,
                             count: int) -> List[Tuple[int, int]]:
        """Make the ``count`` virtual positions ``[start, start + count)``
        writable in one pass — the multi-token (speculative) twin of
        :meth:`ensure_writable`.  Each touched block is resolved exactly
        once, so a k-token span costs at most one copy per distinct block
        it crosses regardless of ``k``.  Returns the (src, dst) COW pairs
        the engine must copy on device, oldest block first."""
        if count <= 0:
            return []
        bs = self.pool.block_size
        pairs = []
        for i in range(start // bs, (start + count - 1) // bs + 1):
            pair = self._ensure_block(slot, i)
            if pair is not None:
                pairs.append(pair)
        return pairs

    def _ensure_block(self, slot: int,
                      i: int) -> Optional[Tuple[int, int]]:
        b = int(self.read[slot, i])
        if b != NULL_BLOCK and int(self.write[slot, i]) == b:
            return None
        out = None
        if b == NULL_BLOCK:
            dst = self.pool.alloc(for_cow=self._pending_tail[slot] == i)
            self.read[slot, i] = self.write[slot, i] = dst
        elif int(self.pool.refcount[b]) > 1:
            dst = self.pool.alloc(for_cow=True)
            self.read[slot, i] = self.write[slot, i] = dst
            self.pool.decref(b)
            out = (b, dst)
        else:
            # sole owner of a sealed block: un-publish and claim in place
            key = self.pool._hash_of.pop(b, None)
            if key is not None and self.pool._by_hash.get(key) == b:
                del self.pool._by_hash[key]
            self.write[slot, i] = b
        if self._pending_tail[slot] == i:
            self._pending_tail[slot] = -1
            self.pool.cow_debt -= 1
        self.dirty = True
        return out

    # -- handoff (disaggregated prefill -> decode) -------------------------

    def export_slot(self, slot: int) -> Tuple[List[int], List[Optional[int]]]:
        """Snapshot ``slot``'s block chain for handoff: the physical block
        ids of its allocated span (in virtual order) and, per block, the
        sealed content key (None for private/unsealed blocks).  Pure read
        — the caller copies the block *values* off the chain and then
        :meth:`release`\\ s the slot as usual."""
        blocks: List[int] = []
        for i in range(self.blocks_per_slot):
            b = int(self.read[slot, i])
            if b == NULL_BLOCK:
                break
            blocks.append(b)
        keys = [self.pool._hash_of.get(b) for b in blocks]
        return blocks, keys

    def import_slot(self, slot: int, blocks: Sequence[int],
                    keys: Sequence[Optional[int]], live_tokens: int,
                    src_pool: Optional[BlockPool] = None,
                    span_blocks: Optional[int] = None,
                    ) -> Optional[List[Tuple[int, int]]]:
        """Map an exported block chain into ``slot`` of this table.

        Two modes, mirroring :meth:`admit`'s sharing semantics so a
        handed-off request is indistinguishable from one admitted here:

        * **shared pool** (``src_pool is self.pool``): re-refcount — every
          block of the chain is adopted read-only (``write = NULL``); the
          first write claims-in-place or COWs exactly as a prefix-share
          adoption would.  O(span) increfs, zero copies.
        * **cross pool**: blocks whose sealed key already exists here are
          adopted from *this* pool's hash index (prefix dedupe survives
          the transfer); the rest are freshly allocated and reported as
          ``(virtual_block, dst_physical)`` pairs whose values the engine
          must scatter from the handoff snapshot.  Live blocks keep their
          seal keys (re-sealed here); blocks past ``live_tokens`` are
          garbage pre-reservations and are allocated without a copy.

        A shared *frontier* block (the partial block the next generated
        token lands in) books one unit of ``cow_debt`` — same reservation
        :meth:`admit` makes for a shared tail — so the deferred COW can
        never fail.  ``span_blocks`` extends the mapping past the exported
        chain with fresh private blocks (the decode-budget reservation
        :meth:`admit` would have made), keeping generation infallible once
        the import lands.  Returns None, with nothing mutated, when this
        pool cannot cover the new blocks plus reservations."""
        span = max(len(blocks), span_blocks or 0)
        assert span <= self.blocks_per_slot
        bs = self.pool.block_size
        n_live = -(-live_tokens // bs)
        frontier = live_tokens // bs if live_tokens % bs else -1
        shared_mode = src_pool is self.pool

        # mutation-free capacity plan
        adopt: List[Optional[int]] = [None] * span
        new_needed = 0
        reserve = 0
        for i in range(span):
            if i >= len(blocks):
                new_needed += 1          # budget extension: fresh, no copy
                continue
            if shared_mode:
                if i == frontier:
                    reserve = 1
                continue
            ex = (self.pool.lookup(keys[i])
                  if i < n_live and keys[i] is not None else None)
            if ex is not None:
                adopt[i] = ex
                if i == frontier:
                    reserve = 1
            else:
                new_needed += 1
        if len(self.pool._free) - self.pool.cow_debt < new_needed + reserve:
            return None

        row_r, row_w = self.read[slot], self.write[slot]
        copies: List[Tuple[int, int]] = []
        for i in range(span):
            if i < len(blocks) and shared_mode:
                b = int(blocks[i])
                self.pool.incref(b)
                row_r[i], row_w[i] = b, NULL_BLOCK
                continue
            if adopt[i] is not None:
                self.pool.incref(adopt[i])
                row_r[i], row_w[i] = adopt[i], NULL_BLOCK
                self.pool.note_shared_hit()
                continue
            dst = self.pool.alloc()
            row_r[i], row_w[i] = dst, dst
            if i < n_live and i < len(blocks):
                copies.append((i, dst))
                if keys[i] is not None:
                    self.pool.seal(dst, keys[i])
        if reserve:
            self._pending_tail[slot] = frontier
            self.pool.cow_debt += 1
        self._own_keys[slot] = None
        self.dirty = True
        return copies

    # -- retirement --------------------------------------------------------

    def release(self, slot: int) -> None:
        for i in range(self.blocks_per_slot):
            b = int(self.read[slot, i])
            if b != NULL_BLOCK:
                self.pool.decref(b)
        self.read[slot].fill(NULL_BLOCK)
        self.write[slot].fill(NULL_BLOCK)
        if self._pending_tail[slot] >= 0:
            self._pending_tail[slot] = -1
            self.pool.cow_debt -= 1
        self._own_keys[slot] = None
        self.dirty = True
