"""CF head serving: retrieval->rank candidate scoring inside the engine.

The paper's deployment target is an LLM *recommender*: a request is not
just a prompt, it is (user id, candidate item set, interaction history).
This module scores the candidates through the row/col/2D-sharded CF factor
tables — the same ``cf_user`` / ``cf_item`` tables the recsys trainer
shards — and fuses the CF scores with the LM's next-item logits through
:func:`repro.recsys.model.fuse`, the gate both sides of the system share.

The perf core is :class:`repro.embeddings.serving.CachedLookup`: a
frequency-tracked replicated copy of each table's hot head serves cache
hits with zero cross-shard bytes; only the cold tail pays the shard_map
psum / all-to-all.  Scoring is layout- and family-agnostic — the head only
needs the request's last-position LM logits row, which every engine
backend's prefill produces.

    head = CFHead.build(n_users=10_000, n_items=vocab, plan="row",
                        mesh=mesh, cache_rows=256)
    engine = ServingEngine(backend, ecfg, cf_head=head)

Per request the engine calls :meth:`CFHead.score`, which returns the fused
candidate scores and the ranking; cached and uncached configurations are
bit-identical (see the exactness tests), so the cache is purely a comms
optimization.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.embeddings import EmbedSpec, init_table, make_plan
from repro.embeddings.serving import CacheConfig, CachedLookup


@dataclasses.dataclass(frozen=True)
class CFConfig:
    """Placement + cache knobs of the serving CF head."""

    plan: str = "replicated"        # replicated | row | col | row_col
    cache_rows: int = 0             # hot-row replica capacity (0 = off)
    decay: float = 0.98
    elect_every: int = 1
    miss_quantum: int = 8
    row_axis: str = "model"
    col_axis: str = "data"


class CFHead:
    """Sharded CF scoring head for the serving engine.

    Owns the ``cf_user`` / ``cf_item`` tables (each behind a
    :class:`CachedLookup`) and the fusion gate.  ``score`` is one
    retrieval->rank step: look up the user's factor row and the candidate
    item rows, dot them into CF scores, fuse with the LM's last-position
    logits at the candidate ids, rank.
    """

    def __init__(self, user_table, item_table, fusion_gate=0.0,
                 cfg: CFConfig = CFConfig(), mesh: Optional[Mesh] = None):
        u = np.asarray(user_table, np.float32)
        it = np.asarray(item_table, np.float32)
        if u.shape[1] != it.shape[1]:
            raise ValueError(f"cf_dim mismatch: user {u.shape} vs "
                             f"item {it.shape}")
        self.cfg = cfg
        self.fusion_gate = jnp.asarray(fusion_gate, jnp.float32)
        plan = make_plan(cfg.plan, row_axis=cfg.row_axis,
                         col_axis=cfg.col_axis)
        cache = CacheConfig(rows=cfg.cache_rows, decay=cfg.decay,
                            elect_every=cfg.elect_every,
                            miss_quantum=cfg.miss_quantum)
        self.lookups: Dict[str, CachedLookup] = {
            "cf_user": CachedLookup(
                EmbedSpec("cf_user", rows=u.shape[0], dim=u.shape[1]),
                plan, u, mesh=mesh, cache=cache),
            "cf_item": CachedLookup(
                EmbedSpec("cf_item", rows=it.shape[0], dim=it.shape[1]),
                plan, it, mesh=mesh, cache=cache),
        }
        self.requests_scored = 0

    @classmethod
    def build(cls, n_users: int, n_items: int, cf_dim: int = 16, *,
              seed: int = 0, plan: str = "replicated", cache_rows: int = 0,
              mesh: Optional[Mesh] = None, fusion_gate: float = 0.0,
              **knobs) -> "CFHead":
        """Fresh factor tables (the :func:`repro.embeddings.init_table`
        convention) under one plan; ``knobs`` feed :class:`CFConfig`."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        u = init_table(k1, EmbedSpec("cf_user", rows=n_users, dim=cf_dim))
        it = init_table(k2, EmbedSpec("cf_item", rows=n_items, dim=cf_dim))
        cfg = CFConfig(plan=plan, cache_rows=cache_rows, **knobs)
        return cls(u, it, fusion_gate=fusion_gate, cfg=cfg, mesh=mesh)

    # -- scoring --------------------------------------------------------------

    def score(self, user_id: int, candidates: Sequence[int],
              lm_logits_row=None) -> Dict:
        """One retrieval->rank step.

        ``lm_logits_row`` is the request's last-position (V,) LM logits
        from prefill; ``None`` ranks on CF scores alone (pure retrieval).
        Returns numpy arrays so the engine can store/compare them without
        device transfers: ``cf`` (C,), ``fused`` (C,), ``ranking`` (the
        candidate ids, best first), plus cache hit/miss counts for this
        call.
        """
        from repro.recsys import model as rec_model
        cand = np.asarray(candidates, np.int64).reshape(-1)
        u_rows, u_stats = self.lookups["cf_user"](np.asarray([user_id]))
        i_rows, i_stats = self.lookups["cf_item"](cand)
        cf = i_rows @ u_rows[0]                          # (C,) f32
        if lm_logits_row is not None:
            lm = np.asarray(lm_logits_row, np.float32)[cand]
        else:
            lm = np.zeros_like(cf)
        fused = np.asarray(rec_model.fuse(jnp.asarray(lm), jnp.asarray(cf),
                                          self.fusion_gate))
        order = np.argsort(-fused, kind="stable")
        self.requests_scored += 1
        return {
            "cf": cf, "fused": fused,
            "ranking": cand[order],
            "hits": u_stats["hits"] + i_stats["hits"],
            "misses": u_stats["misses"] + i_stats["misses"],
        }

    # -- table updates --------------------------------------------------------

    def update_rows(self, table: str, ids, rows,
                    refresh: bool = True) -> np.ndarray:
        """Land a trainer update on one table (rows-touched refresh of the
        hot-row replica unless ``refresh=False``)."""
        return self.lookups[table].update_rows(ids, rows, refresh=refresh)

    def refresh_touched(self, table: str, touched) -> None:
        self.lookups[table].refresh_touched(touched)

    # -- accounting -----------------------------------------------------------

    @property
    def hits(self) -> int:
        return sum(lk.hits for lk in self.lookups.values())

    @property
    def misses(self) -> int:
        return sum(lk.misses for lk in self.lookups.values())

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    @property
    def cache_rows_live(self) -> int:
        return sum(lk.n_cached for lk in self.lookups.values())

    def summary(self) -> Dict:
        return {
            "plan": self.cfg.plan,
            "cache_rows": self.cfg.cache_rows,
            "cache_rows_live": self.cache_rows_live,
            "requests_scored": self.requests_scored,
            "hits": self.hits, "misses": self.misses,
            "hit_rate": self.hit_rate,
            "tables": {n: lk.summary() for n, lk in self.lookups.items()},
        }
