"""Modeled TPU-scale serving roofline (the serve artifact's derived terms).

The host-CPU ``serve`` benchmark measures reduced-arch wall times; this
module models what the *full* architecture's decode step costs on the
TPU-v5e hardware model in :mod:`repro.config` — the serving twin of the
training artifacts' modeled collective terms.  Per engine step:

* compute term — :func:`repro.core.hybrid.decode_model_flops`: active-param
  matmuls plus attention over each slot's live cache positions;
* memory term — the bytes a decode step must stream from HBM: the active
  parameters plus every slot's **resident decode state**, which is exactly
  what the family-polymorphic state layouts size (full KV rows for uniform
  decoders, window-bounded ring rows for gemma's local layers, O(1)
  recurrent rows for mamba/rwkv6, self-KV + encoder-frame cross-KV for
  whisper).

``kv_bits=8`` prices the int8 composition: one byte per element plus a f32
scale per (position, head) — the knob that halves the memory term for
KV-dominated families and does nothing for rwkv6 (no KV to quantize).
"""
from __future__ import annotations

import math
from typing import Dict, Sequence

from repro.config import (ArchConfig, HBM_BW, ICI_BW_PER_LINK,
                          PEAK_FLOPS_BF16)


def _kv_pos_bytes(head_dim: int, n_kv: int, kv_bits: int) -> float:
    """Bytes per cached (position, k+v) across the kv heads."""
    if kv_bits == 8:
        per_head = head_dim + 4          # int8 values + one f32 scale
    elif kv_bits == 16:
        per_head = 2 * head_dim
    else:
        raise ValueError(f"kv_bits must be 8 or 16, got {kv_bits}")
    return 2 * n_kv * per_head           # k and v


def decode_state_bytes(cfg: ArchConfig, cache_len: int,
                       kv_bits: int = 16) -> float:
    """Resident decode-state bytes for ONE slot at ``cache_len`` positions."""
    dt = 2                               # model dtype (bf16) itemsize
    kv_pos = _kv_pos_bytes(cfg.head_dim, cfg.num_kv_heads, kv_bits)
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "attn":
            total += cache_len * kv_pos
        elif kind == "local_attn":
            total += min(cache_len, cfg.sliding_window or cache_len) * kv_pos
        elif kind == "mamba":
            d_in = cfg.ssm_expand * cfg.d_model
            total += (cfg.ssm_d_conv - 1) * d_in * dt       # conv window
            total += d_in * cfg.ssm_d_state * 4             # f32 ssm state
        elif kind == "rwkv6":
            hs = cfg.rwkv_head_size
            total += (cfg.d_model // hs) * hs * hs * 4      # f32 wkv state
            total += 2 * cfg.d_model * dt                   # shift states
        else:
            raise ValueError(kind)
    if cfg.encoder_layers:               # per-decoder-layer cross-KV rows
        total += cfg.num_layers * cfg.encoder_frames * kv_pos
    return total


def _paged_split_bytes(cfg: ArchConfig, max_len: int, kv_bits: int):
    """(bytes per pooled KV *position*, per-slot bytes of state that stays
    slot-resident under the paged layout).

    Only full-cache self-attention rows page (linear append-at-``len``
    semantics); window-bounded rings, recurrent rows and whisper's
    cross-KV stay slot-resident — they are already live-bounded, so the
    paged layout leaves them dense (see ``serving.engine.PagedSlots``)."""
    kv_pos = _kv_pos_bytes(cfg.head_dim, cfg.num_kv_heads, kv_bits)
    n_full_attn = sum(1 for kind in cfg.layer_kinds() if kind == "attn")
    paged_pos = n_full_attn * kv_pos
    resident = decode_state_bytes(cfg, max_len, kv_bits) \
        - max_len * paged_pos
    return paged_pos, resident


def kv_block_bytes(cfg: ArchConfig, layout) -> float:
    """Bytes one physical pool block holds across the paged layers."""
    paged_pos, _ = _paged_split_bytes(cfg, layout.block_size,
                                      layout.kv_bits)
    return layout.block_size * paged_pos


def resident_kv_bytes(cfg: ArchConfig, n_slots: int, max_len: int,
                      layout, used_blocks=None) -> float:
    """Resident decode-state bytes of a serving batch under ``layout``.

    Dense: every slot pins ``max_len`` KV rows whether live or not.
    Paged: the pooled layers cost only the blocks actually mapped
    (``used_blocks``; the whole pool when None — the allocation
    footprint), plus the per-slot resident remainder."""
    if not getattr(layout, "paged", False):
        return n_slots * decode_state_bytes(cfg, max_len, layout.kv_bits)
    paged_pos, resident = _paged_split_bytes(cfg, max_len, layout.kv_bits)
    if used_blocks is None:
        from repro.cache_layout import resolved_num_blocks
        used_blocks = resolved_num_blocks(layout, n_slots, max_len) - 1
    return (used_blocks * layout.block_size * paged_pos
            + n_slots * resident)


def max_concurrent_slots(cfg: ArchConfig, hbm_budget_bytes: float,
                         max_len: int, mean_live_len: int,
                         layout) -> int:
    """How many slots one HBM budget admits under ``layout`` — the
    admission-capacity model the serve artifact and the CI paged gate
    compare across layouts.

    Dense reserves ``max_len`` rows per slot up front; paged maps only the
    blocks a request's live prefix needs (``ceil(mean_live_len /
    block_size)`` blocks), so the same budget admits more concurrent
    requests whenever prompts run shorter than the serving window —
    exactly the fragmentation the block pool reclaims."""
    if not getattr(layout, "paged", False):
        per_slot = decode_state_bytes(cfg, max_len, layout.kv_bits)
        return int(hbm_budget_bytes // max(per_slot, 1.0))
    paged_pos, resident = _paged_split_bytes(cfg, max_len, layout.kv_bits)
    live = max(1, min(int(mean_live_len), max_len))
    blocks = math.ceil(live / layout.block_size)
    per_slot = blocks * layout.block_size * paged_pos + resident
    return int(hbm_budget_bytes // max(per_slot, 1.0))


def decode_attn_read_bytes(cfg: ArchConfig, lengths: Sequence[int],
                           s_max: int, impl: str = "dense",
                           kv_bits: int = 16,
                           block_k: int = 128) -> Dict[str, float]:
    """KV-cache bytes ONE decode step streams through attention, per impl.

    ``lengths`` are the live per-slot prefixes (ragged); ``s_max`` the
    padded cache capacity.  ``impl="dense"`` models the XLA einsum over
    the whole padded cache — every slot pays ``s_max`` positions per
    attention layer regardless of its length.  ``impl="flash"`` models the
    length-aware Pallas flash-decode kernel: a slot streams only its live
    KV blocks, ``max(ceil(len/block_k), 1)`` blocks of ``block_k``
    positions (the clamped index map always touches at least block 0).
    Sliding-window (gemma local / ring) layers cap a slot's live positions
    at the window on both paths.  Whisper's per-slot cross-KV rows are not
    ragged and are charged identically to both impls.  ``kv_bits=8``
    prices the int8-fused variant.
    """
    kv_pos = _kv_pos_bytes(cfg.head_dim, cfg.num_kv_heads, kv_bits)
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "attn":
            cap = s_max
        elif kind == "local_attn":
            cap = min(cfg.sliding_window or s_max, s_max)
        else:
            continue                     # recurrent layers hold no KV rows
        if impl == "dense":
            total += len(lengths) * cap * kv_pos
        elif impl == "flash":
            for ln in lengths:
                bk = min(block_k, cap)
                n_blocks = max(math.ceil(min(int(ln), cap) / bk), 1)
                total += min(n_blocks * bk, cap) * kv_pos
        else:
            raise ValueError(f"impl {impl!r} (want dense|flash)")
    if cfg.encoder_layers:
        total += len(lengths) * cfg.num_layers * cfg.encoder_frames * kv_pos
    return {
        "impl": impl, "kv_bits": kv_bits, "block_k": block_k,
        "n_slots": len(lengths), "s_max": s_max,
        "mean_utilization": (sum(int(x) for x in lengths)
                             / max(len(lengths) * s_max, 1)),
        "attn_read_bytes_per_step": total,
    }


def cf_lookup_bytes(spec, plan, mesh_shape: Dict[str, int], batch: int,
                    hit_rate: float = 0.0,
                    dp_axis: str = "data") -> Dict[str, float]:
    """Modeled per-request wire bytes of the serving CF lookup, cached
    vs uncached.

    The serving path is forward-only (no gradient transpose, no DP table
    sync), so the terms are the lookup half of
    :func:`repro.embeddings.table.exchange_bytes`: a psum of (U, D/nc)
    partials over the row shards and/or an id all-gather + (B, D/nc)
    all-to-all over the column shards, on the same ring model (all-reduce
    ``2n(P-1)/P``, all-gather / all-to-all ``n(P-1)/P``).  ``batch`` is
    ids looked up per request (user + candidates); ``hit_rate`` is the
    hot-row cache's measured hit fraction — hits are served from the
    replicated head and move **zero** wire bytes, so the cached exchange
    is the uncached one scaled by the miss fraction.  The replicated plan
    exchanges nothing on either path (its cost is full-table memory).
    """
    if not 0.0 <= hit_rate <= 1.0:
        raise ValueError(f"hit_rate must be in [0, 1], got {hit_rate}")
    itemsize = 4                        # f32 factor tables
    nr = mesh_shape.get(plan.row_axis, 1) if plan.row_axis else 1
    nc = mesh_shape.get(plan.col_axis, 1) if plan.col_axis else 1
    ring = lambda n: (n - 1) / n if n > 1 else 0.0  # noqa: E731

    def exchange(ids: float) -> float:
        b = 0.0
        if plan.row_axis:                # psum of (U, D/nc) partials
            b += 2 * ids * (spec.dim // nc) * itemsize * ring(nr)
        if plan.col_axis:                # id all-gather + column all-to-all
            b += ids * 4 * ring(nc)
            b += ids * (spec.dim // nc) * itemsize * ring(nc)
        return b

    uncached = exchange(float(batch))
    cached = exchange(float(batch) * (1.0 - hit_rate))
    return {
        "plan": plan.kind, "batch": batch, "hit_rate": hit_rate,
        "uncached_bytes": uncached, "cached_bytes": cached,
        "saved_frac": 1.0 - cached / uncached if uncached else 0.0,
    }


def modeled_decode_step(cfg: ArchConfig, n_slots: int, cache_len: int,
                        kv_bits: int = 16) -> Dict[str, object]:
    """Roofline terms for one engine decode step on the full arch."""
    from repro.core.hybrid import decode_model_flops
    flops = decode_model_flops(cfg, cache_len, n_slots)
    state = n_slots * decode_state_bytes(cfg, cache_len, kv_bits)
    params = 2.0 * cfg.active_params()
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = (params + state) / HBM_BW
    step_s = max(t_compute, t_memory)
    return {
        "t_compute_ms": t_compute * 1e3,
        "t_memory_ms": t_memory * 1e3,
        "state_bytes_per_slot": state / n_slots,
        "param_bytes": params,
        "bound": "memory" if t_memory >= t_compute else "compute",
        "modeled_tok_s": n_slots / step_s,
    }


def modeled_prefill_step(cfg: ArchConfig, prompt_len: int,
                         kv_bits: int = 16) -> Dict[str, object]:
    """Roofline terms for one whole-prompt prefill on the full arch.

    Same two-term model as :func:`modeled_decode_step`, but the compute
    term is the full forward over ``prompt_len`` positions (every matmul
    touches the whole prompt, attention is quadratic-ish in it) while the
    memory term streams the parameters once plus writes the prompt's KV
    rows.  The arithmetic intensity therefore grows with ``prompt_len``
    — prefill crosses into the compute-bound regime at modest prompt
    lengths, which is the whole reason the two phases want different
    batching policies."""
    from repro.core.hybrid import model_flops
    flops = model_flops(cfg, prompt_len, 1, training=False)
    params = 2.0 * cfg.active_params()
    state = decode_state_bytes(cfg, prompt_len, kv_bits)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = (params + state) / HBM_BW
    step_s = max(t_compute, t_memory)
    return {
        "prompt_len": prompt_len,
        "t_compute_ms": t_compute * 1e3,
        "t_memory_ms": t_memory * 1e3,
        "bound": "compute" if t_compute >= t_memory else "memory",
        "modeled_prefill_s": step_s,
        "modeled_prefill_tok_s": prompt_len / step_s,
    }


def modeled_tier_split(cfg: ArchConfig, n_slots: int, cache_len: int,
                       prompt_len: int, kv_bits: int = 16,
                       ici_links: int = 1) -> Dict[str, object]:
    """Why disaggregation wins: the two phases sit on opposite sides of
    the roofline, so an interleaved engine time-slices a compute-bound
    phase (prefill) against a bandwidth-bound one (decode) on the same
    chip and each stalls the other.  Returns both phase models plus the
    modeled KV-handoff cost of moving one finished prompt's resident
    decode state across ``ici_links`` ICI links — the price a split pays
    per request, amortized over every decode step it un-stalls.

    The block-table itself is O(prompt_len / block_size) integers —
    noise next to the KV bytes — so the handoff term is just the state
    transfer.  ``handoff_vs_decode_steps`` says how many decode steps of
    the whole batch one handoff costs; when it is well under 1, splitting
    is effectively free at this granularity."""
    prefill = modeled_prefill_step(cfg, prompt_len, kv_bits)
    decode = modeled_decode_step(cfg, n_slots, cache_len, kv_bits)
    handoff_bytes = decode_state_bytes(cfg, prompt_len, kv_bits)
    t_handoff = handoff_bytes / (ici_links * ICI_BW_PER_LINK)
    t_decode_step = n_slots / decode["modeled_tok_s"]
    return {
        "prefill": prefill,
        "decode": decode,
        "split_is_heterogeneous": prefill["bound"] != decode["bound"],
        "handoff_bytes": handoff_bytes,
        "handoff_s": t_handoff,
        "handoff_vs_decode_steps": t_handoff / t_decode_step,
        # an interleaved engine stalls every in-flight decode for the
        # whole prefill; the tiered engine pays one handoff instead
        "interleave_stall_s": prefill["modeled_prefill_s"],
        "stall_vs_handoff": prefill["modeled_prefill_s"]
        / max(t_handoff, 1e-12),
    }
