"""OLMo-1B — dense decoder with non-parametric LayerNorm [arXiv:2402.00838; hf]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm_type="nonparam_ln",       # OLMo: LN without scale/bias
    mlp_gated=True,                # OLMo uses SwiGLU
    act="silu",
    pos_type="rope",
    rope_theta=1e4,
    tie_embeddings=True,
    source="arXiv:2402.00838; hf",
))
