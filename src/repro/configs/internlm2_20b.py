"""InternLM2-20B — dense GQA decoder [arXiv:2403.17297; hf]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    norm_type="rmsnorm",
    mlp_gated=True,
    act="silu",
    pos_type="rope",
    rope_theta=1e6,
    source="arXiv:2403.17297; hf",
))
