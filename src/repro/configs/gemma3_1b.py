"""Gemma3-1B — 5:1 local:global attention, MQA (kv=1), 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,                   # gemma3 fixed head_dim (> d_model/heads)
    d_ff=6912,
    vocab_size=262144,
    sliding_window=1024,            # local layers
    local_global_pattern=5,         # 5 local then 1 global
    qk_norm=True,
    norm_type="rmsnorm",
    mlp_gated=True,
    act="gelu",
    pos_type="rope",
    rope_theta=1e6,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
))
