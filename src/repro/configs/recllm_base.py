"""RecLLM — the paper's own LLM-based recommendation backbone (~100M class).

A decoder-only LM over item-token sequences fused with CF embeddings (Fig. 1);
trained with next-item prediction on the Amazon-Electronics-like dataset.
"""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recllm-base",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=63001 + 3,           # item vocab (#items + pad/bos/mask)
    norm_type="rmsnorm",
    mlp_gated=True,
    act="silu",
    pos_type="rope",
    tie_embeddings=True,
    source="paper §IV (Amazon Electronics, 63,001 items)",
))
