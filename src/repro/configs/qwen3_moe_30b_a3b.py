"""Qwen3-30B-A3B — MoE 128 experts top-8, qk-norm [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                       # per-expert FFN dim
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    qk_norm=True,
    norm_type="rmsnorm",
    mlp_gated=True,
    act="silu",
    pos_type="rope",
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
