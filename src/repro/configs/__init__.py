"""Architecture registry: importing this package registers every config."""
from repro.configs.internlm2_20b import CONFIG as internlm2_20b
from repro.configs.olmo_1b import CONFIG as olmo_1b
from repro.configs.deepseek_7b import CONFIG as deepseek_7b
from repro.configs.gemma3_1b import CONFIG as gemma3_1b
from repro.configs.moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from repro.configs.qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from repro.configs.rwkv6_1_6b import CONFIG as rwkv6_1_6b
from repro.configs.jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.qwen2_vl_2b import CONFIG as qwen2_vl_2b
from repro.configs.recllm_base import CONFIG as recllm_base

ALL = (
    internlm2_20b, olmo_1b, deepseek_7b, gemma3_1b, moonshot_v1_16b_a3b,
    qwen3_moe_30b_a3b, rwkv6_1_6b, jamba_v0_1_52b, whisper_medium,
    qwen2_vl_2b, recllm_base,
)
