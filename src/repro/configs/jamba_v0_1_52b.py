"""Jamba-v0.1-52B — Mamba+attention 1:7 interleave, MoE 16e top-2 every other
layer [arXiv:2403.19887; hf]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_period=2,                   # MoE at every other FFN
    ssm_type="mamba",
    attn_period=8,                  # 1 attn : 7 mamba
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    norm_type="rmsnorm",
    mlp_gated=True,
    act="silu",
    pos_type="none",                # jamba uses no positional encoding
    source="arXiv:2403.19887; hf",
))
