"""DeepSeek-LLM-7B — llama-arch dense decoder [arXiv:2401.02954; hf]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    norm_type="rmsnorm",
    mlp_gated=True,
    act="silu",
    pos_type="rope",
    rope_theta=1e4,
    source="arXiv:2401.02954; hf",
))
