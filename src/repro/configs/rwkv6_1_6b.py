"""RWKV-6 (Finch) 1.6B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,                   # d_model / rwkv_head_size
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    ssm_type="rwkv6",
    rwkv_head_size=64,
    norm_type="layernorm",
    mlp_gated=False,                # rwkv channel-mix (r,k,v mats; relu^2)
    act="relu2",
    pos_type="none",
    source="arXiv:2404.05892; unverified",
))
