"""Whisper-medium — encoder-decoder audio backbone; conv frontend STUBBED
(``input_specs`` provides 1500 precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,                  # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    encoder_layers=24,
    encoder_frames=1500,            # 30s audio -> 1500 frames (stub frontend)
    norm_type="layernorm",
    mlp_gated=False,
    act="gelu",
    pos_type="learned",
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
))
