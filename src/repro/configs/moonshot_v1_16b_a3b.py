"""Moonlight-16B-A3B (kimi/moonshot) — MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                      # per-expert FFN dim
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    norm_type="rmsnorm",
    mlp_gated=True,
    act="silu",
    pos_type="rope",
    rope_theta=5e4,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
))
