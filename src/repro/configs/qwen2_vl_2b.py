"""Qwen2-VL-2B — VLM text backbone with M-RoPE; vision frontend STUBBED
(``input_specs`` provides precomputed patch embeddings) [arXiv:2409.12191; hf]."""
from repro.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    norm_type="rmsnorm",
    mlp_gated=True,
    act="silu",
    pos_type="mrope",
    mrope_sections=(16, 24, 24),    # head_dim/2 = 64 split across (t, h, w)
    image_prefix_frac=0.25,         # leading fraction of seq = patch embeds
    rope_theta=1e6,
    tie_embeddings=True,
    source="arXiv:2409.12191; hf",
))
