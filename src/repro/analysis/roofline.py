"""Roofline analysis from dry-run compiled artifacts (deliverable g).

Per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory term     = HLO_bytes_per_device / HBM_BW
    collective term = intra_bytes / ICI_BW + cross_pod_bytes / DCI_BW

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
FLOPs/bytes (calibrated empirically — see EXPERIMENTS.md §Methodology), so no
further division by chip count is applied.  MODEL_FLOPS is the analytic
6*N*D (dense) / 6*N_active*D (MoE) from the hybrid planner's cost model, per
device, for the "useful compute fraction" column.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.config import (ArchConfig, ShapeConfig, DCI_BW_PER_LINK,
                          HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16)
from repro.analysis import hlo_cost
from repro.core import hybrid


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_intra: float
    coll_cross: float
    model_flops_per_dev: float
    peak_hbm_bytes: float
    arg_bytes: float
    temp_bytes: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return (self.coll_intra / ICI_BW_PER_LINK
                + self.coll_cross / DCI_BW_PER_LINK)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops_per_dev / max(self.flops_per_dev, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful compute time / bound time."""
        t_useful = self.model_flops_per_dev / PEAK_FLOPS_BF16
        return t_useful / max(self.t_bound, 1e-12)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_fraction=self.useful_fraction,
                 roofline_fraction=self.roofline_fraction)
        return d


def from_costs(arch_cfg: ArchConfig, shape: ShapeConfig, mesh_name: str,
               n_devices: int, costs: "hlo_cost.Costs", mem_stats
               ) -> Roofline:
    """costs: trip-count-aware per-device analysis of the post-SPMD,
    pre-float-normalization HLO (bf16 preserved); mem_stats: compiled
    memory_analysis (CPU-backend upper bound — f32-promoted temps)."""
    training = shape.kind == "train"
    # model FLOPs: decode = one token against the cache
    if shape.kind == "decode":
        mf = hybrid.decode_model_flops(arch_cfg, shape.seq_len,
                                       shape.global_batch)
    else:
        mf = hybrid.model_flops(arch_cfg, shape.seq_len, shape.global_batch,
                                training=training)
    ma = mem_stats
    return Roofline(
        arch=arch_cfg.name, shape=shape.name, mesh=mesh_name,
        n_devices=n_devices,
        flops_per_dev=float(costs.flops),
        bytes_per_dev=float(costs.bytes),
        coll_intra=float(costs.coll_intra),
        coll_cross=float(costs.coll_cross),
        model_flops_per_dev=mf / n_devices,
        peak_hbm_bytes=float(ma.temp_size_in_bytes
                             + ma.argument_size_in_bytes
                             + ma.output_size_in_bytes
                             - ma.alias_size_in_bytes),
        arg_bytes=float(ma.argument_size_in_bytes),
        temp_bytes=float(ma.temp_size_in_bytes),
    )


def save(r: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=1)


def format_row(d: Dict) -> str:
    return (f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['t_compute']*1e3:.1f} | {d['t_memory']*1e3:.1f} "
            f"| {d['t_collective']*1e3:.1f} | {d['bottleneck']} "
            f"| {d['useful_fraction']:.2f} | {d['roofline_fraction']:.2f} |")
