"""Trip-count-aware cost analysis of SPMD-partitioned HLO text.

XLA's ``compiled.cost_analysis()`` visits each while body ONCE, so programs
built from ``lax.scan`` (every model here) under-report FLOPs/bytes/
collectives by the trip count.  This analyzer walks the computation call
graph with multipliers from ``backend_config={"known_trip_count":...}``:

* FLOPs: from ``dot`` ops (2 * result_elems * contracted_elems) — matmuls
  dominate every workload here; elementwise FLOPs are ignored (<2%).
* memory bytes: per top-level op, result + operand bytes (fusion bodies are
  not double-counted: a fusion op's own operands/result model its HBM
  traffic, which is exactly the fused-kernel memory model).
* collectives: bytes by op kind, split intra-pod vs cross-pod by replica
  group analysis (see ``_crosses_pod``).

Shapes in the partitioned module are per-device, so totals are per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "s4": 1, "u4": 1, "token": 0,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
# computation headers: the signature form ``%name (args) -> type {`` (jax
# >= 0.5 dump style) and the bare form ``name {`` / ``ENTRY name {`` that
# older XLA pass dumps emit
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_COMP_HEADER_BARE_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\{\s*$")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"\bcalls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"\bto_apply=%?([\w\.\-]+)")
_COND_RE = re.compile(
    r"true_computation=%?([\w\.\-]+),\s*false_computation=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(?:\[([\d,]+)\]T\(([\d,]+)\)|\[(\d+)\])")

_FREE_OPS = (" parameter(", " get-tuple-element(", " tuple(", " bitcast(",
             " constant(", " after-all(", " partition-id(", " replica-id(",
             " iota(",)

# ops assumed to touch HBM in a well-fused TPU executable ("fused" byte
# model): matmuls, reductions, scan machinery, collectives.  Elementwise
# chains, transposes, pads and layout copies fuse into their neighbours on
# TPU (the MXU consumes transposed operands natively).
_MATERIAL_OPS = (" dot(", " convolution(", " reduce(", " reduce-window(",
                 " dynamic-update-slice(", " dynamic-slice(", " gather(",
                 " scatter(", " sort(", " fusion(", " rng(",
                 " cholesky(", " triangular-solve(",
                 " select-and-scatter(")

_CONST_RE = re.compile(r"%([\w\.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)")
_COMPARE_RE = re.compile(
    r"compare\(([^)]*)\),\s*direction=(LT|LE|GT|GE)")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_shapes_bytes(seg: str) -> int:
    return sum(shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(seg))


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return max(n_devices, 1)


def _crosses_pod(line: str, n_devices: int) -> bool:
    if n_devices <= 0:
        return False
    half = n_devices // 2
    m = _GROUPS_LIST_RE.search(line)
    if m:
        try:
            ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        except ValueError:
            return True
        return bool(ids) and min(ids) < half <= max(ids)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        if m.group(5):                         # plain iota [g,s]<=[N]
            return s > half
        reshape = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")]
        stride = 1
        for d in reshape[perm[-1] + 1:]:
            stride *= d
        return (s - 1) * stride >= half
    return False


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in COLLECTIVE_OPS})
    coll_cross: float = 0.0
    coll_count: float = 0.0

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def coll_intra(self) -> float:
        return self.coll_total - self.coll_cross

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in self.coll_bytes:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
        self.coll_cross += other.coll_cross * mult
        self.coll_count += other.coll_count * mult


_LHS_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _result_info(line: str) -> Tuple[int, List[int]]:
    """(total result bytes, dims of the first result shape) from the LHS."""
    eq = line.find("=")
    op_par = line.find("(", eq)
    seg = line[eq:op_par if op_par > 0 else None]
    shapes = _SHAPE_RE.findall(seg)
    total = sum(shape_bytes(dt, dims) for dt, dims in shapes)
    first = [int(d) for d in shapes[0][1].split(",") if d] if shapes else []
    return total, first


def _operands(line: str, op_token: str) -> List[str]:
    """Operand names between the op's '(' and the first ')'."""
    start = line.find(op_token)
    if start < 0:
        return []
    start = line.find("(", start)
    end = line.find(")", start)
    if start < 0 or end < 0:
        return []
    return _OPERAND_RE.findall(line[start:end])


def _dot_flops(line: str, sym: Dict[str, Tuple[int, List[int]]]) -> float:
    """2 * result_elems * prod(lhs contracting dims) via the symbol table."""
    _, res_dims = _result_info(line)
    ops = _operands(line, " dot(")
    lhs_dims: List[int] = []
    if ops and ops[0] in sym:
        lhs_dims = sym[ops[0]][1]
    m = _CONTRACT_RE.search(line)
    k = 1
    if m and m.group(1) and lhs_dims:
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    res = 1
    for d in res_dims:
        res *= d
    return 2.0 * res * k


def _trip_from_cond(cond_lines: List[str]) -> Optional[float]:
    """Extract the trip count from a jax-scan while condition: the constant
    bound of the ROOT compare (counter starts at 0, step 1)."""
    consts: Dict[str, int] = {}
    for line in cond_lines:
        for nm, val in _CONST_RE.findall(line):
            consts[nm] = int(val)
    for line in cond_lines:
        if "ROOT" in line:
            m = _COMPARE_RE.search(line)
            if not m:
                return None
            ops = _OPERAND_RE.findall(m.group(1))
            for nm in ops:
                if nm in consts:
                    n = consts[nm]
                    return float(n + 1) if m.group(2) in ("LE", "GE") \
                        else float(n)
            # inline constant form: compare(%x, s32[] constant(N))
            mc = re.search(r"constant\((\d+)\)", m.group(1))
            if mc:
                return float(mc.group(1))
    return None


def parse_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line) or _COMP_HEADER_BARE_RE.match(line)
            if m and line.rstrip().endswith("{") \
                    and not line.startswith("HloModule"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def analyze(hlo: str, n_devices: int = 0, byte_model: str = "fused") -> Costs:
    """byte_model: 'fused' (TPU fused-kernel traffic model — only
    materializing ops count) or 'all' (every op's result+operands)."""
    comps, entry = parse_computations(hlo)
    if entry is None:
        return Costs()

    # computations that are fusion bodies / reducers: excluded from traversal
    fusion_bodies = set()
    for lines in comps.values():
        for line in lines:
            if " fusion(" in line:
                m = _CALLS_RE.search(line)
                if m:
                    fusion_bodies.add(m.group(1))
            m = _TO_APPLY_RE.search(line)
            if m:
                fusion_bodies.add(m.group(1))

    # symbol tables: per computation, name -> (result bytes, first dims)
    syms: Dict[str, Dict[str, Tuple[int, List[int]]]] = {}
    for cname, lines in comps.items():
        tbl: Dict[str, Tuple[int, List[int]]] = {}
        for line in lines:
            m = _LHS_NAME_RE.match(line)
            if m and "=" in line:
                tbl[m.group(1)] = _result_info(line)
        syms[cname] = tbl

    memo: Dict[str, Costs] = {}

    def _op_read_bytes(line: str, op_token: str,
                       tbl: Dict[str, Tuple[int, List[int]]]) -> int:
        return sum(tbl.get(nm, (0, []))[0]
                   for nm in _operands(line, op_token))

    def _feeds_only_slice(res_name: str, lines: List[str]) -> bool:
        """True if every consumer of res_name is a (dynamic-)slice."""
        token = f"%{res_name}"
        found = False
        for other in lines:
            pos = other.find(token)
            if pos < 0:
                continue
            # skip the defining line
            m = _LHS_NAME_RE.match(other)
            if m and m.group(1) == res_name:
                continue
            nxt = other[pos + len(token)]if pos + len(token) < len(other) \
                else " "
            if nxt.isalnum() or nxt in "._-":
                continue                        # prefix of a longer name
            found = True
            if " dynamic-slice(" not in other and " slice(" not in other:
                return False
        return found

    def visit(name: str) -> Costs:
        if name in memo:
            return memo[name]
        memo[name] = Costs()                    # cycle guard
        total = Costs()
        tbl = syms.get(name, {})
        for line in comps.get(name, ()):
            stripped = " " + line.strip()
            if any(op in stripped for op in _FREE_OPS):
                # parameters/GTE/tuple/constants/iota: no HBM traffic
                pass
            elif " dot(" in stripped:
                total.flops += _dot_flops(line, tbl)
                total.bytes += (_result_info(line)[0]
                                + _op_read_bytes(line, " dot(", tbl))
            elif " while(" in stripped:
                m = _WHILE_RE.search(line)
                t = _TRIP_RE.search(line)
                if t:
                    trips = float(t.group(1))
                elif m:
                    trips = _trip_from_cond(comps.get(m.group(1), [])) or 1.0
                else:
                    trips = 1.0
                if m:
                    total.add(visit(m.group(2)), trips)   # body
                    total.add(visit(m.group(1)), trips)   # cond (cheap)
                # while's own tuple shuffling ~ free
            elif " conditional(" in stripped:
                m = _COND_RE.search(line)
                names = list(m.groups()) if m else []
                mb = _BRANCH_RE.search(line)
                if mb:
                    names = [x.strip().lstrip("%")
                             for x in mb.group(1).split(",")]
                for nm in names:                 # upper bound: all branches
                    total.add(visit(nm), 1.0)
            elif " call(" in stripped:
                m = _TO_APPLY_RE.search(line) or _CALLS_RE.search(line)
                if m:
                    total.add(visit(m.group(1)), 1.0)
            else:
                is_coll = False
                for op in COLLECTIVE_OPS:
                    if f" {op}(" in stripped or f" {op}-start(" in stripped:
                        used = op if f" {op}(" in stripped else f"{op}-start"
                        b_res = _result_info(line)[0]
                        N = _group_size(line, n_devices)
                        ring = (N - 1) / N
                        # per-device ring wire bytes (EXPERIMENTS.md
                        # §Methodology)
                        if op == "all-reduce":
                            nm = _LHS_NAME_RE.match(line)
                            if nm and _feeds_only_slice(nm.group(1),
                                                        comps[name]):
                                # TPU ReduceScatterCreator turns AR+slice
                                # into reduce-scatter (CPU pipeline doesn't)
                                wire = b_res * ring
                            else:
                                wire = 2 * b_res * ring
                        elif op == "reduce-scatter":
                            ops_in = _operands(line, used + "(")
                            b_in = tbl.get(ops_in[0], (b_res * N, []))[0] \
                                if ops_in else b_res * N
                            wire = b_in * ring
                        elif op == "collective-permute":
                            wire = b_res
                        else:                    # all-gather, all-to-all
                            wire = b_res * ring
                        total.coll_bytes[op] += wire
                        total.coll_count += 1
                        total.bytes += b_res
                        if _crosses_pod(line, n_devices):
                            total.coll_cross += wire
                        is_coll = True
                        break
                    if f" {op}-done(" in stripped:
                        is_coll = True           # counted at -start
                        break
                if not is_coll and "=" in line:
                    if byte_model == "fused" and not any(
                            op in stripped for op in _MATERIAL_OPS):
                        continue                 # fuses into a neighbour
                    tok = line[line.find("=") + 1:].strip()
                    sp = tok.find("(")
                    op_name = tok[:sp].split()[-1] if sp > 0 else ""
                    if op_name == "dynamic-slice":
                        # reads only the slice (result), not the source
                        total.bytes += 2 * _result_info(line)[0]
                    elif op_name == "dynamic-update-slice":
                        # in-place read-modify-write of the update region
                        ops_in = _operands(line, " dynamic-update-slice(")
                        upd = tbl.get(ops_in[1], (0, []))[0] \
                            if len(ops_in) > 1 else _result_info(line)[0]
                        total.bytes += 2 * upd
                    else:
                        total.bytes += _result_info(line)[0]
                        total.bytes += _op_read_bytes(line, f" {op_name}(",
                                                      tbl)
        memo[name] = total
        return total

    return visit(entry)
