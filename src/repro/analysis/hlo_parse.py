"""Parse compiled (SPMD-partitioned) HLO text for collective traffic.

Shapes in the partitioned module are PER-DEVICE buffer sizes, so the summed
bytes here are per-chip wire bytes — matching ``cost_analysis()``'s
per-device FLOPs (see EXPERIMENTS.md §Roofline methodology).

Cross-pod classification: replica groups are parsed (explicit lists and iota
``[g,s]<=[N]`` forms, incl. transposed); a collective whose group spans both
halves of a 2-pod device space (ids < N/2 and >= N/2) is charged to the
slower DCI link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(.*?)\}\}?,")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(?:\[([\d,]+)\]T\(([\d,]+)\)|\[(\d+)\])")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _lhs_bytes(line: str, op: str) -> int:
    """Sum the byte sizes of the op's result shapes (LHS of '=')."""
    eq = line.find("=")
    if eq < 0:
        return 0
    lhs_end = line.find(op, eq)
    seg = line[eq:lhs_end]
    return sum(shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(seg))


def _crosses_pod(line: str, n_devices: int) -> bool:
    """Does this collective's replica group span both pods (halves)?"""
    half = n_devices // 2
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first_group = m.group(1).split("}")[0].lstrip("{")
        try:
            ids = [int(x) for x in first_group.split(",") if x.strip()]
        except ValueError:
            return True
        return bool(ids) and min(ids) < half <= max(ids)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        if m.group(5):                          # plain iota [g,s]<=[N]
            return s > half
        # transposed iota: group elements stride across the device space
        reshape = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")]
        # group members differ in the minor (post-transpose) dims; they span
        # pods iff the id-distance across a group exceeds half the space.
        stride = 1
        for d in reshape[perm[-1] + 1:]:
            stride *= d
        return (s - 1) * stride >= half
    return False                                 # single-group default


@dataclasses.dataclass
class CollectiveStats:
    by_op: Dict[str, int]
    count: int
    total_bytes: int
    cross_pod_bytes: int
    intra_pod_bytes: int


def collective_stats(hlo_text: str, n_devices: int = 0) -> CollectiveStats:
    by_op: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    total = cross = 0
    count = 0
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            tok = f" {op}("
            tok_start = f" {op}-start("
            if tok in line or tok_start in line:
                used = op if tok in line else f"{op}-start"
                b = _lhs_bytes(line, used + "(")
                by_op[op] += b
                total += b
                count += 1
                if n_devices and _crosses_pod(line, n_devices):
                    cross += b
                break
    return CollectiveStats(by_op=by_op, count=count, total_bytes=total,
                           cross_pod_bytes=cross,
                           intra_pod_bytes=total - cross)
