"""Assemble the EXPERIMENTS.md roofline table from dry-run result JSONs.

  PYTHONPATH=src python -m repro.analysis.report experiments/dryrun
"""
import glob
import json
import os
import sys
from typing import Dict, List

from repro.config import PEAK_FLOPS_BF16, SHAPES, get_arch
from repro.core import hybrid


def _refresh_fractions(r: Dict) -> None:
    """Recompute MODEL_FLOPS-derived columns with the current cost model
    (cells don't need recompiling — raw HLO terms are stored)."""
    if r.get("status") != "ok":
        return
    rl = r["roofline"]
    cfg = get_arch(r["arch"])
    shape = SHAPES[r["shape"]]
    if shape.kind == "decode":
        mf = hybrid.decode_model_flops(cfg, shape.seq_len,
                                       shape.global_batch)
    else:
        mf = hybrid.model_flops(cfg, shape.seq_len, shape.global_batch,
                                training=shape.kind == "train")
    mf_dev = mf / rl["n_devices"]
    rl["model_flops_per_dev"] = mf_dev
    rl["useful_fraction"] = mf_dev / max(rl["flops_per_dev"], 1.0)
    t_bound = max(rl["t_compute"], rl["t_memory"], rl["t_collective"],
                  1e-12)
    rl["roofline_fraction"] = (mf_dev / PEAK_FLOPS_BF16) / t_bound


def load_results(out_dir: str) -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    for r in rows:
        _refresh_fractions(r)
    return rows


ARCH_ORDER = ["internlm2-20b", "olmo-1b", "deepseek-7b", "gemma3-1b",
              "moonshot-v1-16b-a3b", "qwen3-moe-30b-a3b", "rwkv6-1.6b",
              "jamba-v0.1-52b", "whisper-medium", "qwen2-vl-2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _key(r):
    a = ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99
    s = SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9
    return (a, s, r["mesh"])


def roofline_table(rows: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "bound | peak GB (bf16-adj) | fits 16G | useful frac | "
           "roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=_key):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped (full-attention; DESIGN.md §5) | — | — | "
                       f"— | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |")
            continue
        rl, mem = r["roofline"], r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl['t_compute']*1e3:.1f} | {rl['t_memory']*1e3:.1f} "
            f"| {rl['t_collective']*1e3:.1f} | {rl['bottleneck']} "
            f"| {mem['peak_bf16adj_gb']:.2f} "
            f"| {'yes' if mem['fits_16g'] else 'NO'} "
            f"| {rl['useful_fraction']:.2f} "
            f"| {rl['roofline_fraction']:.3f} |")
    return "\n".join(out)


def summary(rows: List[Dict]) -> str:
    ok = [r for r in rows if r["status"] == "ok"]
    sk = [r for r in rows if r["status"] == "skipped"]
    err = [r for r in rows if r["status"] == "error"]
    lines = [f"cells: {len(ok)} ok, {len(sk)} skipped, {len(err)} error"]
    for r in err:
        lines.append(f"  ERROR {r['arch']} x {r['shape']} x {r['mesh']}")
    fits = [r for r in ok if r["memory"]["fits_16g"]]
    lines.append(f"fits 16GB (bf16-adj): {len(fits)}/{len(ok)}")
    # worst roofline fraction / most collective-bound (hillclimb candidates)
    train_ok = [r for r in ok if r["mesh"] == "16x16"]
    if train_ok:
        worst = min(train_ok, key=lambda r: r["roofline"]["roofline_fraction"])
        lines.append(f"worst roofline fraction: {worst['arch']} x "
                     f"{worst['shape']} "
                     f"({worst['roofline']['roofline_fraction']:.3f})")
        coll = max(train_ok,
                   key=lambda r: r["roofline"]["t_collective"]
                   / max(r["roofline"]["t_compute"], 1e-9))
        lines.append(f"most collective-bound: {coll['arch']} x "
                     f"{coll['shape']}")
    return "\n".join(lines)


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load_results(out_dir)
    print("## Single-pod (16x16 = 256 chips)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(roofline_table(rows, "2x16x16"))
    print("\n## Summary\n")
    print(summary(rows))


if __name__ == "__main__":
    main()
