"""Timeline utilities: pipeline-tick synthesis and stage-time readout.

Two consumers share this module:

* :func:`stage_tick_times` turns measured ``stage_tick`` spans (emitted by
  :func:`repro.runtime.trainer.probe_stage_times` when handed a tracer)
  back into the per-stage median times that
  :func:`repro.core.load_balance.rebalance_stages` consumes — the probe
  and the rebalancer now read the *same* timeline instead of a side
  channel.  The median rule (sort, take ``[n // 2]``) matches the probe's
  own reduction exactly, so trace-fed and probe-fed rebalancing agree.

* :func:`synthesize_pipeline_ticks` walks the static
  :func:`repro.core.pipeline.schedule_tables` tick tables and lays a
  modeled fwd/bwd span per (tick, stage) onto per-stage tracks.  The real
  pipeline body runs inside one ``lax.scan`` — individual ticks are not
  host-observable — so this is the honest rendering: measured per-stage
  costs on the schedule's exact tick structure, bubbles visible as gaps.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

from repro.obs.trace import Tracer


def stage_tick_times(events: Iterable[Dict], n_stages: int = 0,
                     name: str = "stage_tick") -> List[float]:
    """Per-stage median duration over ``name`` spans (args carry
    ``stage``).  Returns a list indexed by stage; stages with no samples
    get 0.0.  Median = sort then ``[n // 2]`` — the same reduction
    ``probe_stage_times`` applies to its raw samples."""
    per_stage: Dict[int, List[float]] = {}
    for ev in events:
        if ev.get("ph") != "X" or ev.get("name") != name:
            continue
        s = int(ev.get("args", {}).get("stage", -1))
        if s < 0:
            continue
        per_stage.setdefault(s, []).append(float(ev["dur"]))
    if n_stages <= 0:
        n_stages = (max(per_stage) + 1) if per_stage else 0
    out = []
    for s in range(n_stages):
        samples = sorted(per_stage.get(s, []))
        out.append(samples[len(samples) // 2] if samples else 0.0)
    return out


def synthesize_pipeline_ticks(tracer: Tracer, schedule: str, n_stages: int,
                              n_micro: int, stage_times: Sequence[float],
                              t0: float = 0.0, bwd_cost_ratio: float = 2.0,
                              track_prefix: str = "stage") -> float:
    """Lay modeled per-tick fwd/bwd spans onto ``{track_prefix}{s}`` tracks.

    Walks the (T, S) micro-index tables from ``schedule_tables``; each
    tick advances global time by the max cost over the units active in it
    (stages step in lock-step — the synchronous-pipeline assumption the
    bubble model already makes), and every active (tick, stage) cell gets
    one span named ``pp.fwd`` / ``pp.bwd`` with args ``stage`` / ``micro``
    / ``tick``.  Returns the end time of the last tick.
    """
    from repro.core.pipeline import schedule_tables

    fwd, bwd, _depth = schedule_tables(schedule, n_stages, n_micro)
    costs = [float(c) for c in stage_times]
    t = float(t0)
    for tick in range(fwd.shape[0]):
        active = []  # (stage, micro, is_bwd)
        for s in range(n_stages):
            mf, mb = int(fwd[tick, s]), int(bwd[tick, s])
            if mf >= 0:
                active.append((s, mf, False))
            if mb >= 0:
                active.append((s, mb, True))
        if not active:
            continue
        dt = max(costs[s] * (bwd_cost_ratio if is_bwd else 1.0)
                 for s, _m, is_bwd in active)
        for s, m, is_bwd in active:
            dur = costs[s] * (bwd_cost_ratio if is_bwd else 1.0)
            tracer.complete("pp.bwd" if is_bwd else "pp.fwd", t, t + dur,
                            track=f"{track_prefix}{s}",
                            stage=s, micro=m, tick=tick)
        t += dt
    return t
