"""Metrics registry: counters, gauges, and histograms with exact
percentile readout.

The registry is the numeric half of the observability layer (spans are the
temporal half): named counters (monotone totals — prefix hits, COW events,
useful samples), gauges (instantaneous values with a bounded time series —
live pool blocks, active slots), and histograms.

A :class:`Histogram` keeps *both* views of a sample stream: fixed
log-spaced bucket counts (the cheap aggregate a dashboard would scrape)
and the exact sample list (bounded by ``max_samples``), so percentile
readout is **exact** — :meth:`Histogram.percentile` reproduces
:func:`percentile` (numpy's default linear-interpolation method) to the
bit while the sample window holds every observation, and degrades to
bucket interpolation only after ``max_samples`` observations drop out of
the window.  :mod:`repro.serving.metrics` delegates its ``percentile`` /
``_dist`` math here instead of keeping a private copy.

Gauge series are stamped by the registry's injectable ``clock`` (same
contract as :class:`repro.obs.trace.Tracer`), so a registry attached to
the serving engine keeps pool-occupancy series on the *simulated* clock
and exports them as Chrome-trace counter tracks aligned with the spans.
"""
from __future__ import annotations

import math
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple


def percentile(xs: Sequence[float], q: float) -> float:
    """Linearly-interpolated percentile (numpy's default method), q in
    [0, 100].  NaN for an empty sample.

    Bit-identical to ``np.percentile``: the interpolation replicates
    numpy's ``_lerp``, which evaluates from the far edge once the
    fractional rank passes 0.5 (``b - (b - a)*(1 - t)``) — the detail
    that makes the last ulp agree."""
    xs = sorted(float(x) for x in xs)
    if not xs:
        return float("nan")
    if len(xs) == 1:
        return xs[0]
    rank = (q / 100.0) * (len(xs) - 1)
    lo = min(int(math.floor(rank)), len(xs) - 2)
    t = rank - lo
    a, b = xs[lo], xs[lo + 1]
    if t >= 0.5:
        return b - (b - a) * (1.0 - t)
    return a + (b - a) * t


# log-spaced seconds-scale latency bounds: 100us .. ~100s
DEFAULT_BOUNDS: Tuple[float, ...] = tuple(
    1e-4 * (10 ** (i / 4)) for i in range(25))


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-value gauge with a bounded (t, value) series and a running
    peak; series timestamps come from the owning registry's clock."""

    __slots__ = ("_registry", "value", "peak", "series")

    def __init__(self, registry: "MetricsRegistry", max_points: int = 4096):
        self._registry = registry
        self.value: Optional[float] = None
        self.peak = -math.inf
        self.series: deque = deque(maxlen=max_points)

    def set(self, v: float, t: Optional[float] = None) -> None:
        """Set the gauge; ``t`` overrides the registry clock stamp (used
        when several engines share one registry but run on distinct
        simulated clocks)."""
        v = float(v)
        self.value = v
        self.peak = max(self.peak, v)
        self.series.append((self._registry.clock() if t is None else t, v))


class Histogram:
    """Fixed-bucket histogram retaining an exact sample window.

    ``bounds`` are bucket upper edges (one overflow bucket past the last);
    ``observe`` updates bucket counts, count/total/min/max, and appends to
    the sample window (insertion order — the mean is the same left-to-right
    float sum the pre-obs serving metrics computed)."""

    def __init__(self, bounds: Sequence[float] = DEFAULT_BOUNDS,
                 max_samples: int = 100_000):
        self.bounds = tuple(float(b) for b in bounds)
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: deque = deque(maxlen=max_samples)

    @property
    def samples(self) -> List[float]:
        return list(self._samples)

    @property
    def exact(self) -> bool:
        """True while the sample window still holds every observation."""
        return len(self._samples) == self.count

    def observe(self, x: float) -> None:
        x = float(x)
        i = 0
        for i, b in enumerate(self.bounds):          # noqa: B007
            if x <= b:
                break
        else:
            i = len(self.bounds)
        self.bucket_counts[i] += 1
        self.count += 1
        self.total += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        self._samples.append(x)

    def percentile(self, q: float) -> float:
        """Exact (sample-window) percentile; bucket linear interpolation
        once observations have aged out of the window."""
        if self.count == 0:
            return float("nan")
        if self.exact:
            return percentile(self._samples, q)
        # bucket fallback: rank within cumulative counts, interpolate
        # linearly inside the owning bucket
        rank = (q / 100.0) * (self.count - 1)
        seen = 0
        lo_edge = self.min
        for i, c in enumerate(self.bucket_counts):
            if c == 0:
                continue
            hi_edge = (self.bounds[i] if i < len(self.bounds) else self.max)
            hi_edge = min(hi_edge, self.max)
            if rank < seen + c:
                frac = (rank - seen + 1) / c
                return lo_edge + (hi_edge - lo_edge) * min(frac, 1.0)
            seen += c
            lo_edge = hi_edge
        return self.max

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"mean": float("nan"), "p50": float("nan"),
                    "p95": float("nan"), "p99": float("nan")}
        mean = (sum(self._samples) / len(self._samples) if self.exact
                else self.total / self.count)
        return {"mean": mean, "p50": self.percentile(50),
                "p95": self.percentile(95), "p99": self.percentile(99)}


class MetricsRegistry:
    """Named counters/gauges/histograms behind get-or-create accessors."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = clock if clock is not None else time.perf_counter
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter()
        return self._counters[name]

    def gauge(self, name: str, max_points: int = 4096) -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge(self, max_points)
        return self._gauges[name]

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_BOUNDS,
                  max_samples: Optional[int] = None) -> Histogram:
        """Get-or-create; ``max_samples`` (first-create only) bounds the
        exact sample window — a small window makes the histogram a
        sliding window over *recent* observations, which is what the
        disagg router percentiles over."""
        if name not in self._histograms:
            self._histograms[name] = Histogram(
                bounds, max_samples if max_samples is not None else 100_000)
        return self._histograms[name]

    @property
    def gauges(self) -> Dict[str, Gauge]:
        return dict(self._gauges)

    def snapshot(self) -> Dict:
        """JSON-ready dump for benchmark artifacts and launch summaries."""
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: {"value": g.value, "peak": g.peak,
                           "points": len(g.series)}
                       for k, g in self._gauges.items()},
            "histograms": {k: {"count": h.count, **h.summary()}
                           for k, h in self._histograms.items()},
        }
