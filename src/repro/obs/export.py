"""Exporters: Chrome-trace/Perfetto JSON and JSONL.

Chrome trace event format (the JSON array flavor Perfetto's legacy
importer and ``chrome://tracing`` both load): every event carries ``ph``
(X = complete span, i = instant, C = counter, M = metadata), ``ts``
(microseconds), ``pid`` and ``tid``.  Tracks map to threads: each distinct
tracer track (one per engine slot, per pipeline stage, per pool) becomes
one ``tid`` with a ``thread_name`` metadata record, so the timeline opens
with labeled rows.  Registry gauge series export as ``ph="C"`` counter
tracks aligned on the same clock.

Open a trace: https://ui.perfetto.dev → "Open trace file" (or
``chrome://tracing`` → Load).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer


def chrome_trace(tracer: Tracer,
                 registry: Optional[MetricsRegistry] = None,
                 pid: int = 1, process_name: str = "repro") -> Dict:
    """Tracer (+ optional registry gauges) -> Chrome-trace JSON object."""
    events: List[Dict] = [{"ph": "M", "name": "process_name", "ts": 0.0,
                           "pid": pid, "tid": 0,
                           "args": {"name": process_name}}]
    tids: Dict[str, int] = {}

    def tid_for(track: str) -> int:
        if track not in tids:
            tids[track] = len(tids) + 1
            events.append({"ph": "M", "name": "thread_name", "ts": 0.0,
                           "pid": pid, "tid": tids[track],
                           "args": {"name": track}})
        return tids[track]

    for ev in tracer.events:
        base = {"name": ev["name"], "pid": pid,
                "tid": tid_for(ev["track"]),
                "ts": ev["ts"] * 1e6, "args": ev.get("args", {})}
        if ev["ph"] == "X":
            events.append({**base, "ph": "X", "dur": ev["dur"] * 1e6})
        else:
            events.append({**base, "ph": "i", "s": "t"})
    if registry is not None:
        for name, g in registry.gauges.items():
            tid = tid_for(f"counter:{name}")
            for t, v in g.series:
                events.append({"ph": "C", "name": name, "pid": pid,
                               "tid": tid, "ts": t * 1e6,
                               "args": {"value": v}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer: Tracer,
                       registry: Optional[MetricsRegistry] = None) -> int:
    """Write Chrome-trace JSON; returns the event count."""
    obj = chrome_trace(tracer, registry)
    with open(path, "w") as f:
        json.dump(obj, f)
    return len(obj["traceEvents"])


def write_jsonl(path: str, tracer: Tracer,
                registry: Optional[MetricsRegistry] = None) -> int:
    """One raw tracer event per line (seconds-domain timestamps), with a
    final ``{"metrics": ...}`` line when a registry rides along.  The
    grep-able flavor for offline analysis; Chrome trace is for eyeballs."""
    n = 0
    with open(path, "w") as f:
        for ev in tracer.events:
            f.write(json.dumps(ev) + "\n")
            n += 1
        if registry is not None:
            f.write(json.dumps({"metrics": registry.snapshot()}) + "\n")
            n += 1
    return n


def write_trace(path: str, tracer: Tracer,
                registry: Optional[MetricsRegistry] = None) -> int:
    """Suffix-dispatched writer behind the ``--trace-out`` launch flags:
    ``*.jsonl`` -> JSONL, anything else -> Chrome-trace JSON."""
    if path.endswith(".jsonl"):
        return write_jsonl(path, tracer, registry)
    return write_chrome_trace(path, tracer, registry)
