"""Low-overhead tracer: nested spans + instant events in a bounded ring.

One :class:`Tracer` owns a ring buffer (``collections.deque`` with
``maxlen``) of finished events — wraparound drops the *oldest* events, so a
long-running server keeps its newest timeline.  Three event kinds:

* spans — ``with tracer.span("decode_step", track="engine", step=i):`` —
  one ``ph="X"`` (complete) event per exit, stamped with the per-track
  nesting depth at entry;
* retroactive spans — :meth:`Tracer.complete` takes explicit (t0, t1):
  the serving engine builds per-request phase spans straight from the
  same :class:`~repro.serving.metrics.RequestRecord` timestamps the
  TTFT/TPOT metrics read, so span durations reconcile with the report by
  construction;
* instants — :meth:`Tracer.instant` (``ph="i"``): scheduler decisions
  (admit / shed / pushback), rebalance events, kernel dispatches.

Time comes from an injectable ``clock`` callable (seconds).  Wall clock
(``time.perf_counter``) by default; the serving engine pins it to its
simulated :class:`~repro.serving.traffic.Clock`, and tests pin a
:class:`ManualClock` for deterministic timelines.

The disabled path is near-free: ``Tracer(enabled=False)`` (or the shared
:data:`NULL_TRACER`) returns one module-level no-op context manager from
every ``span()`` call and drops instants/completes before touching the
clock — no event objects, no ring writes, no timestamps.  Hot call sites
guard their *argument* computation (e.g. roofline models) behind
``tracer.enabled`` so a disabled tracer costs one attribute check.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional


class ManualClock:
    """Injectable monotonic clock for deterministic tests and simulations:
    ``advance(dt)`` moves time forward; calling the clock reads it."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance(self, dt: float) -> None:
        assert dt >= 0.0
        self.now += dt

    def __call__(self) -> float:
        return self.now


class _NoopSpan:
    """Shared do-nothing context manager returned by disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class _Span:
    """Live span handle: records (ts, dur, depth) on exit."""

    __slots__ = ("_tracer", "name", "track", "args", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str, track: str, args: Dict):
        self._tracer = tracer
        self.name, self.track, self.args = name, track, args

    def __enter__(self):
        tr = self._tracer
        self.depth = tr._depth.get(self.track, 0)
        tr._depth[self.track] = self.depth + 1
        self.t0 = tr.clock()
        return self

    def __exit__(self, *exc):
        tr = self._tracer
        t1 = tr.clock()
        tr._depth[self.track] = self.depth
        tr._events.append({"ph": "X", "name": self.name, "track": self.track,
                           "ts": self.t0, "dur": max(t1 - self.t0, 0.0),
                           "depth": self.depth, "args": self.args})
        return False


class Tracer:
    """Bounded-ring span/instant recorder with an injectable clock.

    ``capacity`` bounds the ring (oldest events drop first); ``clock`` is
    any zero-arg callable returning seconds.  ``enabled=False`` makes every
    recording method a no-op that allocates nothing.
    """

    def __init__(self, capacity: int = 65536,
                 clock: Optional[Callable[[], float]] = None,
                 enabled: bool = True):
        self.enabled = enabled
        self.clock = clock if clock is not None else time.perf_counter
        self._events: deque = deque(maxlen=capacity)
        self._depth: Dict[str, int] = {}

    @property
    def capacity(self) -> int:
        return self._events.maxlen

    @property
    def events(self) -> List[Dict]:
        """Finished events, oldest first (children precede their parent —
        they exit first; Chrome-trace ``X`` events are order-independent)."""
        return list(self._events)

    def span(self, name: str, track: str = "main", **args):
        """Context manager timing a nested span on ``track``."""
        if not self.enabled:
            return _NOOP_SPAN
        return _Span(self, name, track, args)

    def complete(self, name: str, t0: float, t1: float,
                 track: str = "main", **args) -> None:
        """Record an already-timed span retroactively (explicit t0/t1 on
        this tracer's clock domain)."""
        if not self.enabled:
            return
        self._events.append({"ph": "X", "name": name, "track": track,
                             "ts": t0, "dur": max(t1 - t0, 0.0),
                             "depth": self._depth.get(track, 0),
                             "args": args})

    def instant(self, name: str, track: str = "main", **args) -> None:
        if not self.enabled:
            return
        self._events.append({"ph": "i", "name": name, "track": track,
                             "ts": self.clock(), "args": args})

    def extend(self, events: Iterable[Dict]) -> None:
        """Merge finished events from another tracer (e.g. a probe-local
        tracer whose timeline should land in the session trace)."""
        if self.enabled:
            self._events.extend(events)

    def clear(self) -> None:
        self._events.clear()

    def span_names(self) -> Dict[str, int]:
        """Event-count histogram by name — the cheap trace summary the
        serve artifact's ``obs`` section carries."""
        out: Dict[str, int] = {}
        for ev in self._events:
            out[ev["name"]] = out.get(ev["name"], 0) + 1
        return out


NULL_TRACER = Tracer(capacity=1, enabled=False)


def or_null(tracer: Optional[Tracer]) -> Tracer:
    """The idiom every instrumented subsystem uses: ``tracer=None`` means
    the shared no-op tracer, never a None check per call site."""
    return tracer if tracer is not None else NULL_TRACER
