"""Unified observability: tracing spans, metrics registry, exporters.

See README "Observability" for the span taxonomy and metric names.
"""
from repro.obs.export import (chrome_trace, write_chrome_trace, write_jsonl,
                              write_trace)
from repro.obs.metrics import (DEFAULT_BOUNDS, Counter, Gauge, Histogram,
                               MetricsRegistry, percentile)
from repro.obs.timeline import stage_tick_times, synthesize_pipeline_ticks
from repro.obs.trace import NULL_TRACER, ManualClock, Tracer, or_null

__all__ = [
    "Tracer", "ManualClock", "NULL_TRACER", "or_null",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "percentile",
    "DEFAULT_BOUNDS",
    "chrome_trace", "write_chrome_trace", "write_jsonl", "write_trace",
    "stage_tick_times", "synthesize_pipeline_ticks",
]
