"""Version-compat shims for jax APIs that moved between 0.4.x and 0.6.x.

The repo targets current jax, but hermetic CI containers may pin an older
release; every call site goes through these helpers instead of sniffing
versions locally.
"""
from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """`jax.make_mesh` with Auto axis types where the kwarg exists.

    jax < 0.5 has no `jax.sharding.AxisType` (all meshes behave as Auto),
    so omitting the kwarg there is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """`jax.sharding.AbstractMesh` across the 0.4.x -> 0.5.x signature
    change ((name, size) pairs vs separate shape/name tuples)."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes),
                                         tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))


def axis_size(name: str):
    """Static named-axis size inside shard_map: `jax.lax.axis_size` where
    available, else the classic `psum(1, axis)` idiom."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def pallas_compiler_params():
    """Pallas TPU compiler-params class: renamed TPUCompilerParams ->
    CompilerParams in jax 0.5.x."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; unsupported jax version "
            f"{jax.__version__}")
    return cls
