"""Config system: architectures, input shapes, parallelism plans.

Every architecture in ``repro.configs`` registers an :class:`ArchConfig` here and
is selectable via ``--arch <id>`` in the launchers.  Shapes (``--shape``) are the
assigned input-shape set shared by all LM-family archs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware model (TPU v5e target — used by the roofline analysis only).
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12          # per chip, FLOP/s
HBM_BW = 819e9                    # per chip, B/s
ICI_BW_PER_LINK = 50e9            # B/s per ICI link (intra-pod)
DCI_BW_PER_LINK = 12.5e9          # B/s cross-pod (data-center links, ~4x slower)
VMEM_BYTES = 128 * 1024 * 1024    # v5e VMEM per core (approx, for kernel sizing)
HBM_BYTES_PER_CHIP = 16 * 1024**3


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A transformer-family architecture (exact public config)."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1             # 1 => every FFN is MoE; jamba uses 2
    # --- attention pattern ---
    sliding_window: int = 0         # >0 => local attention window for "local" layers
    local_global_pattern: int = 0   # N>0 => N local layers then 1 global, repeated
    qk_norm: bool = False
    # --- norm / act ---
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm | nonparam_ln
    mlp_gated: bool = True          # SwiGLU-style (3 mats) vs plain (2 mats)
    act: str = "silu"               # silu | gelu | relu2
    # --- positions ---
    pos_type: str = "rope"          # rope | mrope | learned | none
    rope_theta: float = 1e4
    # --- ssm / hybrid ---
    ssm_type: str = ""              # "rwkv6" | "mamba" (hybrid)
    attn_period: int = 0            # jamba: one attn layer per period of N layers
    ssm_d_state: int = 16           # mamba state dim
    ssm_d_conv: int = 4             # mamba conv width
    ssm_expand: int = 2             # mamba inner expansion
    rwkv_head_size: int = 64
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 0         # stub frontend: precomputed frames fed directly
    # --- vlm (qwen2-vl) ---
    mrope_sections: Tuple[int, ...] = ()   # head_dim split across (t, h, w)
    image_prefix_frac: float = 0.0         # fraction of seq that is patch embeds
    # --- misc ---
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    dtype: str = "bfloat16"
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string: 'attn' | 'local_attn' | 'mamba' | 'rwkv6'."""
        kinds = []
        for i in range(self.num_layers):
            if self.ssm_type == "rwkv6":
                kinds.append("rwkv6")
            elif self.ssm_type == "mamba" and self.attn_period > 0:
                kinds.append("attn" if i % self.attn_period == 0 else "mamba")
            elif self.local_global_pattern > 0:
                p = self.local_global_pattern
                kinds.append("attn" if (i % (p + 1)) == p else "local_attn")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def num_params(self) -> int:
        """Analytic parameter count (embedding + per-layer)."""
        n = self.padded_vocab * self.d_model          # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model     # lm head
        for i, kind in enumerate(self.layer_kinds()):
            n += self._layer_params(kind, layer_idx=i)
        if self.encoder_layers:
            n += self.encoder_layers * self._layer_params("attn", cross=False)
            # decoder cross-attention blocks
            n += self.num_layers * (2 * self.d_model * self.kv_dim
                                    + self.d_model * self.q_dim
                                    + self.q_dim * self.d_model)
        return n

    def _ffn_params(self, layer_idx: int = 0) -> int:
        mats = 3 if self.mlp_gated else 2
        if self.is_moe and (layer_idx % self.moe_period == self.moe_period - 1):
            router = self.d_model * self.num_experts
            return router + self.num_experts * mats * self.d_model * self.d_ff
        if self.is_moe and self.moe_period > 1:
            # dense interleave layers in a partially-MoE model reuse d_ff
            return mats * self.d_model * self.d_ff
        if self.is_moe:
            return (self.d_model * self.num_experts
                    + self.num_experts * mats * self.d_model * self.d_ff)
        return mats * self.d_model * self.d_ff

    def _layer_params(self, kind: str, cross: bool = False, layer_idx: int = 0) -> int:
        d = self.d_model
        if kind in ("attn", "local_attn"):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        elif kind == "mamba":
            d_in = self.ssm_expand * d
            attn = (d * 2 * d_in                   # in_proj (x, z)
                    + d_in * self.ssm_d_conv       # conv
                    + d_in * (2 * self.ssm_d_state + 1)  # B, C, dt proj (simplified)
                    + d_in * self.ssm_d_state      # A_log
                    + d_in * d)                    # out_proj
        elif kind == "rwkv6":
            h = d // self.rwkv_head_size
            attn = (4 * d * d                      # r, k, v, output
                    + d * d                        # gate
                    + 6 * d                        # time-mix lerps (lora-less approx)
                    + h * self.rwkv_head_size)     # time_first
        else:
            raise ValueError(kind)
        return attn + self._ffn_params(layer_idx)

    def active_params(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.num_params()
        n = self.padded_vocab * self.d_model * (1 if self.tie_embeddings else 2)
        mats = 3 if self.mlp_gated else 2
        for i, kind in enumerate(self.layer_kinds()):
            full = self._layer_params(kind, layer_idx=i)
            if i % self.moe_period == self.moe_period - 1:
                moe_full = self.num_experts * mats * self.d_model * self.d_ff
                moe_act = self.experts_per_token * mats * self.d_model * self.d_ff
                full = full - moe_full + moe_act
            n += full
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic / bounded-state); see DESIGN.md §5.
LONG_CONTEXT_OK = ("rwkv6-1.6b", "jamba-v0.1-52b", "gemma3-1b")


def cell_is_runnable(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in LONG_CONTEXT_OK
    return True


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """The hybrid-parallelism plan (paper C1/C2/C5/C6/C8)."""

    dp: int = 1
    tp: int = 1
    pp: int = 1                       # pipeline stages (separate mesh when > 1)
    microbatches: int = 1             # pipeline micro-batches
    pp_schedule: str = "1f1b"         # 1f1b | gpipe (core.pipeline.SCHEDULES)
    multi_pod: bool = False
    # activation sharding
    seq_shard_activations: bool = True   # Megatron-SP residual stream (beyond-paper)
    remat: str = "full"               # none | full (jax.checkpoint on layer bodies)
    # gradient sync (paper C5/C6)
    grad_sync: str = "auto"           # auto (GSPMD) | hierarchical | compressed
    compression: str = "none"         # none | onebit | topk
    topk_frac: float = 0.01
    # async (paper C7; simulation only)
    async_mode: bool = False
    max_staleness: int = 4


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    zero1: bool = True                # shard optimizer state over dp axis
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    checkpoint_dir: str = "/tmp/repro_ckpt"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401
    return tuple(sorted(_REGISTRY))


def reduced(cfg: ArchConfig, *, layers: Optional[int] = None) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        num_layers=layers if layers is not None else min(cfg.num_layers, 2),
        d_model=64,
        num_heads=max(2, min(cfg.num_heads, 4)),
        num_kv_heads=1 if cfg.num_kv_heads < cfg.num_heads else max(2, min(cfg.num_heads, 4)),
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        vocab_pad_to=32,
    )
    if cfg.is_moe:
        kw.update(num_experts=4, experts_per_token=2)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_frames=8)
    if cfg.ssm_type == "rwkv6":
        kw.update(rwkv_head_size=16, num_heads=4, head_dim=16)
    if cfg.attn_period:
        kw.update(num_layers=max(cfg.attn_period, 4), attn_period=4)
    if cfg.local_global_pattern:
        kw.update(num_layers=6, local_global_pattern=2, sliding_window=8)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(4, 2, 2))
    return dataclasses.replace(cfg, **kw)
