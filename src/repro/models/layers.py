"""Core layer primitives: norms, rotary embeddings (RoPE / M-RoPE), MLPs.

Everything is purely functional: ``init_*`` builds a param pytree (nested dicts
of jnp arrays), ``apply`` functions consume ``(params, x)``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}          # gemma-style (1+scale)
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm_type == "nonparam_ln":
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(cfg: ArchConfig, params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        if cfg.norm_type == "layernorm":
            y = y * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def rms_norm_simple(x, scale, eps: float = 1e-6):
    """Standalone RMSNorm used for qk-norm (scale is multiplicative 1+s)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                         # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Multimodal RoPE (qwen2-vl): positions (..., S, 3) = (t, h, w) grids.

    The D/2 frequency slots are split into ``sections`` (sum == D/2); slots in
    section i rotate by position component i.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = _rope_freqs(d, theta)                          # (D/2,)
    # component selector per frequency slot
    comp = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                            for i, s in enumerate(sections)])
    pos = jnp.take(positions.astype(jnp.float32), comp, axis=-1)  # (..., S, D/2)
    angles = pos[..., None, :] * freqs                     # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def position_embedding(cfg: ArchConfig, x, positions):
    """Dispatch on cfg.pos_type for q/k tensors. positions: (B,S) or (B,S,3)."""
    if cfg.pos_type == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.pos_type == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return x  # learned / none handled at embedding level


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def init_dense(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        return {"wi_gate": init_dense(ks[0], cfg.d_model, d_ff, dtype),
                "wi_up": init_dense(ks[1], cfg.d_model, d_ff, dtype),
                "wo": init_dense(ks[2], d_ff, cfg.d_model, dtype)}
    return {"wi": init_dense(ks[0], cfg.d_model, d_ff, dtype),
            "wo": init_dense(ks[1], d_ff, cfg.d_model, dtype)}


def apply_mlp(cfg: ArchConfig, params, x):
    act = activation(cfg.act)
    if cfg.mlp_gated:
        h = act(x @ params["wi_gate"]) * (x @ params["wi_up"])
    else:
        h = act(x @ params["wi"])
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    emb = (jax.random.normal(key, (cfg.padded_vocab, cfg.d_model), jnp.float32)
           * 0.02).astype(dtype)
    return emb


from functools import partial as _partial  # noqa: E402


def _no_constrain(x, name):
    return x


@_partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _embed_lookup(constrain, vocab: int, dtype_str: str, emb, tokens):
    return jnp.take(emb, tokens, axis=0)


def _embed_fwd(constrain, vocab, dtype_str, emb, tokens):
    return jnp.take(emb, tokens, axis=0), tokens


def _embed_bwd(constrain, vocab, dtype_str, tokens, g):
    """Vocab-dim-shardable embedding gradient.

    The scatter-add autodiff emits a *replicated* (V, d) f32 buffer under
    GSPMD (2+ GB/device for 256k vocabs).  The one-hot einsum form keeps
    the vocab dim sharded like the embedding itself; the explicit
    constraints keep the token dim batch-sharded so GSPMD contracts with a
    psum instead of all-gathering 1M-token operands.
    """
    onehot = jax.nn.one_hot(tokens.reshape(-1), vocab, dtype=g.dtype)
    onehot = constrain(onehot, "embed_onehot")
    d = jnp.einsum("tv,td->vd", onehot, g.reshape(-1, g.shape[-1]),
                   preferred_element_type=jnp.float32)
    d = constrain(d, "embed_grad")
    return d.astype(jnp.dtype(dtype_str)), None


_embed_lookup.defvjp(_embed_fwd, _embed_bwd)


def embed_tokens(emb, tokens, constrain=_no_constrain):
    return _embed_lookup(constrain, emb.shape[0], str(emb.dtype), emb,
                         tokens)


def lm_logits(cfg: ArchConfig, params, h):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return h @ head.T if cfg.tie_embeddings else h @ head


@jax.custom_vjp
def _nll(logits, targets):
    """Per-position negative log-likelihood with a memory-lean VJP.

    The naive autodiff path materializes an f32 copy of the logits (fwd) and
    a second one for softmax in bwd — for 256k-vocab models that is the
    single largest activation.  Here the forward saves only (logits, lse)
    and the backward streams (softmax - onehot) in the logits dtype.
    """
    lse = _lse32(logits)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0].astype(jnp.float32)
    return lse - gold


def _lse32(logits):
    """logsumexp with f32 accumulation; the f32 convert fuses into the
    reduce so no f32 logits copy is materialized."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1)).astype(jnp.float32)
    s = jnp.sum(jnp.exp(logits.astype(jnp.float32) - m[..., None]), axis=-1)
    return m + jnp.log(s)


def _nll_fwd(logits, targets):
    lse = _lse32(logits)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0].astype(jnp.float32)
    return lse - gold, (logits, targets, lse)


def _nll_bwd(res, g):
    logits, targets, lse = res
    # softmax recomputed in the logits dtype; d_logits = g*(p - onehot)
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    d = (g[..., None] * (p - onehot)).astype(logits.dtype)
    return d, None


_nll.defvjp(_nll_fwd, _nll_bwd)


def cross_entropy_loss(logits, targets, mask=None,
                       vocab_size: Optional[int] = None):
    """Next-token CE; ``mask`` zeroes padded / non-text positions.

    ``logits``: (..., V_padded); targets int32.  Padded vocab rows are never
    valid targets so no extra masking of the vocab axis is needed.
    """
    nll = _nll(logits, targets)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
