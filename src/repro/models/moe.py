"""Mixture-of-Experts FFN with top-k routing, capacity dispatch, and the
paper's load-balancing machinery (§III.A.c).

Dispatch is the group-wise one-hot einsum formulation (Mesh-TF / Switch
lineage), fully batched over groups: tokens are split into
(batch x seq-subchunk) groups of ``group_size``; every tensor keeps a
leading group dim that stays dp-sharded (no sequential loops, no global
token reshuffle).  The (g, G, E, C) dispatch/combine tensors are bounded by
``group_size`` per group.  Expert weights are sharded over the ``model``
mesh axis (expert parallelism); under GSPMD the dispatch einsum lowers to
the all-to-all the paper describes.

Aux outputs: load-balance loss (Switch), router z-loss, and per-expert load
counts consumed by ``core.load_balance.rebalance_experts``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers


def init_moe(key, cfg: ArchConfig) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    p = {"router": layers.init_dense(ks[0], d, E, jnp.float32)}
    if cfg.mlp_gated:
        p["wi_gate"] = _expert_init(ks[1], E, d, f, dtype)
        p["wi_up"] = _expert_init(ks[2], E, d, f, dtype)
        p["wo"] = _expert_init(ks[3], E, f, d, dtype)
    else:
        p["wi"] = _expert_init(ks[1], E, d, f, dtype)
        p["wo"] = _expert_init(ks[2], E, f, d, dtype)
    return p


def _expert_init(key, E, din, dout, dtype):
    scale = 1.0 / jnp.sqrt(din)
    return (jax.random.normal(key, (E, din, dout), jnp.float32) * scale).astype(dtype)


def router_topk(logits: jnp.ndarray, k: int, use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(..., E) logits -> (gates (..., k), idx (..., k), probs (..., E)).

    Batched dims are preserved (no (T, E) flatten: merging the sharded
    group dim into a single token axis made GSPMD all-gather 1M-token
    router tensors)."""
    if use_kernel:
        from repro.kernels import ops
        shp = logits.shape
        g2, i2, p2 = ops.moe_router(logits.reshape(-1, shp[-1]), k)
        return (g2.reshape(shp[:-1] + (k,)), i2.reshape(shp[:-1] + (k,)),
                p2.reshape(shp))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, idx, probs


def _capacity(group: int, k: int, E: int, factor: float) -> int:
    c = int(group * k / E * factor)
    return max(8, -(-c // 8) * 8)   # round up to 8


def moe_ffn(cfg: ArchConfig, params: Dict, x: jnp.ndarray,
            *, capacity_factor: float = 1.25, group_size: int = 1024,
            use_kernel: bool = False, constrain=None, live=None):
    """x: (B, S, d) -> (out, aux) where aux has losses + expert loads.

    ``live`` (optional (B, S) 0/1 mask — serving prefill): masked-out
    positions are dropped from routing entirely — they occupy no expert
    capacity (pad garbage can never evict a real token from its expert),
    contribute nothing to dispatch/combine or ``expert_load``, and get
    zero FFN output."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    G = min(group_size, S)
    if S % G:
        import math
        G = math.gcd(G, S)
    g = B * (S // G)
    C = _capacity(G, k, E, capacity_factor)
    xg = x.reshape(g, G, d)
    if constrain is not None:
        # MoE boundary: groups stay dp-sharded, sequence gathered (the
        # Megatron-SP -> expert-parallel transition)
        xg = constrain(xg, "moe_groups")

    logits = xg.astype(jnp.float32) @ params["router"]           # (g, G, E)
    gates, idx, probs = router_topk(logits, k, use_kernel)       # (g, G, .)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)           # (g,G,k,E)
    if live is not None:
        # dead (pad) tokens leave the expert queues before positions are
        # assigned: real tokens' capacity slots are pad-independent
        onehot = onehot * live.reshape(g, G).astype(jnp.float32)[..., None,
                                                                 None]
    # position of each (token, slot) within its expert queue, per group
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, k * G, E)     # slot-major
    pos = jnp.cumsum(flat, axis=1) - flat                        # (g,kG,E)
    pos = pos.reshape(g, k, G, E).transpose(0, 2, 1, 3)          # (g,G,k,E)
    pos_in_e = jnp.sum(pos * onehot, axis=-1)                    # (g,G,k)
    keep = pos_in_e < C                                          # capacity drop
    pos_in_e = jnp.where(keep, pos_in_e, 0).astype(jnp.int32)
    gates_k = gates * keep
    poshot = jax.nn.one_hot(pos_in_e, C, dtype=jnp.float32) \
        * keep[..., None]                                        # (g,G,k,C)
    dt = x.dtype
    # dispatch/combine without materializing the k-dim outer product
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, poshot).astype(dt)
    combine = jnp.einsum("gtke,gtkc->gtec", onehot * gates_k[..., None],
                         poshot).astype(dt)
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)       # (g,E,C,d)
    if constrain is not None:
        expert_in = constrain(expert_in, "expert_stack")
    act = layers.activation(cfg.act)
    if cfg.mlp_gated:
        h = act(jnp.einsum("gecd,edf->gecf", expert_in, params["wi_gate"],
                           preferred_element_type=jnp.float32)) \
            * jnp.einsum("gecd,edf->gecf", expert_in, params["wi_up"],
                         preferred_element_type=jnp.float32)
        h = h.astype(dt)
    else:
        h = act(jnp.einsum("gecd,edf->gecf", expert_in, params["wi"],
                           preferred_element_type=jnp.float32)).astype(dt)
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    if constrain is not None:
        expert_out = constrain(expert_out, "expert_stack")
    out = jnp.einsum("gtec,gecd->gtd", combine, expert_out,
                     preferred_element_type=jnp.float32)

    # aux statistics (Switch LB loss over all tokens)
    frac_tokens = jnp.mean(onehot[..., 0, :], axis=(0, 1))       # top-1 frac
    mean_prob = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    load = jnp.sum(onehot, axis=(0, 1, 2))                       # (E,)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "expert_load": load}
    return out.astype(dt).reshape(B, S, d), aux
