"""Int8 KV-cache quantization (serving memory optimization).

The §Roofline decode cells are bandwidth-bound streaming the KV cache
(e.g. deepseek-7b decode_32k: 8 GB/dev of cache, the whole memory term).
Per-(position, head) symmetric int8 quantization halves cache bytes vs
bf16 — and the roofline memory term with it — at <0.5% attention-output
error (validated in tests/test_kvquant.py).

Layout: values int8 (B, S, Hk, D); scales f32 (B, S, Hk) — amax over the
head dim, the standard KV-quant granularity.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., D) float -> (int8 values, f32 scales (...,))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def quantize_kv_tree(tree):
    """Quantize every leaf of a KV pytree (e.g. gemma's per-layer ring
    buffers, whisper's cross-KV): returns (int8-values tree, scales tree)
    with the input treedef.  Requantizing a dequantized leaf is exact —
    the max-|x| element of each (…, D) row always lands on ±127, pinning
    the scale — so round-tripping untouched cache rows every decode step
    does not drift (the property the serving int8 composition relies on)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    qs, ss = [], []
    for leaf in leaves:
        q, s = quantize_kv(leaf)
        qs.append(q)
        ss.append(s)
    return treedef.unflatten(qs), treedef.unflatten(ss)


def dequantize_kv_tree(q_tree, s_tree, dtype=jnp.bfloat16):
    """Inverse of :func:`quantize_kv_tree`."""
    return jax.tree.map(lambda q, s: dequantize_kv(q, s, dtype),
                        q_tree, s_tree)


def init_quant_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                     layers: int) -> Dict:
    """Stacked per-layer quantized K/V cache."""
    return {
        "k_q": jnp.zeros((layers, batch, max_len, n_kv, head_dim), jnp.int8),
        "k_s": jnp.zeros((layers, batch, max_len, n_kv), jnp.float32),
        "v_q": jnp.zeros((layers, batch, max_len, n_kv, head_dim), jnp.int8),
        "v_s": jnp.zeros((layers, batch, max_len, n_kv), jnp.float32),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def cache_insert(cache_q, cache_s, pos, k_new):
    """Insert one token's K or V (B, Hk, D) at per-sequence positions."""
    B = k_new.shape[0]
    q, s = quantize_kv(k_new)
    cache_q = cache_q.at[jnp.arange(B), pos].set(q)
    cache_s = cache_s.at[jnp.arange(B), pos].set(s)
    return cache_q, cache_s


def cache_insert_paged(pool_q, pool_s, phys, off, k_new):
    """Paged twin of :func:`cache_insert`: pools (N, bs, Hk, D) / (N, bs,
    Hk); ``phys``/``off`` (B,) physical block and in-block row per slot
    (write-table resolved — unowned slots target the null block 0)."""
    q, s = quantize_kv(k_new)
    pool_q = pool_q.at[phys, off].set(q)
    pool_s = pool_s.at[phys, off].set(s)
    return pool_q, pool_s


def init_model_quant_cache(cfg, batch: int, max_len: int) -> Dict:
    """Quantized decode cache shaped for an ArchConfig (uniform family:
    stacked per-layer K/V, the layout serving's Int8KVBackend scatters
    into)."""
    from repro.models import transformer as tf
    if tf.family(cfg) != "uniform":
        raise NotImplementedError(
            f"int8 KV cache supports the uniform family, not {tf.family(cfg)}")
    return init_quant_cache(batch, max_len, cfg.num_kv_heads, cfg.head_dim,
                            cfg.num_layers)


def init_paged_quant_cache(cfg, n_slots: int, max_len: int, *,
                           num_blocks: int, block_size: int) -> Dict:
    """Paged int8 decode cache (uniform family): pooled quantized values
    ``(L, num_blocks, block_size, Hk, D)`` int8 + pooled scales
    ``(L, num_blocks, block_size, Hk)`` f32, with the same read/write block
    tables as :func:`transformer.init_paged_slots`."""
    from repro.models import transformer as tf
    if tf.family(cfg) != "uniform":
        raise NotImplementedError(
            f"int8 KV cache supports the uniform family, not {tf.family(cfg)}")
    if max_len % block_size:
        raise ValueError(f"max_len={max_len} not a multiple of "
                         f"block_size={block_size}")
    L, Hk, D = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    tbl = jnp.zeros((n_slots, max_len // block_size), jnp.int32)
    return {
        "k_q": jnp.zeros((L, num_blocks, block_size, Hk, D), jnp.int8),
        "k_s": jnp.zeros((L, num_blocks, block_size, Hk), jnp.float32),
        "v_q": jnp.zeros((L, num_blocks, block_size, Hk, D), jnp.int8),
        "v_s": jnp.zeros((L, num_blocks, block_size, Hk), jnp.float32),
        "block_table": tbl, "write_table": tbl,
        "len": jnp.zeros((n_slots,), jnp.int32),
    }


def quant_decode_step(cfg, params, cache: Dict, tokens, ctx=None):
    """One decode step against the int8 cache — the quantized twin of
    ``transformer.decode_step`` for the uniform family.

    tokens (B, 1) -> (logits (B, 1, V), new_cache).  Per-layer K/V for the
    incoming token are quantized on insert; attention runs via
    :func:`decode_attention_quant` so the cache is never dequantized in
    full.  A paged cache (``"block_table"`` present — built by
    :func:`init_paged_quant_cache`) inserts through the write table and
    attends through the read table via the unified layout dispatch."""
    from repro.models import layers
    from repro.models import transformer as tf
    if tf.family(cfg) != "uniform":
        raise NotImplementedError("quant_decode_step: uniform family only")
    if ctx is None:
        ctx = tf.ModelCtx()
    B = tokens.shape[0]
    pos = cache["len"]                              # (B,) per-row lengths
    h = layers.embed_tokens(params["embed"], tokens)
    paged = "block_table" in cache
    if paged:
        from repro.cache_layout import CacheLayout
        from repro.kernels import ops
        bs = cache["k_q"].shape[2]
        S = cache["block_table"].shape[1] * bs
        phys = cache["write_table"][jnp.arange(B), pos // bs]
        off = pos % bs
        layout = CacheLayout(kind="paged", kv_bits=8, impl=ctx.decode_impl,
                             block_size=bs)

    def body(x, inp):
        blk, k_q, k_s, v_q, v_s = inp
        hn = layers.apply_norm(cfg, blk["attn"]["norm"], x)
        q, k, v = tf._qkv(cfg, blk["attn"], hn, pos[:, None], ctx)
        if paged:
            k_q, k_s = cache_insert_paged(k_q, k_s, phys, off, k[:, 0])
            v_q, v_s = cache_insert_paged(v_q, v_s, phys, off, v[:, 0])
            o = ops.decode_attention(
                q, {"k_q": k_q, "k_s": k_s, "v_q": v_q, "v_s": v_s,
                    "block_table": cache["block_table"]},
                jnp.minimum(pos + 1, S), layout=layout)
        else:
            k_q, k_s = cache_insert(k_q, k_s, pos, k[:, 0])
            v_q, v_s = cache_insert(v_q, v_s, pos, v[:, 0])
            o = decode_attention_quant(q, k_q, k_s, v_q, v_s, pos + 1,
                                       impl=ctx.decode_impl,
                                       block_k=ctx.decode_block_k)
        x = x + o.reshape(B, 1, cfg.q_dim) @ blk["attn"]["wo"]
        f_out, _ = tf.ffn_apply(cfg, blk["ffn"], x, ctx)
        x = x + f_out
        return x, (k_q, k_s, v_q, v_s)

    h, (kqs, kss, vqs, vss) = jax.lax.scan(
        body, h, (params["blocks"], cache["k_q"], cache["k_s"],
                  cache["v_q"], cache["v_s"]))
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = layers.lm_logits(cfg, params, h)
    out = {"k_q": kqs, "k_s": kss, "v_q": vqs, "v_s": vss,
           "len": cache["len"] + 1}
    if paged:
        out["block_table"] = cache["block_table"]
        out["write_table"] = cache["write_table"]
    return logits, out


def quant_decode_spec(cfg, params, cache: Dict, tokens, ctx=None,
                      q_lens=None):
    """Speculative k-row twin of :func:`quant_decode_step` (uniform family,
    dense or paged int8 cache).

    tokens (B, k) -> (logits (B, k, V), accepts (B,), committed cache with
    ``len += accepts``).  The k rows' K/V quantize and land at positions
    ``len + j`` before attention; :func:`decode_attention_quant` (or the
    paged layout dispatch) gives draft row ``j`` effective length
    ``len + 1 + j`` and ``q_lens`` caps live rows.  Rejected rows leave
    int8 garbage at dead positions only (>= the committed length) — the
    same no-rollback argument as the bf16 linear caches."""
    from repro.models import layers
    from repro.models import transformer as tf
    if tf.family(cfg) != "uniform":
        raise NotImplementedError("quant_decode_spec: uniform family only")
    if ctx is None:
        ctx = tf.ModelCtx()
    B, Sq = tokens.shape
    if q_lens is None:
        q_lens = jnp.full((B,), Sq, jnp.int32)
    q_lens = q_lens.astype(jnp.int32)
    lens = cache["len"]
    pos = lens[:, None] + jnp.arange(Sq)[None]          # (B, k) absolute
    b_idx = jnp.arange(B)[:, None]
    h = layers.embed_tokens(params["embed"], tokens)
    paged = "block_table" in cache
    if paged:
        from repro.cache_layout import CacheLayout
        from repro.kernels import ops
        bs = cache["k_q"].shape[2]
        nb = cache["block_table"].shape[1]
        S = nb * bs
        blk = jnp.minimum(pos // bs, nb - 1)
        phys = cache["write_table"][b_idx, blk]
        phys = jnp.where(pos < S, phys, 0)    # overflow rows -> null block
        off = pos % bs
        layout = CacheLayout(kind="paged", kv_bits=8, impl=ctx.decode_impl,
                             block_size=bs)
    else:
        S = cache["k_q"].shape[2]

    def body(x, inp):
        blk_p, k_q, k_s, v_q, v_s = inp
        hn = layers.apply_norm(cfg, blk_p["attn"]["norm"], x)
        q, k, v = tf._qkv(cfg, blk_p["attn"], hn, pos, ctx)
        kq_new, ks_new = quantize_kv(k)
        vq_new, vs_new = quantize_kv(v)
        if paged:
            k_q = k_q.at[phys, off].set(kq_new)
            k_s = k_s.at[phys, off].set(ks_new)
            v_q = v_q.at[phys, off].set(vq_new)
            v_s = v_s.at[phys, off].set(vs_new)
            o = ops.decode_attention(
                q, {"k_q": k_q, "k_s": k_s, "v_q": v_q, "v_s": v_s,
                    "block_table": cache["block_table"]},
                jnp.minimum(lens + 1, S), layout=layout, q_lens=q_lens)
        else:
            k_q = k_q.at[b_idx, pos].set(kq_new, mode="drop")
            k_s = k_s.at[b_idx, pos].set(ks_new, mode="drop")
            v_q = v_q.at[b_idx, pos].set(vq_new, mode="drop")
            v_s = v_s.at[b_idx, pos].set(vs_new, mode="drop")
            o = decode_attention_quant(q, k_q, k_s, v_q, v_s, lens + 1,
                                       impl=ctx.decode_impl,
                                       block_k=ctx.decode_block_k,
                                       q_lens=q_lens)
        x = x + o.reshape(B, Sq, cfg.q_dim) @ blk_p["attn"]["wo"]
        f_out, _ = tf.ffn_apply(cfg, blk_p["ffn"], x, ctx)
        x = x + f_out
        return x, (k_q, k_s, v_q, v_s)

    h, (kqs, kss, vqs, vss) = jax.lax.scan(
        body, h, (params["blocks"], cache["k_q"], cache["k_s"],
                  cache["v_q"], cache["v_s"]))
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = layers.lm_logits(cfg, params, h)
    accepts = tf.verify_greedy(tokens, logits, q_lens)
    out = {"k_q": kqs, "k_s": kss, "v_q": vqs, "v_s": vss,
           "len": cache["len"] + accepts}
    if paged:
        out["block_table"] = cache["block_table"]
        out["write_table"] = cache["write_table"]
    return logits, accepts, out


def quant_prefill_kv(cfg, params, batch: Dict, ctx=None):
    """Full-sequence prefill forward returning quantized per-layer K/V.

    Returns (logits (B, S, V), (k_q, k_s, v_q, v_s)) with the K/V stacked
    (L, B, S, Hk, D) / scales (L, B, S, Hk), ready to scatter into an
    :func:`init_model_quant_cache` slot."""
    from repro.models import transformer as tf
    if tf.family(cfg) != "uniform":
        raise NotImplementedError("quant prefill: uniform family only")
    if ctx is None:
        ctx = tf.ModelCtx()
    logits, _, kvs = tf.forward(cfg, params, batch, ctx, collect_kv=True)
    k, v = kvs
    k_q, k_s = quantize_kv(k)
    v_q, v_s = quantize_kv(v)
    return logits, (k_q, k_s, v_q, v_s)


def decode_attention_quant(q, k_q, k_s, v_q, v_s, lengths,
                           softmax_scale=None, impl="dense", block_k=128,
                           q_lens=None):
    """Decode against an int8 cache.

    q: (B, Sq, H, D); k_q/v_q: (B, S, Hk, D) int8; k_s/v_s: (B, S, Hk).
    Sq > 1 is speculative k-row verification: draft row ``j`` attends with
    effective length ``lengths + j`` and ``q_lens`` (B,) caps live rows.
    The score matmul runs int8 x bf16 -> f32 with the scale folded in
    afterwards (on TPU this is an int8 MXU pass — cache bytes halve AND
    the matmul rate doubles).  ``impl="flash"`` routes through the fused
    Pallas flash-decode kernel (in-kernel tile dequantization, per-slot
    KV-block skipping) so the quantized cache is attended without ever
    materializing a bf16 copy — and without streaming dead positions.
    Empty slots (``len == 0``) produce exactly-zero outputs on both paths.
    """
    if impl == "flash":
        from repro.kernels import ops
        return ops.flash_decode_quant(q, k_q, k_s, v_q, v_s, lengths,
                                      softmax_scale=softmax_scale,
                                      block_k=block_k, q_lens=q_lens)
    if impl != "dense":
        raise ValueError(f"decode impl {impl!r} (want dense|flash)")
    B, Sq, H, D = q.shape
    _, S, Hk, _ = k_q.shape
    G = H // Hk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if q_lens is None:
        q_lens = jnp.full((B,), Sq, jnp.int32)
    qg = q.reshape(B, Sq, Hk, G, D)
    s = jnp.einsum("bjhgd,bkhd->bhjgk", qg.astype(jnp.float32),
                   k_q.astype(jnp.float32))
    s = s * k_s.transpose(0, 2, 1)[:, :, None, None, :] * scale
    pos_k = jnp.arange(S)[None, None, :]
    eff = (lengths[:, None] + jnp.arange(Sq)[None, :])[:, :, None]
    valid = pos_k < eff
    valid &= (jnp.arange(Sq)[None, :] < q_lens[:, None])[:, :, None]
    s = jnp.where(valid[:, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, :, None, :], p, 0.0)        # len==0 -> 0
    pv = jnp.einsum("bhjgk,bkhd->bjhgd",
                    (p * v_s.transpose(0, 2, 1)[:, :, None, None, :]),
                    v_q.astype(jnp.float32))
    return pv.reshape(B, Sq, H, D).astype(q.dtype)
