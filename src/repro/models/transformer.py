"""Composable transformer stacks for every assigned architecture family.

Families and their parameter layouts:

* ``uniform``  — dense / MoE decoder-only (internlm2, olmo, deepseek, moonshot,
  qwen3-moe, qwen2-vl, recllm): one ``lax.scan`` over L stacked layers.
* ``rwkv6``    — attention-free stack (token-shift time-mix + channel-mix).
* ``jamba``    — periods of [attn, mamba x7] with MoE every other FFN; scan
  over periods, unrolled inside.
* ``gemma``    — 5 local : 1 global attention; 26 small layers, fully unrolled
  (heterogeneous ring-buffer vs full KV caches).
* ``whisper``  — encoder-decoder; conv frontend stubbed (precomputed frames).

All functions are pure; distribution enters only through ``ModelCtx.constrain``
(activation sharding hooks installed by ``core.sharding``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import attention as attn_lib
from repro.models import layers, moe, ssm


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Runtime knobs threaded through the stack (not part of params)."""
    attn_impl: str = "chunked"       # naive | chunked | pallas
    attn_chunk: int = 1024
    decode_impl: str = "dense"       # dense | flash (Pallas flash-decode)
    decode_block_k: int = 128        # flash-decode KV block (skip quantum)
    mamba_chunk: int = 512
    remat: bool = False
    use_kernels: bool = False
    moe_group: int = 256
    moe_capacity_factor: float = 1.25
    flash_vjp: bool = False          # custom flash backward (dp_heavy/no-TP)
    constrain: Callable[[jnp.ndarray, str], jnp.ndarray] = \
        staticmethod(lambda x, name: x)


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: ArchConfig, cross: bool = False) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    p = {
        "norm": layers.init_norm(cfg),
        "wq": layers.init_dense(ks[0], d, cfg.q_dim, dtype),
        "wk": layers.init_dense(ks[1], d, cfg.kv_dim, dtype),
        "wv": layers.init_dense(ks[2], d, cfg.kv_dim, dtype),
        "wo": layers.init_dense(ks[3], cfg.q_dim, d, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
    return p


def _qkv(cfg: ArchConfig, p: Dict, h, positions, ctx: ModelCtx,
         rope: bool = True):
    B, S, _ = h.shape
    q = (h @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (h @ p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (h @ p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm and "q_norm" in p:
        q = layers.rms_norm_simple(q, p["q_norm"])
        k = layers.rms_norm_simple(k, p["k_norm"])
    if rope and cfg.pos_type in ("rope", "mrope"):
        q = layers.position_embedding(cfg, q, positions)
        k = layers.position_embedding(cfg, k, positions)
    q = ctx.constrain(q, "heads")
    k = ctx.constrain(k, "kv_heads")
    v = ctx.constrain(v, "kv_heads")
    return q, k, v


def attn_apply(cfg: ArchConfig, p: Dict, x, positions, ctx: ModelCtx,
               *, window: int = 0, return_kv: bool = False):
    """Full-sequence (train/prefill) self-attention residual branch."""
    h = layers.apply_norm(cfg, p["norm"], x)
    q, k, v = _qkv(cfg, p, h, positions, ctx)
    o = attn_lib.attention(q, k, v, causal=True, window=window,
                           impl=ctx.attn_impl, chunk=ctx.attn_chunk,
                           flash_vjp=ctx.flash_vjp)
    out = ctx.constrain(o.reshape(x.shape[0], x.shape[1], cfg.q_dim)
                        @ p["wo"], "residual")
    if return_kv:
        return out, (k, v)
    return out, None


def attn_decode(cfg: ArchConfig, p: Dict, x, position, ctx: ModelCtx,
                k_cache, v_cache, cache_len, *, window: int = 0):
    """One-token decode.  x:(B,1,d); caches (B,S,Hk,D); cache_len (B,).

    Returns (out, k_cache, v_cache).  For ``window>0`` the cache is a ring
    buffer of size W (softmax is permutation-invariant over keys; RoPE is
    applied with absolute positions before insertion)."""
    B = x.shape[0]
    S = k_cache.shape[1]
    h = layers.apply_norm(cfg, p["norm"], x)
    q, k, v = _qkv(cfg, p, h, position[:, None] if position.ndim == 1 else position,
                   ctx)
    slot = cache_len % S if window > 0 else cache_len
    k_cache = k_cache.at[jnp.arange(B), slot].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[jnp.arange(B), slot].set(v[:, 0].astype(v_cache.dtype))
    if window > 0:
        # ring-buffer cache: unclamped lengths + wraparound band masking
        # (ring rows hold permuted absolute positions; with window == S the
        # band covers every written row, reducing to the length clamp)
        o = attn_lib.decode_attention(q, k_cache, v_cache, cache_len + 1,
                                      window=window, ring=True,
                                      impl=ctx.decode_impl,
                                      block_k=ctx.decode_block_k)
    else:
        valid = jnp.minimum(cache_len + 1, S)
        o = attn_lib.decode_attention(q, k_cache, v_cache, valid,
                                      impl=ctx.decode_impl,
                                      block_k=ctx.decode_block_k)
    out = o.reshape(B, 1, cfg.q_dim) @ p["wo"]
    return out, k_cache, v_cache


def attn_decode_paged(cfg: ArchConfig, p: Dict, x, position, ctx: ModelCtx,
                      k_pool, v_pool, read_table, write_table, cache_len):
    """One-token decode against a paged cache.  x (B,1,d); pools
    (N, bs, Hk, D) shared across slots; tables (B, nb) int32; cache_len (B,).

    The new K/V row lands at physical block ``write_table[b, len//bs]``,
    row ``len % bs`` — the *write* table, so slots that do not own their
    frontier block (shared prefix tails awaiting copy-on-write, or retired
    slots with zeroed tables) scatter into the reserved null block 0
    instead of corrupting a neighbour.  The engine guarantees every
    *active* slot's frontier is exclusively owned (read == write) before
    the step, so live tokens always land in readable rows.  Attention then
    reads through the *read* table via the unified layout dispatch."""
    from repro.cache_layout import CacheLayout
    from repro.kernels import ops
    B = x.shape[0]
    bs = k_pool.shape[1]
    S = read_table.shape[1] * bs                 # virtual position space
    h = layers.apply_norm(cfg, p["norm"], x)
    q, k, v = _qkv(cfg, p, h,
                   position[:, None] if position.ndim == 1 else position,
                   ctx)
    blk = cache_len // bs
    off = cache_len % bs
    phys = write_table[jnp.arange(B), blk]
    k_pool = k_pool.at[phys, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[phys, off].set(v[:, 0].astype(v_pool.dtype))
    layout = CacheLayout(kind="paged", impl=ctx.decode_impl, block_size=bs)
    valid = jnp.minimum(cache_len + 1, S)
    o = ops.decode_attention(q, {"k": k_pool, "v": v_pool,
                                 "block_table": read_table}, valid,
                             layout=layout)
    out = o.reshape(B, 1, cfg.q_dim) @ p["wo"]
    return out, k_pool, v_pool


def attn_decode_spec(cfg: ArchConfig, p: Dict, x, position, ctx: ModelCtx,
                     k_cache, v_cache, cache_len, q_lens, *,
                     window: int = 0, snapshot: bool = False):
    """Speculative k-row decode.  x: (B, k, d); position (B, k) (or
    (B, k, 3) mrope); caches (B, S, Hk, D); cache_len (B,) committed rows;
    q_lens (B,) in [1, k] live rows per slot.

    All k rows' K/V land at positions ``cache_len + j`` *before* the
    attention; the k-row decode kernels give draft row ``j`` the effective
    length ``cache_len + 1 + j`` (causal intra-draft: cache plus rows
    ``<= j``) and zero out rows ``>= q_lens``.  Dead/rejected rows leave
    garbage only at positions beyond the committed length — masked until
    linear appends overwrite them — so linear caches need no rollback.

    Ring caches (``window > 0``): rows land at ``(cache_len + j) % S``.
    Exactness against row-by-row decode needs ``S >= window + k - 1``
    (:func:`init_cache` ``spec_margin``): then a slot written by row
    ``j' > j`` is outside row ``j``'s window band — exactly as the old
    position it overwrote would have been.  ``snapshot=True`` also returns
    the k overwritten (k, v) row pairs so the caller can restore rejected
    rows post-verification (:func:`_restore_ring_rows`)."""
    B, Sq = x.shape[:2]
    S = k_cache.shape[1]
    h = layers.apply_norm(cfg, p["norm"], x)
    q, k, v = _qkv(cfg, p, h, position, ctx)
    b_idx = jnp.arange(B)[:, None]
    pos = cache_len[:, None] + jnp.arange(Sq)[None]
    snaps = None
    if window > 0:
        slots = pos % S
        if snapshot:
            snaps = (k_cache[b_idx, slots], v_cache[b_idx, slots])
        k_cache = k_cache.at[b_idx, slots].set(k.astype(k_cache.dtype))
        v_cache = v_cache.at[b_idx, slots].set(v.astype(v_cache.dtype))
        o = attn_lib.decode_attention(q, k_cache, v_cache, cache_len + 1,
                                      window=window, ring=True,
                                      impl=ctx.decode_impl,
                                      block_k=ctx.decode_block_k,
                                      q_lens=q_lens)
    else:
        # dead rows spilling past the cache end are dropped, not clamped —
        # a clamp would race them against row S-1's live write
        k_cache = k_cache.at[b_idx, pos].set(k.astype(k_cache.dtype),
                                             mode="drop")
        v_cache = v_cache.at[b_idx, pos].set(v.astype(v_cache.dtype),
                                             mode="drop")
        o = attn_lib.decode_attention(q, k_cache, v_cache,
                                      jnp.minimum(cache_len + 1, S),
                                      impl=ctx.decode_impl,
                                      block_k=ctx.decode_block_k,
                                      q_lens=q_lens)
    out = o.reshape(B, Sq, cfg.q_dim) @ p["wo"]
    return out, k_cache, v_cache, snaps


def attn_decode_paged_spec(cfg: ArchConfig, p: Dict, x, position,
                           ctx: ModelCtx, k_pool, v_pool, read_table,
                           write_table, cache_len, q_lens):
    """Speculative k-row twin of :func:`attn_decode_paged`: the k-token
    span scatters through the write table (row ``j`` at physical block
    ``write_table[b, (len + j) // bs]``, offset ``(len + j) % bs``); rows
    overflowing the virtual space land in the null block 0.  The engine
    pre-owns every block the live span touches
    (:meth:`~repro.serving.block_pool.SlotTables.ensure_writable_span`),
    so accepted rows always land in readable blocks; rejected rows leave
    garbage at dead positions only."""
    from repro.cache_layout import CacheLayout
    from repro.kernels import ops
    B, Sq = x.shape[:2]
    bs = k_pool.shape[1]
    nb = read_table.shape[1]
    S = nb * bs
    h = layers.apply_norm(cfg, p["norm"], x)
    q, k, v = _qkv(cfg, p, h, position, ctx)
    pos = cache_len[:, None] + jnp.arange(Sq)[None]
    blk = jnp.minimum(pos // bs, nb - 1)
    phys = write_table[jnp.arange(B)[:, None], blk]
    phys = jnp.where(pos < S, phys, 0)
    off = pos % bs
    k_pool = k_pool.at[phys, off].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[phys, off].set(v.astype(v_pool.dtype))
    layout = CacheLayout(kind="paged", impl=ctx.decode_impl, block_size=bs)
    o = ops.decode_attention(q, {"k": k_pool, "v": v_pool,
                                 "block_table": read_table},
                             jnp.minimum(cache_len + 1, S), layout=layout,
                             q_lens=q_lens)
    out = o.reshape(B, Sq, cfg.q_dim) @ p["wo"]
    return out, k_pool, v_pool


def _restore_ring_rows(k_cache, v_cache, snaps, cache_len, accepts, Sq: int):
    """Put back the pre-step (k, v) ring rows for rejected draft rows
    (``j >= accepts``) — the rollback half of gemma ring speculation.
    ``snaps``: the (B, Sq, Hk, D) row pairs :func:`attn_decode_spec`
    captured before writing."""
    S = k_cache.shape[1]
    B = k_cache.shape[0]
    b_idx = jnp.arange(B)[:, None]
    slots = (cache_len[:, None] + jnp.arange(Sq)[None]) % S
    keep = (jnp.arange(Sq)[None] < accepts[:, None])[..., None, None]
    snap_k, snap_v = snaps
    k_cache = k_cache.at[b_idx, slots].set(
        jnp.where(keep, k_cache[b_idx, slots], snap_k))
    v_cache = v_cache.at[b_idx, slots].set(
        jnp.where(keep, v_cache[b_idx, slots], snap_v))
    return k_cache, v_cache


def init_cross_attn(key, cfg: ArchConfig) -> Dict:
    return init_attn_block(key, cfg, cross=True)


def cross_attn_apply(cfg: ArchConfig, p: Dict, x, enc_kv, ctx: ModelCtx):
    """enc_kv: precomputed (k, v) from encoder output, (B,F,Hk,D)."""
    B, S, _ = x.shape
    h = layers.apply_norm(cfg, p["norm"], x)
    q = (h @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k, v = enc_kv
    o = attn_lib.attention(q, k, v, causal=False, impl="naive"
                           if S == 1 else ctx.attn_impl, chunk=ctx.attn_chunk)
    return o.reshape(B, S, cfg.q_dim) @ p["wo"]


def enc_kv(cfg: ArchConfig, p: Dict, enc_out):
    B, F, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
    return k, v


# ---------------------------------------------------------------------------
# FFN block (dense MLP or MoE)
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ArchConfig, is_moe: bool) -> Dict:
    p = {"norm": layers.init_norm(cfg)}
    if is_moe:
        p["moe"] = moe.init_moe(key, cfg)
    else:
        p["mlp"] = layers.init_mlp(key, cfg)
    return p


def ffn_apply(cfg: ArchConfig, p: Dict, x, ctx: ModelCtx, live=None):
    """``live`` (optional (B, S) mask, serving prefill): positions masked
    out are excluded from MoE routing/capacity — see :func:`moe.moe_ffn`.
    Dense MLPs are per-token, so the mask is irrelevant there."""
    h = layers.apply_norm(cfg, p["norm"], x)
    if "moe" in p:
        out, aux = moe.moe_ffn(cfg, p["moe"], h, group_size=ctx.moe_group,
                               capacity_factor=ctx.moe_capacity_factor,
                               use_kernel=ctx.use_kernels,
                               constrain=ctx.constrain, live=live)
    else:
        out, aux = layers.apply_mlp(cfg, p["mlp"], h), None
    return ctx.constrain(out, "residual"), aux


def zero_aux(cfg: ArchConfig) -> Dict:
    a = {"lb_loss": jnp.zeros((), jnp.float32),
         "z_loss": jnp.zeros((), jnp.float32)}
    if cfg.is_moe:
        a["expert_load"] = jnp.zeros((cfg.num_experts,), jnp.float32)
    return a


def _aux_of(aux, cfg: ArchConfig) -> Dict:
    if aux is None:
        return zero_aux(cfg)
    a = {"lb_loss": jnp.asarray(aux["lb_loss"], jnp.float32),
         "z_loss": jnp.asarray(aux["z_loss"], jnp.float32)}
    if cfg.is_moe:
        a["expert_load"] = jnp.asarray(aux["expert_load"], jnp.float32)
    return a


def _sum_aux(a, b):
    return {k: a[k] + b[k] for k in a}


# ---------------------------------------------------------------------------
# Family: uniform decoder-only (dense / full-MoE / vlm)
# ---------------------------------------------------------------------------

def _init_uniform_layer(key, cfg: ArchConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {"attn": init_attn_block(k1, cfg),
            "ffn": init_ffn(k2, cfg, cfg.is_moe)}


def _stack_init(key, n: int, init_one) -> Dict:
    ks = jax.random.split(key, n)
    per = [init_one(k) for k in ks]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def init_params(key, cfg: ArchConfig) -> Dict:
    """Entry point: params for any family."""
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": layers.init_embedding(ks[0], cfg),
                              "final_norm": layers.init_norm(cfg)}
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_dense(
            ks[1], cfg.d_model, cfg.padded_vocab, jnp.dtype(cfg.dtype))

    fam = family(cfg)
    if fam == "uniform":
        params["blocks"] = _stack_init(
            ks[2], cfg.num_layers, lambda k: _init_uniform_layer(k, cfg))
    elif fam == "rwkv6":
        def one(k):
            k1, k2 = jax.random.split(k)
            return {"tmix": ssm.init_rwkv6(k1, cfg),
                    "cmix": ssm.init_rwkv_cmix(k2, cfg),
                    "norm1": layers.init_norm(cfg),
                    "norm2": layers.init_norm(cfg)}
        params["blocks"] = _stack_init(ks[2], cfg.num_layers, one)
    elif fam == "jamba":
        n_periods = cfg.num_layers // cfg.attn_period
        def one_period(k):
            kk = jax.random.split(k, 4)
            per = cfg.attn_period
            n_moe = per // 2
            return {
                "attn": init_attn_block(kk[0], cfg),
                "mamba": _stack_init(kk[1], per - 1,
                                     lambda k2: {"norm": layers.init_norm(cfg),
                                                 "m": ssm.init_mamba(k2, cfg)}),
                "ffn_dense": _stack_init(
                    kk[2], per - n_moe, lambda k2: init_ffn(k2, cfg, False)),
                "ffn_moe": _stack_init(
                    kk[3], n_moe, lambda k2: init_ffn(k2, cfg, True)),
            }
        params["blocks"] = _stack_init(ks[2], n_periods, one_period)
    elif fam == "gemma":
        params["blocks"] = tuple(
            _init_uniform_layer(k, cfg) for k in jax.random.split(
                ks[2], cfg.num_layers))
    elif fam == "whisper":
        def dec_layer(k):
            kk = jax.random.split(k, 3)
            return {"attn": init_attn_block(kk[0], cfg),
                    "cross": init_cross_attn(kk[1], cfg),
                    "ffn": init_ffn(kk[2], cfg, False)}
        params["blocks"] = _stack_init(ks[2], cfg.num_layers, dec_layer)
        params["enc_blocks"] = _stack_init(
            ks[3], cfg.encoder_layers, lambda k: _init_uniform_layer(k, cfg))
        params["enc_final_norm"] = layers.init_norm(cfg)
        params["dec_pos"] = (jax.random.normal(
            ks[4], (32768, cfg.d_model), jnp.float32) * 0.01
        ).astype(jnp.dtype(cfg.dtype))
    else:
        raise ValueError(fam)
    return params


def family(cfg: ArchConfig) -> str:
    if cfg.ssm_type == "rwkv6":
        return "rwkv6"
    if cfg.ssm_type == "mamba":
        return "jamba"
    if cfg.local_global_pattern > 0:
        return "gemma"
    if cfg.encoder_layers > 0:
        return "whisper"
    return "uniform"


def _maybe_remat(fn, ctx: ModelCtx):
    return jax.checkpoint(fn) if ctx.remat else fn


# --- uniform forward --------------------------------------------------------

def _uniform_forward(cfg, params, h, positions, ctx, collect_kv: bool,
                     live=None):
    def body(carry, blk):
        x, aux = carry
        a_out, kv = attn_apply(cfg, blk["attn"], x, positions, ctx,
                               return_kv=collect_kv)
        x = x + a_out
        f_out, f_aux = ffn_apply(cfg, blk["ffn"], x, ctx, live=live)
        x = x + f_out
        return (x, _sum_aux(aux, _aux_of(f_aux, cfg))), kv

    body = _maybe_remat(body, ctx)
    (h, aux), kvs = jax.lax.scan(body, (h, zero_aux(cfg)), params["blocks"])
    return h, aux, kvs


def _uniform_decode(cfg, params, h, position, ctx, cache):
    def body(carry, inp):
        x = carry
        blk, kc, vc = inp
        a_out, kc, vc = attn_decode(cfg, blk["attn"], x, position, ctx,
                                    kc, vc, cache["len"])
        x = x + a_out
        f_out, _ = ffn_apply(cfg, blk["ffn"], x, ctx)
        x = x + f_out
        return x, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(body, h, (params["blocks"],
                                           cache["k"], cache["v"]))
    return h, {"k": kcs, "v": vcs, "len": cache["len"] + 1}


def _uniform_decode_paged(cfg, params, h, position, ctx, cache):
    read_t = cache["block_table"]
    write_t = cache["write_table"]

    def body(x, inp):
        blk, kp, vp = inp
        a_out, kp, vp = attn_decode_paged(cfg, blk["attn"], x, position, ctx,
                                          kp, vp, read_t, write_t,
                                          cache["len"])
        x = x + a_out
        f_out, _ = ffn_apply(cfg, blk["ffn"], x, ctx)
        x = x + f_out
        return x, (kp, vp)

    h, (kps, vps) = jax.lax.scan(body, h, (params["blocks"],
                                           cache["k"], cache["v"]))
    return h, {"k": kps, "v": vps, "block_table": read_t,
               "write_table": write_t, "len": cache["len"] + 1}


def _uniform_decode_spec(cfg, params, h, position, ctx, cache, q_lens):
    def body(x, inp):
        blk, kc, vc = inp
        a_out, kc, vc, _ = attn_decode_spec(cfg, blk["attn"], x, position,
                                            ctx, kc, vc, cache["len"], q_lens)
        x = x + a_out
        f_out, _ = ffn_apply(cfg, blk["ffn"], x, ctx)
        x = x + f_out
        return x, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(body, h, (params["blocks"],
                                           cache["k"], cache["v"]))
    return h, {"k": kcs, "v": vcs, "len": cache["len"]}


def _uniform_decode_paged_spec(cfg, params, h, position, ctx, cache, q_lens):
    read_t = cache["block_table"]
    write_t = cache["write_table"]

    def body(x, inp):
        blk, kp, vp = inp
        a_out, kp, vp = attn_decode_paged_spec(
            cfg, blk["attn"], x, position, ctx, kp, vp, read_t, write_t,
            cache["len"], q_lens)
        x = x + a_out
        f_out, _ = ffn_apply(cfg, blk["ffn"], x, ctx)
        x = x + f_out
        return x, (kp, vp)

    h, (kps, vps) = jax.lax.scan(body, h, (params["blocks"],
                                           cache["k"], cache["v"]))
    return h, {"k": kps, "v": vps, "block_table": read_t,
               "write_table": write_t, "len": cache["len"]}


# --- rwkv forward ------------------------------------------------------------

def _rwkv_forward(cfg, params, h, ctx):
    def body(x, blk):
        t_out, _ = ssm.rwkv6_forward(cfg, blk["tmix"],
                                     layers.apply_norm(cfg, blk["norm1"], x))
        x = x + t_out
        c_out, _ = ssm.rwkv_cmix_forward(cfg, blk["cmix"],
                                         layers.apply_norm(cfg, blk["norm2"], x))
        x = ctx.constrain(x + c_out, "residual")
        return x, None

    body = _maybe_remat(body, ctx)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    return h


def _rwkv_decode(cfg, params, h, ctx, cache):
    def body(x, inp):
        blk, st = inp
        xn = layers.apply_norm(cfg, blk["norm1"], x)
        t_out, tstate = ssm.rwkv6_forward(
            cfg, blk["tmix"], xn, state={"last": st["tmix_last"],
                                         "wkv": st["wkv"]})
        x = x + t_out
        xn2 = layers.apply_norm(cfg, blk["norm2"], x)
        c_out, clast = ssm.rwkv_cmix_forward(cfg, blk["cmix"], xn2,
                                             state=st["cmix_last"])
        x = x + c_out
        new_st = {"tmix_last": xn[:, -1], "wkv": tstate["wkv"],
                  "cmix_last": xn2[:, -1]}
        return x, new_st

    h, states = jax.lax.scan(body, h, (params["blocks"], cache["states"]))
    return h, {"states": states, "len": cache["len"] + 1}


# --- jamba forward -----------------------------------------------------------

def _jamba_ffn_idx(j: int) -> Tuple[str, int]:
    # global layer index within period: j odd -> MoE slot j//2, else dense j//2
    return ("ffn_moe", j // 2) if j % 2 == 1 else ("ffn_dense", j // 2)


def _jamba_forward(cfg, params, h, positions, ctx, collect_kv: bool,
                   live=None):
    per = cfg.attn_period

    # nested remat: each sublayer is its own checkpoint so the period
    # backward holds one sublayer's recomputed internals at a time (the
    # period body is 8 layers — period-level remat alone peaks at 8x).
    def attn_sub(blk, x):
        a_out, kvs = attn_apply(cfg, blk["attn"], x, positions, ctx,
                                return_kv=collect_kv)
        return x + a_out, kvs

    def mamba_sub(mblk, x):
        m_out, _ = ssm.mamba_forward(
            cfg, mblk["m"], layers.apply_norm(cfg, mblk["norm"], x),
            chunk=ctx.mamba_chunk)
        return x + ctx.constrain(m_out, "residual")

    def ffn_sub(fblk, x):
        f_out, f_aux = ffn_apply(cfg, fblk, x, ctx, live=live)
        return x + f_out, _aux_of(f_aux, cfg)

    if ctx.remat:
        attn_sub = jax.checkpoint(attn_sub)
        mamba_sub = jax.checkpoint(mamba_sub)
        ffn_sub = jax.checkpoint(ffn_sub)

    def body(carry, blk):
        x, aux = carry
        kvs = None
        for j in range(per):
            if j == 0:
                x, kvs = attn_sub(blk, x)
            else:
                mblk = jax.tree.map(lambda a: a[j - 1], blk["mamba"])
                x = mamba_sub(mblk, x)
            name, idx = _jamba_ffn_idx(j)
            fblk = jax.tree.map(lambda a: a[idx], blk[name])
            x, f_aux = ffn_sub(fblk, x)
            aux = _sum_aux(aux, f_aux)
        return (x, aux), kvs

    body = _maybe_remat(body, ctx)
    (h, aux), kvs = jax.lax.scan(body, (h, zero_aux(cfg)), params["blocks"])
    return h, aux, kvs


def _jamba_decode(cfg, params, h, position, ctx, cache):
    per = cfg.attn_period

    def body(x, inp):
        blk, kc, vc, mstates = inp
        new_m = []
        for j in range(per):
            if j == 0:
                a_out, kc, vc = attn_decode(cfg, blk["attn"], x, position, ctx,
                                            kc, vc, cache["len"])
                x = x + a_out
            else:
                mblk = jax.tree.map(lambda a: a[j - 1], blk["mamba"])
                mst = jax.tree.map(lambda a: a[j - 1], mstates)
                m_out, mst = ssm.mamba_decode_step(
                    cfg, mblk["m"], layers.apply_norm(cfg, mblk["norm"], x), mst)
                new_m.append(mst)
                x = x + m_out
            name, idx = _jamba_ffn_idx(j)
            fblk = jax.tree.map(lambda a: a[idx], blk[name])
            f_out, _ = ffn_apply(cfg, fblk, x, ctx)
            x = x + f_out
        new_m = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
        return x, (kc, vc, new_m)

    h, (kcs, vcs, ms) = jax.lax.scan(
        body, h, (params["blocks"], cache["k"], cache["v"], cache["mamba"]))
    return h, {"k": kcs, "v": vcs, "mamba": ms, "len": cache["len"] + 1}


# --- gemma forward (unrolled heterogeneous local/global) ---------------------

def _gemma_forward(cfg, params, h, positions, ctx, collect_kv: bool,
                   live=None):
    kinds = cfg.layer_kinds()
    kvs = []
    aux = zero_aux(cfg)

    def layer(x, blk, window):
        a_out, kv = attn_apply(cfg, blk["attn"], x, positions, ctx,
                               window=window, return_kv=collect_kv)
        x = x + a_out
        f_out, f_aux = ffn_apply(cfg, blk["ffn"], x, ctx, live=live)
        return x + f_out, kv, f_aux

    for blk, kind in zip(params["blocks"], kinds):
        window = cfg.sliding_window if kind == "local_attn" else 0
        fn = _maybe_remat(partial(layer, window=window), ctx)
        h, kv, f_aux = fn(h, blk)
        aux = _sum_aux(aux, _aux_of(f_aux, cfg))
        kvs.append(kv)
    return h, aux, kvs


def _gemma_decode(cfg, params, h, position, ctx, cache):
    kinds = cfg.layer_kinds()
    new_k, new_v = [], []
    for i, (blk, kind) in enumerate(zip(params["blocks"], kinds)):
        window = cfg.sliding_window if kind == "local_attn" else 0
        a_out, kc, vc = attn_decode(cfg, blk["attn"], h, position, ctx,
                                    cache["k"][i], cache["v"][i], cache["len"],
                                    window=window)
        h = h + a_out
        f_out, _ = ffn_apply(cfg, blk["ffn"], h, ctx)
        h = h + f_out
        new_k.append(kc)
        new_v.append(vc)
    return h, {"k": tuple(new_k), "v": tuple(new_v), "len": cache["len"] + 1}


def _gemma_decode_spec(cfg, params, h, position, ctx, cache, q_lens):
    """k-row gemma decode: global layers are linear (no rollback needed);
    local ring layers snapshot the k rows they overwrite so
    :func:`decode_spec` can restore the rejected ones post-verification.
    Returns (h, cache, snaps) with ``snaps[i]`` None for global layers."""
    kinds = cfg.layer_kinds()
    new_k, new_v, snaps = [], [], []
    for i, (blk, kind) in enumerate(zip(params["blocks"], kinds)):
        window = cfg.sliding_window if kind == "local_attn" else 0
        a_out, kc, vc, snap = attn_decode_spec(
            cfg, blk["attn"], h, position, ctx, cache["k"][i], cache["v"][i],
            cache["len"], q_lens, window=window, snapshot=window > 0)
        h = h + a_out
        f_out, _ = ffn_apply(cfg, blk["ffn"], h, ctx)
        h = h + f_out
        new_k.append(kc)
        new_v.append(vc)
        snaps.append(snap)
    return h, {"k": tuple(new_k), "v": tuple(new_v),
               "len": cache["len"]}, snaps


# --- whisper (enc-dec) --------------------------------------------------------

def _sinusoid(F: int, d: int):
    pos = jnp.arange(F)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def whisper_encode(cfg, params, frames, ctx):
    """frames: (B, F, d) precomputed by the (stubbed) conv frontend."""
    h = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(x, blk):
        hn = layers.apply_norm(cfg, blk["attn"]["norm"], x)
        B, F, _ = hn.shape
        q = (hn @ blk["attn"]["wq"]).reshape(B, F, cfg.num_heads, cfg.head_dim)
        k = (hn @ blk["attn"]["wk"]).reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
        v = (hn @ blk["attn"]["wv"]).reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
        o = attn_lib.attention(q, k, v, causal=False, impl=ctx.attn_impl,
                               chunk=ctx.attn_chunk)
        x = x + o.reshape(B, F, cfg.q_dim) @ blk["attn"]["wo"]
        f_out, _ = ffn_apply(cfg, blk["ffn"], x, ctx)
        return x + f_out, None

    body = _maybe_remat(body, ctx)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return layers.apply_norm(cfg, params["enc_final_norm"], h)


def _whisper_dec_forward(cfg, params, h, positions, enc_out, ctx,
                         collect_kv: bool):
    def body(carry, blk):
        x = carry
        a_out, kv = attn_apply(cfg, blk["attn"], x, positions, ctx,
                               return_kv=collect_kv)
        x = x + a_out
        ekv = enc_kv(cfg, blk["cross"], enc_out)
        x = x + cross_attn_apply(cfg, blk["cross"], x, ekv, ctx)
        f_out, _ = ffn_apply(cfg, blk["ffn"], x, ctx)
        x = x + f_out
        return x, (kv, ekv) if collect_kv else None

    body = _maybe_remat(body, ctx)
    h, kvs = jax.lax.scan(body, h, params["blocks"])
    return h, zero_aux(cfg), kvs


def whisper_prefill_cross(cfg, params, frames, ctx: ModelCtx = ModelCtx()):
    """Run the encoder and precompute per-layer cross-attention K/V for the
    decode cache: returns (cross_k, cross_v) stacked (L, B, F, Hk, D)."""
    enc_out = whisper_encode(cfg, params, frames, ctx)

    def one(blk):
        return enc_kv(cfg, blk["cross"], enc_out)

    ks, vs = jax.vmap(one, in_axes=(0,))(params["blocks"])
    return ks, vs


def _whisper_decode(cfg, params, h, position, ctx, cache):
    def body(x, inp):
        blk, kc, vc, ck, cv = inp
        a_out, kc, vc = attn_decode(cfg, blk["attn"], x, position, ctx,
                                    kc, vc, cache["len"])
        x = x + a_out
        x = x + cross_attn_apply(cfg, blk["cross"], x, (ck, cv), ctx)
        f_out, _ = ffn_apply(cfg, blk["ffn"], x, ctx)
        x = x + f_out
        return x, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(
        body, h, (params["blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    return h, {"k": kcs, "v": vcs, "cross_k": cache["cross_k"],
               "cross_v": cache["cross_v"], "len": cache["len"] + 1}


def _whisper_decode_spec(cfg, params, h, position, ctx, cache, q_lens):
    # cross-attention is non-causal over a fixed frame count — every draft
    # row attends all frames, so k rows are safe; force the naive impl so
    # the k-row scores reduce bit-identically to the single-row decode path
    cross_ctx = dataclasses.replace(ctx, attn_impl="naive")

    def body(x, inp):
        blk, kc, vc, ck, cv = inp
        a_out, kc, vc, _ = attn_decode_spec(cfg, blk["attn"], x, position,
                                            ctx, kc, vc, cache["len"],
                                            q_lens)
        x = x + a_out
        x = x + cross_attn_apply(cfg, blk["cross"], x, (ck, cv), cross_ctx)
        f_out, _ = ffn_apply(cfg, blk["ffn"], x, ctx)
        x = x + f_out
        return x, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(
        body, h, (params["blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    return h, {"k": kcs, "v": vcs, "cross_k": cache["cross_k"],
               "cross_v": cache["cross_v"], "len": cache["len"]}


# ---------------------------------------------------------------------------
# Pipeline-parallel stage slicing (uniform family)
# ---------------------------------------------------------------------------
#
# The stacked-layer (L, ...) scan params split at ``balance_stages`` bounds
# into per-stage blocks with shape-uniform inter-stage activations.  Stages
# may hold different layer counts, so every stage is padded to the widest
# stage and carries a per-slot ``mask`` — a masked slot is the identity
# (``x + 0 * sublayer(x)``), with the pad slots holding copies of a real
# layer's params so no degenerate-weight numerics ever run.  Embed and
# final-norm/head ride outside the stage stack as first/last-stage extras
# (``pp_partition_params`` -> {"stage", "last", ["embed"]}).


def stage_slice_params(cfg: ArchConfig, blocks, bounds) -> Dict:
    """Split stacked (L, ...) uniform blocks into {"blocks": (S, L_max, ...),
    "mask": (S, L_max)} at ``bounds`` (len S+1, from balance_stages)."""
    S = len(bounds) - 1
    sizes = [bounds[s + 1] - bounds[s] for s in range(S)]
    if min(sizes) < 1:
        raise ValueError(f"empty stage in bounds {bounds}")
    L_max = max(sizes)

    def slice_one(a):
        outs = []
        for s in range(S):
            sl = a[bounds[s]:bounds[s + 1]]
            if sizes[s] < L_max:                  # pad with a real layer
                pad = jnp.broadcast_to(sl[-1:],
                                       (L_max - sizes[s],) + sl.shape[1:])
                sl = jnp.concatenate([sl, pad], axis=0)
            outs.append(sl)
        return jnp.stack(outs)

    mask = jnp.asarray([[1.0] * n + [0.0] * (L_max - n) for n in sizes],
                       jnp.float32)
    return {"blocks": jax.tree.map(slice_one, blocks), "mask": mask}


def unstack_stage_params(stage_params: Dict, bounds) -> Any:
    """Inverse of :func:`stage_slice_params`: back to stacked (L, ...)."""
    S = len(bounds) - 1
    sizes = [bounds[s + 1] - bounds[s] for s in range(S)]

    def join(a):
        return jnp.concatenate([a[s, :sizes[s]] for s in range(S)], axis=0)

    return jax.tree.map(join, stage_params["blocks"])


def remap_stage_params(stage_params: Dict, old_bounds, new_bounds) -> Dict:
    """Live stage remap: re-carve a padded stage stack under new layer
    bounds (the observe->rebalance loop).  The model function is invariant
    — layer order is preserved, only the stage assignment (and pad width)
    changes."""
    blocks = unstack_stage_params(stage_params, old_bounds)
    return stage_slice_params(None, blocks, new_bounds)


def pp_partition_params(cfg: ArchConfig, params: Dict, bounds) -> Dict:
    """Full-model params -> the pipeline-parallel partition.

    Returns {"stage": stage-stacked blocks+mask, "last": final-norm + head
    (the tied-embedding table lives here when ``cfg.tie_embeddings``),
    "embed": input table (untied only)}."""
    if family(cfg) != "uniform":
        raise NotImplementedError(
            f"pipeline stage slicing covers the uniform family; "
            f"{cfg.name} is {family(cfg)}")
    if cfg.is_moe:
        raise NotImplementedError(
            "pipelined training drops MoE aux losses; dense uniform only")
    if cfg.pos_type == "mrope":
        raise NotImplementedError(
            "the pipelined path runs plain rope positions and a bare "
            "token embedding; mrope archs (patch_embeds mixing, "
            "3-component positions) are not stage-sliceable yet")
    out = {"stage": stage_slice_params(cfg, params["blocks"], bounds),
           "last": {"final_norm": params["final_norm"]}}
    if cfg.tie_embeddings:
        out["last"]["embed"] = params["embed"]
    else:
        out["last"]["lm_head"] = params["lm_head"]
        out["embed"] = params["embed"]
    return out


def pp_merge_params(cfg: ArchConfig, pp_params: Dict, bounds) -> Dict:
    """Inverse of :func:`pp_partition_params` (checkpoint/export)."""
    params = {"blocks": unstack_stage_params(pp_params["stage"], bounds),
              "final_norm": pp_params["last"]["final_norm"]}
    if cfg.tie_embeddings:
        params["embed"] = pp_params["last"]["embed"]
    else:
        params["lm_head"] = pp_params["last"]["lm_head"]
        params["embed"] = pp_params["embed"]
    return params


def make_stage_fn(cfg: ArchConfig, ctx: ModelCtx = ModelCtx(),
                  tp_axis: Optional[str] = None):
    """stage_fn(stage_slice, x) for the pipeline schedules: a masked scan
    over the stage's (padded) layers.  x: (mb, S, d) residual stream.

    With ``tp_axis`` set this is the manual Megatron-TP body, for use
    inside a shard_map whose mesh carries that axis alongside the stage
    axis (the trainer's full DP x TP x stage step): per-device block
    params hold head / d_ff column slices (see ``pp_stage_specs``); each
    residual branch enters through the Megatron ``f`` collective
    (identity forward / psum backward) and exits through ``g`` (psum
    forward / identity backward) — the conjugate pair is load-bearing: a
    bare ``lax.psum`` transposes to another psum, so cotangents crossing
    k branch boundaries would be scaled tp^k.  Gradients of TP-sliced
    weights come out exact and local; gradients of the *replicated*
    leaves inside a branch (the norms) are per-rank partials the trainer
    psums over ``tp_axis`` at sync time.  Local head counts are inferred
    from the sliced param shapes, so one builder serves any tp degree.
    """
    if tp_axis is not None:
        f_in, g_out = _tp_f_g(tp_axis)
    else:
        f_in = g_out = lambda x: x

    def stage_fn(p, x):
        qd = p["blocks"]["attn"]["wq"].shape[-1]
        kvd = p["blocks"]["attn"]["wk"].shape[-1]
        cfg_l = dataclasses.replace(cfg, num_heads=qd // cfg.head_dim,
                                    num_kv_heads=kvd // cfg.head_dim)
        B, S_seq, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S_seq)[None], (B, S_seq))

        def body(h, inp):
            blk, m = inp
            m = jax.lax.stop_gradient(m)        # the pad mask is not a param
            a_out, _ = attn_apply(cfg_l, blk["attn"], f_in(h), positions,
                                  ctx)
            h = h + m * g_out(a_out)
            # dense FFN spelled out (pp_partition_params rejects MoE):
            # norm -> mlp -> residual constrain, = ffn_apply's dense path
            # (the constrain sees the full, post-collective branch output)
            hn = layers.apply_norm(cfg_l, blk["ffn"]["norm"], f_in(h))
            f_out = layers.apply_mlp(cfg_l, blk["ffn"]["mlp"], hn)
            h = h + m * ctx.constrain(g_out(f_out), "residual")
            return h, None

        body = _maybe_remat(body, ctx)
        h, _ = jax.lax.scan(body, x, (p["blocks"], p["mask"]))
        return h

    return stage_fn


def make_stage_fn_tp(cfg: ArchConfig, ctx: ModelCtx = ModelCtx(),
                     tp_axis: str = "model"):
    """The Megatron-TP configuration of :func:`make_stage_fn`."""
    return make_stage_fn(cfg, ctx, tp_axis=tp_axis)


def _tp_f_g(axis: str):
    """Megatron's conjugate TP collectives for shard_map bodies.

    ``f``: identity forward, psum backward — wraps a replicated activation
    entering a tensor-sliced branch, so the branch's input cotangent is
    reduced exactly once.  ``g``: psum forward, identity backward — merges
    the branch's partial outputs without re-reducing the (already
    replicated) cotangent on the way back.
    """

    @jax.custom_vjp
    def f(x):
        return x

    f.defvjp(lambda x: (x, None),
             lambda _, ct: (jax.lax.psum(ct, axis),))

    @jax.custom_vjp
    def g(x):
        return jax.lax.psum(x, axis)

    g.defvjp(lambda x: (jax.lax.psum(x, axis), None),
             lambda _, ct: (ct,))
    return f, g


def make_last_fn(cfg: ArchConfig, ctx: ModelCtx = ModelCtx()):
    """last_fn(last_params, y, tgt, mask) -> masked NLL *sum* over one
    micro-batch (the pipeline divides by the global mask weight)."""

    def last_fn(lp, y, tgt, mask):
        h = layers.apply_norm(cfg, lp["final_norm"], y)
        logits = ctx.constrain(layers.lm_logits(cfg, lp, h), "logits")
        nll = layers._nll(logits, tgt)
        return jnp.sum(nll * mask)

    return last_fn


# ---------------------------------------------------------------------------
# mrope decode positions (qwen2-vl serving)
# ---------------------------------------------------------------------------

def mrope_prompt_positions(cfg: ArchConfig, seq_len: int,
                           grid: Optional[Tuple[int, int]] = None):
    """(1, seq_len, 3) multimodal-RoPE positions for a prompt laid out as
    [grid_h x grid_w image patches][text...].

    Patch token p sits at (t=0, h=p//gw, w=p%gw); the first text token
    starts at ``max(gh, gw)`` — one past the largest patch index — and text
    advances all three components together (the qwen2-vl rule).  ``grid``
    None means a pure-text prompt (positions = arange on every component).
    Pad positions past the true prompt length are harmless: causal
    attention never lets a live query see them.

    ``seq_len`` here is the (possibly padded) buffer length, so the check
    below only catches grids larger than the whole buffer; the caller
    must guard ``gh*gw < true_len`` against the REAL prompt length (the
    serving engine rejects such requests at admission, and
    :func:`mrope_next_position` raises) — patches spilling into pad
    positions would silently mis-position every generated token.
    """
    idx = jnp.arange(seq_len)
    if grid is None:
        pos = jnp.stack([idx, idx, idx], axis=-1)
        return pos[None].astype(jnp.int32)
    gh, gw = grid
    n_patch = gh * gw
    if n_patch > seq_len:
        raise ValueError(f"patch grid {grid} exceeds prompt length {seq_len}")
    base = max(gh, gw)
    text = base + idx - n_patch
    t = jnp.where(idx < n_patch, 0, text)
    h = jnp.where(idx < n_patch, idx // max(gw, 1), text)
    w = jnp.where(idx < n_patch, idx % max(gw, 1), text)
    return jnp.stack([t, h, w], axis=-1)[None].astype(jnp.int32)


def mrope_next_position(true_len: int,
                        grid: Optional[Tuple[int, int]] = None) -> int:
    """Scalar position (shared by all three components) of the NEXT token
    after a ``true_len``-token prompt with the given patch layout — the
    value the serving engine advances per generated token."""
    if grid is None:
        return int(true_len)
    gh, gw = grid
    if gh * gw >= true_len:
        raise ValueError(
            f"patch grid {grid} needs {gh * gw} tokens but the prompt has "
            f"only {true_len}; a prompt must carry at least one text token "
            f"after its patches")
    return int(max(gh, gw) + true_len - gh * gw)


# ---------------------------------------------------------------------------
# Public API: forward / loss / cache / decode
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch, ctx):
    tokens = batch["tokens"]
    h = layers.embed_tokens(params["embed"], tokens, ctx.constrain)
    if cfg.pos_type == "mrope" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(h.dtype)
        h = jnp.concatenate([pe, h[:, pe.shape[1]:]], axis=1)
    if cfg.pos_type == "learned":
        S = tokens.shape[1]
        h = h + params["dec_pos"][:S][None]
    return ctx.constrain(h, "residual")


def _positions(cfg, batch):
    if cfg.pos_type == "mrope":
        return batch["positions"]                        # (B,S,3)
    B, S = batch["tokens"].shape
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def forward_hidden(cfg: ArchConfig, params: Dict, batch: Dict,
                   ctx: ModelCtx = ModelCtx(), collect_kv: bool = False,
                   true_len=None):
    """Full-sequence forward up to the final norm: (hidden, aux, kvs).

    ``true_len`` (serving prefill): positions >= true_len are right-padding
    — they are masked out of MoE routing so pad garbage never consumes
    expert capacity (every other sublayer is causal or per-token, so pads
    cannot touch real positions there)."""
    fam = family(cfg)
    h = _embed_inputs(cfg, params, batch, ctx)
    positions = _positions(cfg, batch)
    live = None
    if true_len is not None:
        B, S = batch["tokens"].shape
        live = jnp.broadcast_to((jnp.arange(S) < true_len)[None], (B, S))
    if fam == "uniform":
        h, aux, kvs = _uniform_forward(cfg, params, h, positions, ctx,
                                       collect_kv, live)
    elif fam == "rwkv6":
        h, aux, kvs = _rwkv_forward(cfg, params, h, ctx), zero_aux(cfg), None
    elif fam == "jamba":
        h, aux, kvs = _jamba_forward(cfg, params, h, positions, ctx,
                                     collect_kv, live)
    elif fam == "gemma":
        h, aux, kvs = _gemma_forward(cfg, params, h, positions, ctx,
                                     collect_kv, live)
    elif fam == "whisper":
        enc_out = whisper_encode(cfg, params, batch["frames"], ctx)
        h, aux, kvs = _whisper_dec_forward(cfg, params, h, positions, enc_out,
                                           ctx, collect_kv)
    else:
        raise ValueError(fam)
    return layers.apply_norm(cfg, params["final_norm"], h), aux, kvs


def forward(cfg: ArchConfig, params: Dict, batch: Dict,
            ctx: ModelCtx = ModelCtx(), collect_kv: bool = False,
            true_len=None):
    """Full-sequence forward.  Returns (logits, aux, kvs)."""
    h, aux, kvs = forward_hidden(cfg, params, batch, ctx, collect_kv,
                                 true_len=true_len)
    logits = ctx.constrain(layers.lm_logits(cfg, params, h), "logits")
    return logits, aux, kvs


def chunked_ce(cfg: ArchConfig, params: Dict, hidden, targets, mask,
               ctx: ModelCtx, chunk: int = 512):
    """LM-head + CE evaluated in sequence chunks with per-chunk remat.

    The (B, S, V) logits tensor — the single largest activation for 150k+
    vocabularies — only ever exists one chunk at a time; the backward
    recomputes each chunk's logits (head matmul) instead of stashing three
    full copies (fwd logits, softmax, d_logits)."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:
        import math
        chunk = math.gcd(chunk, S)
    nh = S // chunk
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    hs = hidden.reshape(B, nh, chunk, d).swapaxes(0, 1)
    ts = targets.reshape(B, nh, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, nh, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def one(carry, args):
        hc, tc, mc = args
        logits = ctx.constrain(layers.lm_logits(cfg, params, hc), "logits")
        nll = layers._nll(logits, tc)
        s, n = carry
        return (s + jnp.sum(nll * mc), n + jnp.sum(mc)), None

    (s, n), _ = jax.lax.scan(one, (jnp.zeros((), jnp.float32),
                                   jnp.zeros((), jnp.float32)),
                             (hs, ts, ms))
    return s / jnp.maximum(n, 1.0)


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict,
            ctx: ModelCtx = ModelCtx(),
            lb_weight: float = 0.01, z_weight: float = 1e-3):
    hidden, aux, _ = forward_hidden(cfg, params, batch, ctx)
    loss = chunked_ce(cfg, params, hidden, batch["targets"],
                      batch.get("mask"), ctx)
    total = loss + lb_weight * aux["lb_loss"] + z_weight * aux["z_loss"]
    return total, {"ce": loss, **aux}


# --- caches -------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               spec_margin: int = 0) -> Dict:
    """Decode cache pytree (all-zeros; lengths supplied separately).

    ``spec_margin`` (speculative decode, ``k - 1`` for draft width k):
    extra rows on gemma's sliding-window ring buffers.  A k-row
    speculative step writes k consecutive ring slots before attending, so
    exactness against row-by-row decode needs the slots written by rows
    ``> j`` to sit *outside* row ``j``'s window band — true iff the ring
    holds ``window + k - 1`` rows (the overwritten positions were outside
    the band too, so the attended sets match).  Linear caches need no
    margin: rejected rows land at dead positions beyond the committed
    length."""
    fam = family(cfg)
    dtype = jnp.dtype(cfg.dtype)
    Hk, D = cfg.num_kv_heads, cfg.head_dim
    L = cfg.num_layers

    def kv(n, s):
        return jnp.zeros((n, batch, s, Hk, D), dtype)

    if fam == "uniform":
        return {"k": kv(L, max_len), "v": kv(L, max_len),
                "len": jnp.zeros((batch,), jnp.int32)}
    if fam == "rwkv6":
        st = {"tmix_last": jnp.zeros((L, batch, cfg.d_model), dtype),
              "wkv": jnp.zeros((L, batch, cfg.d_model // cfg.rwkv_head_size,
                                cfg.rwkv_head_size, cfg.rwkv_head_size),
                               jnp.float32),
              "cmix_last": jnp.zeros((L, batch, cfg.d_model), dtype)}
        return {"states": st, "len": jnp.zeros((batch,), jnp.int32)}
    if fam == "jamba":
        n_per = cfg.num_layers // cfg.attn_period
        d_in = cfg.ssm_expand * cfg.d_model
        m = {"conv": jnp.zeros((n_per, cfg.attn_period - 1, batch,
                                cfg.ssm_d_conv - 1, d_in), dtype),
             "ssm": jnp.zeros((n_per, cfg.attn_period - 1, batch, d_in,
                               cfg.ssm_d_state), jnp.float32)}
        return {"k": kv(n_per, max_len), "v": kv(n_per, max_len), "mamba": m,
                "len": jnp.zeros((batch,), jnp.int32)}
    if fam == "gemma":
        kinds = cfg.layer_kinds()
        ks, vs = [], []
        for kind in kinds:
            s = (cfg.sliding_window + spec_margin
                 if kind == "local_attn" else max_len)
            ks.append(jnp.zeros((batch, s, Hk, D), dtype))
            vs.append(jnp.zeros((batch, s, Hk, D), dtype))
        return {"k": tuple(ks), "v": tuple(vs),
                "len": jnp.zeros((batch,), jnp.int32)}
    if fam == "whisper":
        F = cfg.encoder_frames
        return {"k": kv(L, max_len), "v": kv(L, max_len),
                "cross_k": kv(L, F), "cross_v": kv(L, F),
                "len": jnp.zeros((batch,), jnp.int32)}
    raise ValueError(fam)


def prefill_into_cache(cfg: ArchConfig, params: Dict, batch: Dict,
                       cache: Dict, ctx: ModelCtx = ModelCtx()):
    """Batched all-rows prefill: one full-sequence forward whose per-layer
    K/V land in the decode cache (every row shares one prompt length).

    Supported for the uniform and whisper families (stacked (L,B,S,Hk,D)
    caches).  The serving engine uses the family-polymorphic
    :func:`prefill_into_slot` instead, which covers every family — ring
    buffers, recurrent states, cross-KV — one slot row at a time.
    Returns (last_logits (B, V), cache)."""
    fam = family(cfg)
    if fam not in ("uniform", "whisper"):
        raise NotImplementedError(f"batched prefill for family {fam}")
    B, S_p = batch["tokens"].shape
    logits, aux, kvs = forward(cfg, params, batch, ctx, collect_kv=True)
    if fam == "whisper":
        kvs, ekvs = kvs
        cache["cross_k"], cache["cross_v"] = ekvs
    k, v = kvs                                  # (L, B, S_p, Hk, D)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["len"] = jnp.full((B,), S_p, jnp.int32)
    return logits[:, -1], cache


# --- per-slot serving state (the family-polymorphic DecodeState protocol) ---
#
# Every family exposes the same three operations to the serving engine:
#   init_slots(cfg, n_slots, max_len)            -> slot-indexed state
#   prefill_into_slot(cfg, params, state, ...)   -> scatter one request
#   decode_step(cfg, params, state, tokens)      -> one token for all slots
# The state layout is family-owned (stacked KV rows, ring buffers, mamba /
# wkv recurrent rows, whisper cross-KV); the engine never looks inside it.


def init_slots(cfg: ArchConfig, n_slots: int, max_len: int,
               spec_margin: int = 0) -> Dict:
    """Slot-indexed decode state for ``n_slots`` concurrent requests (the
    serving alias of :func:`init_cache`: one cache row == one slot).
    ``spec_margin``: gemma ring headroom for speculative decode — see
    :func:`init_cache`."""
    return init_cache(cfg, n_slots, max_len, spec_margin=spec_margin)


def init_paged_slots(cfg: ArchConfig, n_slots: int, max_len: int, *,
                     num_blocks: int, block_size: int) -> Dict:
    """Paged decode state for the uniform family: per-layer KV lives in one
    shared pool ``(L, num_blocks, block_size, Hk, D)`` instead of per-slot
    padded rows; slots hold only block tables.  ``block_table`` is what
    attention *reads* through, ``write_table`` is where appends land
    (entries the slot does not own point at the null block 0).  Both start
    all-null: the serving engine's :class:`~repro.serving.block_pool`
    machinery populates them at admission.  Other families page through the
    generic pooled-leaf composition in :mod:`repro.serving.engine`."""
    if family(cfg) != "uniform":
        raise ValueError("init_paged_slots is the uniform-family native "
                         f"path, not {family(cfg)!r}")
    if max_len % block_size:
        raise ValueError(f"max_len={max_len} not a multiple of "
                         f"block_size={block_size}")
    dtype = jnp.dtype(cfg.dtype)
    Hk, D = cfg.num_kv_heads, cfg.head_dim
    L = cfg.num_layers
    nb = max_len // block_size
    tbl = jnp.zeros((n_slots, nb), jnp.int32)
    return {"k": jnp.zeros((L, num_blocks, block_size, Hk, D), dtype),
            "v": jnp.zeros((L, num_blocks, block_size, Hk, D), dtype),
            "block_table": tbl, "write_table": tbl,
            "len": jnp.zeros((n_slots,), jnp.int32)}


def _ring_rows(x, true_len, window: int):
    """Gather a prompt's K or V rows (x: (S, Hk, D), absolute positions)
    into ring-buffer layout: row ``r`` holds the *latest* position
    ``p < true_len`` with ``p % window == r`` — the layout decode's
    ``slot = len % window`` insertion continues from, wraparound-correct
    for prompts longer than the window.  Rows with no valid position
    (true_len < window) hold clamped garbage; decode masks them via the
    per-slot length."""
    S = x.shape[0]
    r = jnp.arange(window)
    p = true_len - 1 - jnp.mod(true_len - 1 - r, window)
    return x[jnp.clip(p, 0, S - 1)]


def _scatter_kv(cache: Dict, name: str, rows, slot):
    """Scatter (L, 1, S, Hk, D) prompt K/V into slot ``slot`` of a stacked
    (L, n_slots, max_len, Hk, D) cache entry."""
    return jax.lax.dynamic_update_slice(
        cache[name], rows.astype(cache[name].dtype), (0, slot, 0, 0, 0))


def _uniform_prefill_slot(cfg, params, cache, tokens, true_len, slot, ctx,
                          grid=None):
    batch = {"tokens": tokens}
    if cfg.pos_type == "mrope":
        # positions from the request's text+patch layout (qwen2-vl); the
        # patch ids embed through the token table — position handling is
        # what decode correctness needs (see mrope_prompt_positions)
        batch["positions"] = mrope_prompt_positions(cfg, tokens.shape[1],
                                                    grid)
    logits, _, (k, v) = forward(cfg, params, batch, ctx,
                                collect_kv=True, true_len=true_len)
    cache = dict(cache)
    cache["k"] = _scatter_kv(cache, "k", k, slot)
    cache["v"] = _scatter_kv(cache, "v", v, slot)
    cache["len"] = cache["len"].at[slot].set(true_len)
    return logits[0, true_len - 1], cache


def _uniform_prefill_slot_paged(cfg, params, cache, tokens, true_len, slot,
                                ctx, grid=None):
    """Paged twin of :func:`_uniform_prefill_slot`: the same whole-prompt
    forward, with the per-layer K/V rows scattered block-by-block through
    the slot's *write* table.  Virtual blocks the slot does not own (shared
    sealed prefix blocks, or table entries past the mapped span) have write
    entry 0, so their recomputed rows land in the null block — storage is
    deduplicated while prefill compute stays a pure function of the
    request.  Pad rows inside owned blocks are dead by the slot length and
    are overwritten in place by decode appends before the length reaches
    them (the same argument as the dense layout's bucket padding)."""
    batch = {"tokens": tokens}
    if cfg.pos_type == "mrope":
        batch["positions"] = mrope_prompt_positions(cfg, tokens.shape[1],
                                                    grid)
    logits, _, (k, v) = forward(cfg, params, batch, ctx,
                                collect_kv=True, true_len=true_len)
    L, _, S_p, Hk, D = k.shape
    bs = cache["k"].shape[2]
    pad = (-S_p) % bs
    if pad:
        grow = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        k, v = jnp.pad(k, grow), jnp.pad(v, grow)
    nbp = (S_p + pad) // bs
    wt = cache["write_table"][slot][:nbp]                    # (nbp,)
    cache = dict(cache)
    cache["k"] = cache["k"].at[:, wt].set(
        k[:, 0].reshape(L, nbp, bs, Hk, D).astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[:, wt].set(
        v[:, 0].reshape(L, nbp, bs, Hk, D).astype(cache["v"].dtype))
    cache["len"] = cache["len"].at[slot].set(true_len)
    return logits[0, true_len - 1], cache


def _uniform_prefill_slot_chunked(cfg, params, cache, tokens, true_len,
                                  slot, ctx, chunk: int):
    """Streaming prefill: the prompt runs through the stack in fixed
    ``chunk``-token pieces that reuse the decode cache-append path — each
    chunk's per-layer K/V lands in the slot's cache rows and the next chunk
    attends the accumulated prefix (``q_offset`` causal masking).  A long
    prompt therefore never compiles or pads a monolithic ``(1, S_pad)``
    forward: the traced unit is one chunk, scanned ``S_pad/chunk`` times.

    Parity with the whole-prompt path is exact for dense uniform archs
    (per-position math is identical; only the attention accumulation order
    differs).  MoE layers route each chunk as its own capacity group, so a
    capacity-dropping MoE can differ from the bucket-length grouping of the
    monolithic forward — streams stay a pure function of request + chunk
    size.  mrope archs take the whole-prompt path (their patch/text
    position layout is not chunk-decomposable here)."""
    B, S_in = tokens.shape
    pad = (-S_in) % chunk
    if pad:
        tokens = jnp.pad(tokens, ((0, 0), (0, pad)))
    S_pad = S_in + pad
    n_chunks = S_pad // chunk
    L = cfg.num_layers
    S_max = cache["k"].shape[2]
    Hk, D = cfg.num_kv_heads, cfg.head_dim
    k_rows = jax.lax.dynamic_slice(cache["k"], (0, slot, 0, 0, 0),
                                   (L, 1, S_max, Hk, D))
    v_rows = jax.lax.dynamic_slice(cache["v"], (0, slot, 0, 0, 0),
                                   (L, 1, S_max, Hk, D))
    if S_pad > S_max:
        # chunk padding may overhang the cache (bucket == S_max with a
        # non-dividing chunk): give the working rows that headroom so the
        # tail chunk's dynamic_update_slice never clamps into live rows —
        # the overhang holds pad-token K/V only and is dropped at
        # write-back (positions >= true_len are dead by the slot length)
        grow = ((0, 0), (0, 0), (0, S_pad - S_max), (0, 0), (0, 0))
        k_rows = jnp.pad(k_rows, grow)
        v_rows = jnp.pad(v_rows, grow)

    def per_chunk(carry, ci):
        k_rows, v_rows = carry
        c0 = ci * chunk
        toks = jax.lax.dynamic_slice(tokens, (0, c0), (1, chunk))
        x = layers.embed_tokens(params["embed"], toks)
        positions = c0 + jnp.arange(chunk)[None]             # (1, chunk)
        live = positions < true_len

        def body(h, inp):
            blk, kc, vc = inp                                # kc (1,S,Hk,D)
            hn = layers.apply_norm(cfg, blk["attn"]["norm"], h)
            q, k, v = _qkv(cfg, blk["attn"], hn, positions, ctx)
            kc = jax.lax.dynamic_update_slice(
                kc, k.astype(kc.dtype), (0, c0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v.astype(vc.dtype), (0, c0, 0, 0))
            o = attn_lib.attention(q, kc, vc, causal=True, q_offset=c0,
                                   impl="chunked", chunk=ctx.attn_chunk)
            h = h + o.reshape(1, chunk, cfg.q_dim) @ blk["attn"]["wo"]
            f_out, _ = ffn_apply(cfg, blk["ffn"], h, ctx, live=live)
            return h + f_out, (kc, vc)

        x, (k_rows, v_rows) = jax.lax.scan(
            body, x, (params["blocks"], k_rows, v_rows))
        return (k_rows, v_rows), x                           # x (1,chunk,d)

    (k_rows, v_rows), hs = jax.lax.scan(
        per_chunk, (k_rows, v_rows), jnp.arange(n_chunks))
    hidden = hs.transpose(1, 0, 2, 3).reshape(1, S_pad, cfg.d_model)
    row = jax.lax.dynamic_slice(hidden, (0, true_len - 1, 0),
                                (1, 1, cfg.d_model))
    row = layers.apply_norm(cfg, params["final_norm"], row)
    logits = layers.lm_logits(cfg, params, row)              # (1, 1, V)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k_rows[:, :, :S_max], (0, slot, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v_rows[:, :, :S_max], (0, slot, 0, 0, 0))
    cache["len"] = cache["len"].at[slot].set(true_len)
    return logits[0, 0], cache


def _gemma_prefill_slot(cfg, params, cache, tokens, true_len, slot, ctx):
    logits, _, kvs = forward(cfg, params, {"tokens": tokens}, ctx,
                             collect_kv=True, true_len=true_len)
    cache = dict(cache)
    new_k, new_v = [], []
    for (k, v), kind, kc, vc in zip(kvs, cfg.layer_kinds(),
                                    cache["k"], cache["v"]):
        if kind == "local_attn":                 # ring-buffer rows
            ring = kc.shape[1]       # window + spec margin (see init_cache)
            k_row = _ring_rows(k[0], true_len, ring)
            v_row = _ring_rows(v[0], true_len, ring)
        else:                                    # full rows from position 0
            k_row, v_row = k[0], v[0]
        new_k.append(jax.lax.dynamic_update_slice(
            kc, k_row[None].astype(kc.dtype), (slot, 0, 0, 0)))
        new_v.append(jax.lax.dynamic_update_slice(
            vc, v_row[None].astype(vc.dtype), (slot, 0, 0, 0)))
    cache["k"], cache["v"] = tuple(new_k), tuple(new_v)
    cache["len"] = cache["len"].at[slot].set(true_len)
    return logits[0, true_len - 1], cache


def _jamba_prefill_slot(cfg, params, cache, tokens, true_len, slot, ctx):
    per = cfg.attn_period
    batch = {"tokens": tokens}
    h = _embed_inputs(cfg, params, batch, ctx)
    positions = _positions(cfg, batch)
    B, S = tokens.shape
    live = jnp.broadcast_to((jnp.arange(S) < true_len)[None], (B, S))

    def body(x, blk):
        kv, new_m = None, []
        for j in range(per):
            if j == 0:
                a_out, kv = attn_apply(cfg, blk["attn"], x, positions, ctx,
                                       return_kv=True)
                x = x + a_out
            else:
                mblk = jax.tree.map(lambda a: a[j - 1], blk["mamba"])
                m_out, mst = ssm.mamba_forward(
                    cfg, mblk["m"], layers.apply_norm(cfg, mblk["norm"], x),
                    chunk=ctx.mamba_chunk, true_len=true_len)
                new_m.append(mst)
                x = x + m_out
            name, idx = _jamba_ffn_idx(j)
            fblk = jax.tree.map(lambda a: a[idx], blk[name])
            f_out, _ = ffn_apply(cfg, fblk, x, ctx, live=live)
            x = x + f_out
        new_m = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
        return x, (kv, new_m)

    h, (kvs, ms) = jax.lax.scan(body, h, params["blocks"])
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = layers.lm_logits(cfg, params, h)
    cache = dict(cache)
    k, v = kvs                                   # (n_per, 1, S, Hk, D)
    cache["k"] = _scatter_kv(cache, "k", k, slot)
    cache["v"] = _scatter_kv(cache, "v", v, slot)
    # mamba rows: (n_per, per-1, B, ...) — batch axis 2
    cache["mamba"] = ssm.scatter_slot_state(cache["mamba"], ms, slot,
                                            batch_axis=2)
    cache["len"] = cache["len"].at[slot].set(true_len)
    return logits[0, true_len - 1], cache


def _rwkv_prefill_slot(cfg, params, cache, tokens, true_len, slot, ctx):
    h = _embed_inputs(cfg, params, {"tokens": tokens}, ctx)

    def body(x, blk):
        xn = layers.apply_norm(cfg, blk["norm1"], x)
        t_out, tstate = ssm.rwkv6_forward(cfg, blk["tmix"], xn,
                                          true_len=true_len)
        x = x + t_out
        xn2 = layers.apply_norm(cfg, blk["norm2"], x)
        c_out, clast = ssm.rwkv_cmix_forward(cfg, blk["cmix"], xn2,
                                             true_len=true_len)
        x = x + c_out
        st = {"tmix_last": tstate["last"], "wkv": tstate["wkv"],
              "cmix_last": clast}
        return x, st

    h, states = jax.lax.scan(body, h, params["blocks"])
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = layers.lm_logits(cfg, params, h)
    cache = dict(cache)
    cache["states"] = ssm.scatter_slot_state(cache["states"], states, slot,
                                             batch_axis=1)
    cache["len"] = cache["len"].at[slot].set(true_len)
    return logits[0, true_len - 1], cache


def _whisper_prefill_slot(cfg, params, cache, tokens, true_len, slot, ctx,
                          frames):
    logits, _, (kvs, ekvs) = forward(
        cfg, params, {"tokens": tokens, "frames": frames}, ctx,
        collect_kv=True, true_len=true_len)
    cache = dict(cache)
    cache["k"] = _scatter_kv(cache, "k", kvs[0], slot)
    cache["v"] = _scatter_kv(cache, "v", kvs[1], slot)
    cache["cross_k"] = _scatter_kv(cache, "cross_k", ekvs[0], slot)
    cache["cross_v"] = _scatter_kv(cache, "cross_v", ekvs[1], slot)
    cache["len"] = cache["len"].at[slot].set(true_len)
    return logits[0, true_len - 1], cache


def prefill_into_slot(cfg: ArchConfig, params: Dict, cache: Dict, tokens,
                      true_len, slot, ctx: ModelCtx = ModelCtx(),
                      frames=None, grid=None, chunk: int = 0):
    """Scatter one request's prompt state into slot ``slot`` of a decode
    state built by :func:`init_slots`; returns (last-position logits (V,),
    new state).  This is the family-polymorphic half of the serving
    DecodeState protocol — every architecture family implements it over
    its own state layout:

    * ``uniform``  — per-layer K/V rows scattered at positions [0, true_len).
    * ``gemma``    — global layers as uniform; local layers land in
      sliding-window **ring-buffer** rows (``position % window``),
      wraparound-correct for prompts longer than the window.
    * ``jamba``    — per-period K/V rows + mamba conv/ssm recurrent rows.
    * ``rwkv6``    — wkv ``S``-state plus time-mix/channel-mix shift states.
    * ``whisper``  — decoder self-KV plus per-slot cross-KV computed once
      here from the request's encoder ``frames`` (1, F, d_model).

    ``tokens`` (1, S_pad) may be right-padded to a static prefill bucket;
    ``true_len`` marks the real prompt end.  KV families mask padding via
    the per-slot length; recurrent families neutralize pad steps inside
    the scan (identity transitions — see :mod:`repro.models.ssm`); MoE
    layers drop pad positions from routing so they never consume expert
    capacity.  The scattered state is the state after ``true_len`` tokens
    — exactly, except that a capacity-dropping MoE evaluates its group
    capacity at the bucket length (streams stay a pure function of the
    request + bucket, never of pad contents).

    ``chunk > 0`` (uniform family): streaming prefill — the prompt runs in
    fixed ``chunk``-token pieces through the decode cache-append path, so
    long prompts never trace a monolithic ``(1, S_pad)`` forward (see
    :func:`_uniform_prefill_slot_chunked`)."""
    fam = family(cfg)
    if fam == "uniform":
        if "block_table" in cache:
            if chunk > 0:
                raise ValueError("streaming (chunked) prefill is not "
                                 "supported on the native paged path; use "
                                 "the pooled-leaf composition backend")
            return _uniform_prefill_slot_paged(cfg, params, cache, tokens,
                                               true_len, slot, ctx,
                                               grid=grid)
        if chunk > 0 and cfg.pos_type != "mrope":
            # streaming prefill: fixed chunks through the decode
            # cache-append path (mrope prompts keep the monolithic
            # forward — their position layout is not chunk-decomposable)
            return _uniform_prefill_slot_chunked(
                cfg, params, cache, tokens, true_len, slot, ctx, chunk)
        return _uniform_prefill_slot(cfg, params, cache, tokens, true_len,
                                     slot, ctx, grid=grid)
    if fam == "gemma":
        return _gemma_prefill_slot(cfg, params, cache, tokens, true_len,
                                   slot, ctx)
    if fam == "jamba":
        return _jamba_prefill_slot(cfg, params, cache, tokens, true_len,
                                   slot, ctx)
    if fam == "rwkv6":
        return _rwkv_prefill_slot(cfg, params, cache, tokens, true_len,
                                  slot, ctx)
    if fam == "whisper":
        if frames is None:
            raise ValueError("whisper prefill_into_slot needs the request's "
                             "encoder frames (1, F, d_model)")
        return _whisper_prefill_slot(cfg, params, cache, tokens, true_len,
                                     slot, ctx, frames)
    raise ValueError(fam)


def decode_step(cfg: ArchConfig, params: Dict, cache: Dict, tokens,
                ctx: ModelCtx = ModelCtx(), positions=None):
    """One decode step.  tokens (B,1) -> (logits (B,1,V), new_cache)."""
    fam = family(cfg)
    batch = {"tokens": tokens}
    if positions is not None:
        batch["positions"] = positions
    h = layers.embed_tokens(params["embed"], tokens)
    if cfg.pos_type == "learned":
        h = h + jnp.take(params["dec_pos"], cache["len"], axis=0)[:, None]
    pos = positions if positions is not None else cache["len"]
    if fam == "uniform":
        if "block_table" in cache:
            h, cache = _uniform_decode_paged(cfg, params, h, pos, ctx, cache)
        else:
            h, cache = _uniform_decode(cfg, params, h, pos, ctx, cache)
    elif fam == "rwkv6":
        h, cache = _rwkv_decode(cfg, params, h, ctx, cache)
    elif fam == "jamba":
        h, cache = _jamba_decode(cfg, params, h, pos, ctx, cache)
    elif fam == "gemma":
        h, cache = _gemma_decode(cfg, params, h, pos, ctx, cache)
    elif fam == "whisper":
        h, cache = _whisper_decode(cfg, params, h, pos, ctx, cache)
    else:
        raise ValueError(fam)
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = layers.lm_logits(cfg, params, h)
    return logits, cache


# Families whose decode state is a pure KV cache: rejected draft rows can
# be abandoned (linear caches) or restored (gemma rings).  jamba / rwkv6
# carry recurrent per-token state that cannot cheaply rewind.
SPEC_FAMILIES = ("uniform", "gemma", "whisper")


def verify_greedy(tokens, logits, q_lens):
    """Greedy draft verification.  ``tokens`` (B, k) are the step inputs
    (row 0 = last committed token, rows 1.. = drafts), ``logits`` (B, k, V)
    from :func:`decode_spec`, ``q_lens`` (B,) live rows.  Returns
    ``accepts`` (B,) in ``[1, q_lens]``: row ``j``'s greedy emission
    ``argmax(logits[:, j])`` counts iff every earlier draft row matched the
    emission before it — by induction the accepted prefix is exactly what
    row-by-row greedy decode would have produced."""
    B, k = tokens.shape
    g = jnp.argmax(logits, axis=-1)
    ok = (tokens[:, 1:] == g[:, :-1]) & \
        (jnp.arange(k - 1)[None] < q_lens[:, None] - 1)
    return (1 + jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1),
                        axis=1)).astype(jnp.int32)


def decode_spec(cfg: ArchConfig, params: Dict, cache: Dict, tokens,
                ctx: ModelCtx = ModelCtx(), q_lens=None, positions=None):
    """Speculative k-row decode + greedy verification + commit.

    ``tokens`` (B, k): row 0 is the last committed token (whose KV is not
    yet in the cache — the same contract as :func:`decode_step`), rows
    ``1..k-1`` the self-drafted continuation.  ``q_lens`` (B,) in
    ``[1, k]``: live rows per slot (1 = plain single-step for that slot;
    default all-k).  ``positions`` (B, k) or (B, k, 3): explicit decode
    positions (mrope).

    Returns ``(logits (B, k, V), accepts (B,), cache)``: the emitted
    tokens are ``argmax(logits, -1)[:, :accepts]`` per slot, and the cache
    is *committed* — ``len += accepts``, with gemma ring rows written by
    rejected drafts restored from pre-step snapshots.  Rejected rows on
    linear caches (uniform dense/paged, whisper, gemma global layers)
    leave garbage only at positions beyond the committed length, which the
    per-slot length masks until later appends overwrite it.

    Recurrent-state families raise: their per-token state cannot cheaply
    roll back a rejected draft."""
    fam = family(cfg)
    if fam not in SPEC_FAMILIES:
        raise ValueError(
            f"speculative decode needs a rollback-free KV cache; family "
            f"{fam!r} carries recurrent per-token state that cannot rewind "
            f"rejected draft rows (supported: {SPEC_FAMILIES})")
    B, k = tokens.shape
    if q_lens is None:
        q_lens = jnp.full((B,), k, jnp.int32)
    q_lens = q_lens.astype(jnp.int32)
    if fam == "gemma":
        for kc, kind in zip(cache["k"], cfg.layer_kinds()):
            if kind == "local_attn" and \
                    kc.shape[1] < cfg.sliding_window + k - 1:
                raise ValueError(
                    f"gemma speculative decode with k={k} needs ring "
                    f"buffers of >= window + k - 1 = "
                    f"{cfg.sliding_window + k - 1} rows (have "
                    f"{kc.shape[1]}); build the state with "
                    f"init_cache(..., spec_margin=k - 1)")
    h = layers.embed_tokens(params["embed"], tokens)
    if cfg.pos_type == "learned":
        h = h + jnp.take(params["dec_pos"],
                         cache["len"][:, None] + jnp.arange(k), axis=0)
    pos = positions if positions is not None \
        else cache["len"][:, None] + jnp.arange(k)[None]
    snaps = None
    if fam == "uniform":
        if "block_table" in cache:
            h, cache = _uniform_decode_paged_spec(cfg, params, h, pos, ctx,
                                                  cache, q_lens)
        else:
            h, cache = _uniform_decode_spec(cfg, params, h, pos, ctx, cache,
                                            q_lens)
    elif fam == "gemma":
        h, cache, snaps = _gemma_decode_spec(cfg, params, h, pos, ctx,
                                             cache, q_lens)
    else:
        h, cache = _whisper_decode_spec(cfg, params, h, pos, ctx, cache,
                                        q_lens)
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = layers.lm_logits(cfg, params, h)
    accepts = verify_greedy(tokens, logits, q_lens)
    cache = dict(cache)
    if snaps is not None:
        new_k, new_v = list(cache["k"]), list(cache["v"])
        for i, snap in enumerate(snaps):
            if snap is None:
                continue
            new_k[i], new_v[i] = _restore_ring_rows(
                new_k[i], new_v[i], snap, cache["len"], accepts, k)
        cache["k"], cache["v"] = tuple(new_k), tuple(new_v)
    cache["len"] = cache["len"] + accepts
    return logits, accepts, cache
