"""Attention: GQA with causal / sliding-window masking.

Three implementations share one math contract:

* ``naive_attention``  — O(S^2) materialized scores; test oracle only.
* ``chunked_attention``— flash-style online softmax over KV chunks via
  ``lax.scan``; this is what gets *lowered* (dry-run + CPU runs).  Its HLO has
  block-sized intermediates, so roofline memory terms reflect a flash
  implementation rather than an S^2 score tensor.
* ``kernels.flash_attention`` — the Pallas TPU kernel (same math, MXU tiling),
  validated against ``naive_attention`` in interpret mode.

Layouts: q (B, Sq, H, D); k/v (B, Sk, Hkv, D).  GQA is computed group-wise
without materializing repeated KV heads.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grad_cast(dtype_str: str, x):
    """Identity with a cotangent dtype barrier: the f32 softmax internals
    of attention otherwise leak f32 cotangents into the seq-gather
    collectives (2x wire bytes vs the bf16 primal)."""
    return x


def _grad_cast_fwd(dtype_str, x):
    return x, None


def _grad_cast_bwd(dtype_str, _, g):
    return (g.astype(jnp.dtype(dtype_str)),)


_grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def grad_dtype_barrier(x):
    return _grad_cast(str(x.dtype), x)


def _mask(pos_q, pos_k, *, causal: bool, window: int, kv_len=None):
    """Boolean mask (..., Sq, Sk): True = attend."""
    pq = pos_q[..., :, None]
    pk = pos_k[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(pq.shape, pk.shape), bool)
    if causal:
        m &= pk <= pq
    if window > 0:
        m &= pk > pq - window
    if kv_len is not None:
        m &= pk < kv_len[..., None, None]
    return m


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_len=None, softmax_scale=None):
    """Reference implementation. q:(B,Sq,H,D) k,v:(B,Sk,Hk,D)."""
    B, Sq, H, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = H // Hk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Hk, G, D)
    # MXU semantics: low-precision operands, f32 accumulation
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    pos_q = q_offset + jnp.arange(Sq)
    pos_k = jnp.arange(Sk)
    m = _mask(pos_q, pos_k, causal=causal, window=window,
              kv_len=kv_len)                                 # (Sq,Sk) or (B,Sq,Sk)
    while m.ndim < scores.ndim:
        m = jnp.expand_dims(m, -3 if m.ndim >= 3 else 0)     # broadcast over h,g
    scores = jnp.where(m, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      kv_len=None, chunk=1024, softmax_scale=None):
    """Flash-style attention: lax.scan over KV chunks with running (m, l, acc).

    Memory high-water per step is O(Sq * chunk) instead of O(Sq * Sk).
    """
    B, Sq, H, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = H // Hk
    chunk = min(chunk, Sk)
    if Sk % chunk:                                           # pad KV to chunk multiple
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = jnp.full((B,), Sk, jnp.int32) if kv_len is None else kv_len
        Sk = Sk + pad
    n_chunks = Sk // chunk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5

    qg = q.reshape(B, Sq, Hk, G, D)
    pos_q = q_offset + jnp.arange(Sq)
    kc = k.reshape(B, n_chunks, chunk, Hk, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hk, D).transpose(1, 0, 2, 3, 4)

    # flash-attention semantics in the backward too: the step is
    # rematerialized, so per-chunk score/softmax tensors are recomputed
    # instead of stacked into an (n_chunks, ..., Sq, chunk) == O(S^2) buffer.
    @jax.checkpoint
    def step(carry, inp):
        # NOTE: the kv position counter rides in the carry (not scan xs) so
        # the mask is loop-variant — XLA cannot hoist + materialize a
        # (n_chunks, B, .., Sq, chunk) mask tensor outside the loop.
        m_run, l_run, acc, k0 = carry
        kb, vb = inp                                         # (B,chunk,Hk,D)
        pos_k = k0 + jnp.arange(chunk)
        # MXU semantics: low-precision operands, f32 accumulation
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(pos_q, pos_k, causal=causal, window=window,
                    kv_len=kv_len)
        if msk.ndim == 2:                                # (Sq, Ck)
            msk = msk[None, None, None]
        else:                                            # (B, Sq, Ck)
            msk = msk[:, None, None]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == NEG_INF): exp(0)=1 but l stays 0-safe
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new, k0 + chunk), None

    m0 = jnp.full((B, Hk, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hk, G, D), jnp.float32)
    (m_f, l_f, acc, _), _ = jax.lax.scan(
        step, (m0, l0, a0, jnp.zeros((), jnp.int32)), (kc, vc))
    l_f = jnp.maximum(l_f, 1e-30).transpose(0, 3, 1, 2)[..., None]
    out = acc / l_f
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash-style custom VJP: the autodiff backward of the chunked scan stacks
# per-chunk softmax tensors and accumulates/reshards f32 carries.  This
# hand-written backward recomputes s/p per chunk (true flash semantics),
# emits dk/dv in the model dtype, and keeps only (out, lse) as residuals.
# ---------------------------------------------------------------------------

def _chunked_fwd_lse(q, k, v, *, causal, window, chunk, scale):
    """Forward identical to chunked_attention; also returns lse (B,Hk,G,Sq)."""
    B, Sq, H, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = H // Hk
    n_chunks = Sk // chunk
    qg = q.reshape(B, Sq, Hk, G, D)
    pos_q = jnp.arange(Sq)
    kc = k.reshape(B, n_chunks, chunk, Hk, D).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, Hk, D).swapaxes(0, 1)

    @jax.checkpoint
    def step(carry, inp):
        m_run, l_run, acc, k0 = carry
        kb, vb = inp
        pos_k = k0 + jnp.arange(chunk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(pos_q, pos_k, causal=causal, window=window)
        msk = msk[None, None, None]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc, k0 + chunk), None

    m0 = jnp.full((B, Hk, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hk, G, D), jnp.float32)
    (m_f, l_f, acc, _), _ = jax.lax.scan(
        step, (m0, l0, a0, jnp.zeros((), jnp.int32)), (kc, vc))
    l_f = jnp.maximum(l_f, 1e-30)
    out = (acc / l_f.transpose(0, 3, 1, 2)[..., None]).astype(q.dtype)
    lse = m_f + jnp.log(l_f)                       # (B,Hk,G,Sq)
    return out.reshape(B, Sq, H, D), lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, chunk, scale):
    out, _ = _chunked_fwd_lse(q, k, v, causal=causal, window=window,
                              chunk=chunk, scale=scale)
    return out


def _flash_fwd(q, k, v, causal, window, chunk, scale):
    out, lse = _chunked_fwd_lse(q, k, v, causal=causal, window=window,
                                chunk=chunk, scale=scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, chunk, scale, res, do):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = H // Hk
    n_chunks = Sk // chunk
    qg = q.reshape(B, Sq, Hk, G, D)
    dog = do.reshape(B, Sq, Hk, G, D)
    outg = out.reshape(B, Sq, Hk, G, D)
    # delta = rowsum(do * out): (B,Hk,G,Sq) f32
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", dog.astype(jnp.float32),
                       outg.astype(jnp.float32))
    pos_q = jnp.arange(Sq)
    kc = k.reshape(B, n_chunks, chunk, Hk, D).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, Hk, D).swapaxes(0, 1)

    @jax.checkpoint
    def step(carry, inp):
        dq_acc, k0 = carry
        kb, vb = inp
        pos_k = k0 + jnp.arange(chunk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(pos_q, pos_k, causal=causal, window=window)[None, None,
                                                                None]
        p = jnp.where(msk, jnp.exp(s - lse[..., None]), 0.0)   # (B,Hk,G,Sq,Ck)
        pb = p.astype(vb.dtype)
        dv = jnp.einsum("bhgqk,bqhgd->bkhd", pb, dog,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale               # f32
        dsb = ds.astype(q.dtype)
        dk = jnp.einsum("bhgqk,bqhgd->bkhd", dsb, qg,
                        preferred_element_type=jnp.float32)
        dq_c = jnp.einsum("bhgqk,bkhd->bqhgd", dsb, kb,
                          preferred_element_type=jnp.float32)
        return (dq_acc + dq_c, k0 + chunk), (dk.astype(k.dtype),
                                             dv.astype(v.dtype))

    dq0 = jnp.zeros((B, Sq, Hk, G, D), jnp.float32)
    (dq, _), (dks, dvs) = jax.lax.scan(
        step, (dq0, jnp.zeros((), jnp.int32)), (kc, vc))
    dk = dks.swapaxes(0, 1).reshape(B, Sk, Hk, D)
    dv = dvs.swapaxes(0, 1).reshape(B, Sk, Hk, D)
    return (dq.reshape(B, Sq, H, D).astype(q.dtype), dk, dv)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_chunked_attention(q, k, v, *, causal=True, window=0,
                            chunk=1024, softmax_scale=None):
    """chunked_attention with the hand-written flash backward.  Requires
    Sk % chunk == 0 and no kv_len masking (the training path)."""
    D = q.shape[-1]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    chunk = min(chunk, k.shape[1])
    if k.shape[1] % chunk:
        import math
        chunk = math.gcd(chunk, k.shape[1])
    return _flash(q, k, v, causal, window, chunk, scale)


def decode_attention(q, k_cache, v_cache, lengths, *, window=0, ring=False,
                     softmax_scale=None, impl="dense", block_k=128,
                     q_lens=None):
    """Decode attention. q:(B,Sq,H,D); caches:(B,S,Hk,D); lengths:(B,) valid
    len for query row 0 (that row's own position is lengths-1 and must be
    attendable).  Sq > 1 is speculative k-row verification: draft row ``j``
    attends with effective length ``lengths + j`` (cache + draft rows
    ``< j`` + itself), and ``q_lens`` (B,) caps the live rows per slot —
    rows ``>= q_lens`` produce exactly-zero outputs.

    ``window > 0`` masks a sliding band ``[len-window, len)``; with
    ``ring=True`` the cache is a size-S ring buffer (row ``r`` holds the
    latest position ``p < len`` with ``p % S == r``) and the band *wraps*:
    valid rows are ``r < min(len, S)`` with ``(len-1-r) mod S < window``.
    Empty slots (``len == 0``) produce exactly-zero outputs.

    ``impl`` selects the hot-path implementation: ``"dense"`` streams the
    whole padded cache through one XLA einsum; ``"flash"`` is the Pallas
    flash-decode kernel (:mod:`repro.kernels.decode_attention`) that
    streams only ``ceil(len/block_k)`` KV blocks per slot."""
    if impl == "flash":
        from repro.kernels import ops
        return ops.flash_decode(q, k_cache, v_cache, lengths, window=window,
                                ring=ring, softmax_scale=softmax_scale,
                                block_k=block_k, q_lens=q_lens)
    if impl != "dense":
        raise ValueError(f"decode impl {impl!r} (want dense|flash)")
    B, Sq, H, D = q.shape
    _, S, Hk, _ = k_cache.shape
    G = H // Hk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if q_lens is None:
        q_lens = jnp.full((B,), Sq, jnp.int32)
    qg = q.reshape(B, Sq, Hk, G, D)
    s = jnp.einsum("bjhgd,bkhd->bhjgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos_k = jnp.arange(S)[None, None, :]                     # (1,1,S)
    eff = (lengths[:, None] + jnp.arange(Sq)[None, :])[:, :, None]
    if ring and window > 0:
        valid = pos_k < jnp.minimum(eff, S)
        valid &= jnp.mod(eff - 1 - pos_k, S) < window
    else:
        valid = pos_k < eff
        if window > 0:
            valid &= pos_k > (eff - 1 - window)
    valid &= (jnp.arange(Sq)[None, :] < q_lens[:, None])[:, :, None]
    s = jnp.where(valid[:, None, :, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, :, None, :], p, 0.0)        # len==0 -> 0
    out = jnp.einsum("bhjgk,bkhd->bjhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, q_offset=0, kv_len=None,
              impl="chunked", chunk=1024, softmax_scale=None,
              flash_vjp=False):
    """Public dispatch used by the transformer stack."""
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, kv_len=kv_len,
                               softmax_scale=softmax_scale)
    if impl == "chunked":
        if flash_vjp and q_offset == 0 and kv_len is None \
                and q.shape[1] == k.shape[1]:
            # hand-written flash backward: only for plans whose activations
            # are not head-sharded (dp_heavy / tp==1) — under Megatron-SP
            # the grouped-head reshape inside the bwd scan fights GSPMD.
            return flash_chunked_attention(
                q, k, v, causal=causal, window=window, chunk=chunk,
                softmax_scale=softmax_scale)
        q, k, v = map(grad_dtype_barrier, (q, k, v))
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, kv_len=kv_len, chunk=chunk,
                                 softmax_scale=softmax_scale)
    if impl == "pallas":
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   softmax_scale=softmax_scale)
    raise ValueError(impl)
