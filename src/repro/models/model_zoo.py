"""Model zoo: arch-indexed bundle of init / loss / prefill / decode — plus
the per-slot serving protocol (``init_slots`` / ``prefill_into_slot``),
which every family implements — and the ``input_specs`` used by the
multi-pod dry-run (ShapeDtypeStruct stand-ins, weak-type-correct, no device
allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    ctx: ModelCtx

    def init(self, key):
        return tf.init_params(key, self.cfg)

    def init_eval_shape(self):
        return jax.eval_shape(lambda k: tf.init_params(k, self.cfg),
                              jax.random.PRNGKey(0))

    def loss(self, params, batch):
        return tf.loss_fn(self.cfg, params, batch, self.ctx)

    def prefill(self, params, batch):
        logits, aux, kvs = tf.forward(self.cfg, params, batch, self.ctx,
                                      collect_kv=True)
        return logits, kvs

    def decode(self, params, cache, tokens, positions=None):
        return tf.decode_step(self.cfg, params, cache, tokens, self.ctx,
                              positions=positions)

    # -- per-slot serving protocol (family-polymorphic DecodeState) ---------

    def init_slots(self, n_slots: int, max_len: int):
        return tf.init_slots(self.cfg, n_slots, max_len)

    def prefill_into_slot(self, params, cache, tokens, true_len, slot,
                          frames=None):
        return tf.prefill_into_slot(self.cfg, params, cache, tokens,
                                    true_len, slot, self.ctx, frames=frames)


def build(cfg: ArchConfig, ctx: ModelCtx = ModelCtx()) -> ModelBundle:
    return ModelBundle(cfg, ctx)


# ---------------------------------------------------------------------------
# Dry-run input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one train/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": _sds((B, S), jnp.int32),
             "targets": _sds((B, S), jnp.int32),
             "mask": _sds((B, S), jnp.float32)}
    if cfg.pos_type == "mrope":
        s_img = int(cfg.image_prefix_frac * S)
        specs["patch_embeds"] = _sds((B, s_img, cfg.d_model), cfg.dtype)
        specs["positions"] = _sds((B, S, 3), jnp.int32)
    if cfg.encoder_layers:
        specs["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    if shape.kind == "prefill":
        specs.pop("targets")
        specs.pop("mask")
    return specs


def decode_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for one serve_step: token + KV cache of seq_len + lengths."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: tf.init_cache(cfg, B, S))
    specs = {"tokens": _sds((B, 1), jnp.int32), "cache": cache}
    if cfg.pos_type == "mrope":
        specs["positions"] = _sds((B, 1, 3), jnp.int32)
    return specs


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg, shape)
    return decode_specs(cfg, shape)
