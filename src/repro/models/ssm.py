"""State-space / linear-attention layers: Mamba-1 (jamba) and RWKV-6 (finch).

Both are written TPU-natively:
* Mamba's selective scan is a chunked ``lax.scan`` carrying the (d_in, d_state)
  state between chunks with an associative scan *inside* each chunk — the state
  tensor (T, d_in, N) is only ever materialized chunk-wide (the TPU analogue of
  the CUDA fused selective-scan kernel's SRAM blocking).
* RWKV6's WKV recurrence is a ``lax.scan`` over time carrying the per-head
  (dk, dv) state matrix; channels/heads are sharded over the ``model`` axis
  (TP for attention-free layers).

Decode paths are single-step state updates (O(1) per token) — this is what
makes ``long_500k`` runnable for these families.

Serving hooks: the forward passes accept ``true_len`` so a right-padded
prompt (static-shape prefill buckets) yields *exactly* the recurrent state
after ``true_len`` real tokens — pad steps are neutralized inside the scan
(mamba: ``dt = 0`` makes the transition the identity; rwkv6: ``w = 1`` and
``k = 0`` freeze the WKV state) and shift/conv states are sliced at the
true prompt end.  :func:`scatter_slot_state` writes one request's states
into a slot row of the engine's slot-indexed cache.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    N = cfg.ssm_d_state
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": layers.init_dense(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_d_conv, d_in), jnp.float32)
                   * (cfg.ssm_d_conv ** -0.5)).astype(dtype),
        "x_proj": layers.init_dense(ks[2], d_in, 2 * N + 1, dtype),   # B, C, dt
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, N)).copy()),
        "D": jnp.ones((d_in,), jnp.float32),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "out_proj": layers.init_dense(ks[3], d_in, d, dtype),
    }


def _causal_conv(x, w, state=None, true_len=None):
    """x: (B, T, C); w: (K, C). Returns (y, new_state) with state (B, K-1, C).

    With ``true_len`` the state is the K-1 inputs *ending at the true prompt
    end* (xp row i holds input position i-(K-1), so rows [true_len,
    true_len+K-1) are positions [true_len-K+1, true_len)) — trailing pad
    inputs never enter the resumed conv window."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([state, x], axis=1)              # (B, T+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    if true_len is None:
        new_state = xp[:, -(K - 1):] if K > 1 else xp[:, :0]
    else:
        new_state = jax.lax.dynamic_slice_in_dim(xp, true_len, K - 1, axis=1)
    return y, new_state


def _ssm_scan_chunked(A, xi, dt, Bc, Cc, h0, chunk: int):
    """Selective scan h_t = dA_t * h_{t-1} + dBx_t ; y_t = h_t . C_t.

    A: (d_in, N); xi, dt: (B, T, d_in); Bc, Cc: (B, T, N); h0: (B, d_in, N).

    Discretization (dA = exp(dt*A), dBx = dt*B*x) happens *inside* each
    chunk step and the step is rematerialized — the (chunk, d_in, N) state
    tensors exist only chunk-wide (the TPU/VMEM analogue of the fused CUDA
    selective-scan; full-length (T, d_in, N) buffers never hit HBM).
    """
    B, T, d_in = xi.shape
    N = A.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk

    def split(t):
        return t.reshape((B, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    def assoc(a, b):
        return (a[0] * b[0], a[1] * b[0] + b[1])

    @jax.checkpoint
    def step(h, inp):
        xi_c, dt_c, B_c, C_c = inp                        # (B,chunk,...)
        dA = jnp.exp(dt_c[..., None] * A)                 # (B,chunk,d_in,N)
        dBx = (dt_c[..., None] * B_c[..., None, :].astype(jnp.float32)
               * xi_c[..., None].astype(jnp.float32))
        A_cum, X_cum = jax.lax.associative_scan(assoc, (dA, dBx), axis=1)
        h_t = A_cum * h[:, None] + X_cum                  # (B,chunk,d_in,N)
        y = jnp.einsum("btdn,btn->btd", h_t,
                       C_c.astype(jnp.float32))
        return h_t[:, -1], y

    h_f, ys = jax.lax.scan(step, h0, (split(xi), split(dt),
                                      split(Bc), split(Cc)))
    y = ys.swapaxes(0, 1).reshape(B, T, d_in)
    return y, h_f


def mamba_forward(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
                  state: Dict = None, chunk: int = 512,
                  true_len=None) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, T, d). state: {'conv': (B,K-1,d_in), 'ssm': (B,d_in,N)} or None.

    ``true_len`` (serving prefill): positions >= true_len are padding — their
    ``dt`` is forced to 0, making the selective-scan step the identity
    (dA = exp(0) = 1, dBx = 0), so the returned ``ssm``/``conv`` states are
    exactly the states after ``true_len`` real tokens."""
    B, T, d = x.shape
    N = cfg.ssm_d_state
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B,T,d_in) each
    conv_state = None if state is None else state["conv"]
    xi, new_conv = _causal_conv(xi, p["conv_w"], conv_state,
                                true_len=true_len)
    xi = jax.nn.silu(xi)
    bcd = xi @ p["x_proj"]                                # (B,T,2N+1)
    Bc, Cc, dt = bcd[..., :N], bcd[..., N:2 * N], bcd[..., 2 * N]
    # per-channel dt = softplus(scalar head + channel bias)  (dt_rank=1 variant)
    dt = jax.nn.softplus(dt[..., None].astype(jnp.float32) + p["dt_bias"])  # (B,T,d_in)
    if true_len is not None:
        dt = dt * (jnp.arange(T) < true_len)[None, :, None]
    A = -jnp.exp(p["A_log"])                              # (d_in, N)
    h0 = (jnp.zeros((B, cfg.ssm_expand * d, N), jnp.float32)
          if state is None else state["ssm"])
    y, h_f = _ssm_scan_chunked(A, xi, dt, Bc, Cc, h0, chunk)
    y = y + xi.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": h_f}


def mamba_decode_step(cfg: ArchConfig, p: Dict, x: jnp.ndarray, state: Dict
                      ) -> Tuple[jnp.ndarray, Dict]:
    """Single-token step. x: (B, 1, d)."""
    return mamba_forward(cfg, p, x, state=state, chunk=1)


def init_mamba_state(cfg: ArchConfig, batch: int) -> Dict:
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, d_in), jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((batch, d_in, cfg.ssm_d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RWKV-6 (finch): data-dependent decay time-mix
# ---------------------------------------------------------------------------

def init_rwkv6(key, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    # decay: per-channel base + low-rank data-dependent delta (finch)
    lora = max(32, d // 32)
    return {
        "mix": 0.5 * jnp.ones((5, d), jnp.float32),        # lerp coefs r,k,v,w,g
        "Wr": layers.init_dense(ks[0], d, d, dtype),
        "Wk": layers.init_dense(ks[1], d, d, dtype),
        "Wv": layers.init_dense(ks[2], d, d, dtype),
        "Wg": layers.init_dense(ks[3], d, d, dtype),
        "Wo": layers.init_dense(ks[4], d, d, dtype),
        "w_base": -6.0 + jnp.zeros((d,), jnp.float32),
        "w_lora_a": layers.init_dense(ks[5], d, lora, dtype),
        "w_lora_b": layers.init_dense(ks[6], lora, d, dtype),
        "u": jnp.zeros((H, hs), jnp.float32),              # time_first bonus
        "ln_x": {"scale": jnp.ones((d,), jnp.float32),
                 "bias": jnp.zeros((d,), jnp.float32)},
    }


def _wkv6_scan(r, k, v, w, u):
    """Sequential WKV recurrence (oracle / decode path).
    r,k,v: (B,T,H,hs); w: (B,T,H,hs) decay in (0,1); u: (H,hs).

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    """
    B, T, H, hs = r.shape

    def step(S, inp):
        rt, kt, vt, wt = inp                               # (B,H,hs)
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,hs,hs)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[..., None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    S0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    S_f, out = jax.lax.scan(step, S0, xs)
    return out.transpose(1, 0, 2, 3), S_f


def _wkv6_chunked(r, k, v, w, u, S0=None, chunk: int = 32):
    """Chunked WKV (the TPU-native train/prefill path).

    The per-token scan reads/writes the (H, hs, hs) state every token —
    O(T * H * hs^2) HBM traffic that made rwkv6 train_4k 99.99% memory-bound.
    Here the state is carried once per chunk; within a chunk, contributions
    go through decay-matrix einsums whose exponents are all <= 0 (exact, no
    overflow; deep-past pairs underflow to their true ~0 contribution):

      cum_t   = sum_{s<=t} log w_s                  (per channel, <= 0)
      intra   : o_t += sum_{s<t} (r_t . exp(cum_{t-1}-cum_s) k_s) v_s
      cross   : o_t += (r_t * exp(cum_{t-1})) . S_chunk_start
      bonus   : o_t += u * (r_t . k_t) v_t
      state   : S'  = exp(cum_C) * S + sum_s (exp(cum_C - cum_s) k_s) v_s^T
    """
    B, T, H, hs = r.shape
    chunk = min(chunk, T)
    if T % chunk:
        import math
        chunk = math.gcd(chunk, T)
    nc = T // chunk

    def split(t):
        return t.reshape(B, nc, chunk, H, hs).swapaxes(0, 1)

    rc, kc, vc, wc = map(split, (r, k, v, w))
    if S0 is None:
        S0 = jnp.zeros((B, H, hs, hs), jnp.float32)

    @jax.checkpoint
    def step(S, inp):
        rt, kt, vt, wt = inp                     # (B,C,H,hs)
        # 1e-30: subnormal floors flush to zero on some backends -> log(0)
        lw = jnp.log(jnp.maximum(wt, 1e-30))
        cum = jnp.cumsum(lw, axis=1)             # (B,C,H,hs), <= 0
        cum_prev = cum - lw                      # cum_{t-1}
        cum_C = cum[:, -1:]                      # (B,1,H,hs)
        # intra-chunk: decay matrix D[t,s,c] = exp(cum_{t-1,c} - cum_{s,c})
        expo = cum_prev[:, :, None] - cum[:, None, :, :, :]  # (B,C,C,H,hs)
        mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :]
                )[None, :, :, None, None]
        D = jnp.where(mask, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        M = jnp.einsum("bthc,btshc,bshc->bhts", rt, D, kt)   # (B,H,C,C)
        o = jnp.einsum("bhts,bshv->bthv", M, vt)
        # cross-chunk: state contribution
        o += jnp.einsum("bthc,bhcv->bthv", rt * jnp.exp(cum_prev), S)
        # bonus (current token): sum_c r_c u_c k_c
        o += jnp.sum(rt * kt * u, axis=-1, keepdims=True) * vt
        # state update
        k2 = kt * jnp.exp(cum_C - cum)
        S = jnp.exp(cum_C)[:, 0, :, :, None] * S \
            + jnp.einsum("bshc,bshv->bhcv", k2, vt)
        return S, o

    S_f, out = jax.lax.scan(step, S0, (rc, kc, vc, wc))
    return out.swapaxes(0, 1).reshape(B, T, H, hs), S_f


def rwkv6_forward(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
                  state: Dict = None, wkv_chunk: int = 32,
                  true_len=None) -> Tuple[jnp.ndarray, Dict]:
    """Time-mix block. x: (B,T,d). state: {'last': (B,d), 'wkv': (B,H,hs,hs)}.

    ``true_len`` (serving prefill): pad positions get ``w = 1`` (log-decay 0)
    and ``k = 0``, so the WKV recurrence is frozen past the true prompt end
    and the returned state/``last`` are exactly those after ``true_len``
    tokens."""
    B, T, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs
    last = jnp.zeros((B, 1, d), x.dtype) if state is None else state["last"][:, None]
    x_prev = jnp.concatenate([last, x[:, :-1]], axis=1)    # token shift
    xf = x.astype(jnp.float32)
    pf = x_prev.astype(jnp.float32)

    def mixed(i):
        m = p["mix"][i]
        return (xf * m + pf * (1 - m)).astype(x.dtype)

    r = (mixed(0) @ p["Wr"]).reshape(B, T, H, hs).astype(jnp.float32)
    k = (mixed(1) @ p["Wk"]).reshape(B, T, H, hs).astype(jnp.float32)
    v = (mixed(2) @ p["Wv"]).reshape(B, T, H, hs).astype(jnp.float32)
    wx = mixed(3)
    g = jax.nn.silu(mixed(4) @ p["Wg"])
    w_delta = jnp.tanh(wx @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(p["w_base"] + w_delta.astype(jnp.float32)))  # (B,T,d)
    w = w.reshape(B, T, H, hs)
    if true_len is not None:
        live = (jnp.arange(T) < true_len)[None, :, None, None]
        w = jnp.where(live, w, 1.0)
        k = k * live

    S0 = None if state is None else state["wkv"]
    if T == 1:
        # decode: single sequential step (no chunk machinery)
        def step(S, inp):
            rt, kt, vt, wt = inp
            kv = kt[..., :, None] * vt[..., None, :]
            o = jnp.einsum("bhk,bhkv->bhv", rt, S + p["u"][..., None] * kv)
            S = wt[..., None] * S + kv
            return S, o
        xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
        S_f, out = jax.lax.scan(
            step, S0 if S0 is not None
            else jnp.zeros((B, H, hs, hs), jnp.float32), xs)
        out = out.transpose(1, 0, 2, 3)
    else:
        out, S_f = _wkv6_chunked(r, k, v, w, p["u"], S0, chunk=wkv_chunk)

    out = out.reshape(B, T, d).astype(x.dtype)
    out = layers.apply_norm(
        type("c", (), {"norm_type": "layernorm"}), p["ln_x"], out)
    out = (out * g) @ p["Wo"]
    last = x[:, -1] if true_len is None else \
        jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)[:, 0]
    return out, {"last": last, "wkv": S_f}


def init_rwkv6_state(cfg: ArchConfig, batch: int) -> Dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    return {"last": jnp.zeros((batch, d), jnp.dtype(cfg.dtype)),
            "wkv": jnp.zeros((batch, d // hs, hs, hs), jnp.float32)}


# RWKV channel-mix (the FFN counterpart, with token shift + receptance gate)
def init_rwkv_cmix(key, cfg: ArchConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {"mix": 0.5 * jnp.ones((2, d), jnp.float32),
            "Wk": layers.init_dense(ks[0], d, f, dtype),
            "Wv": layers.init_dense(ks[1], f, d, dtype),
            "Wr": layers.init_dense(ks[2], d, d, dtype)}


def rwkv_cmix_forward(cfg: ArchConfig, p: Dict, x: jnp.ndarray,
                      state=None, true_len=None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, d = x.shape
    last = jnp.zeros((B, 1, d), x.dtype) if state is None else state[:, None]
    x_prev = jnp.concatenate([last, x[:, :-1]], axis=1)
    xf, pf = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    xk = (xf * p["mix"][0] + pf * (1 - p["mix"][0])).astype(x.dtype)
    xr = (xf * p["mix"][1] + pf * (1 - p["mix"][1])).astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    out = jax.nn.sigmoid(xr @ p["Wr"]) * (k @ p["Wv"])
    shift = x[:, -1] if true_len is None else \
        jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)[:, 0]
    return out, shift


# ---------------------------------------------------------------------------
# Slot-indexed state helpers (serving): the engine's caches carry recurrent
# states with a slot (batch) axis; one request's prefilled states scatter
# into its slot row.
# ---------------------------------------------------------------------------

def scatter_slot_state(states, update, slot, batch_axis: int):
    """Write one request's state rows into slot ``slot`` of a slot-indexed
    state pytree.  ``update`` leaves match ``states`` leaves except for a
    size-1 dim at ``batch_axis`` (the single prefilled request)."""
    def one(dst, src):
        start = [0] * dst.ndim
        start[batch_axis] = slot
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                            tuple(start))
    return jax.tree.map(one, states, update)
