"""Adaptive load balancing (paper C4): the three mechanisms the paper folds
into its "adaptive load-balancing mechanism".

1. **Expert placement rebalancing** (MoE, §III.A.c): given observed per-expert
   token loads, re-assign experts to devices with LPT (longest-processing-time
   first) greedy bin packing so per-device load is near-uniform.  Returns the
   permutation to apply to the expert-sharded weight arrays.
2. **Pipeline stage partitioning** (§III.A.b): contiguous layer->stage
   partition minimizing the max stage cost (classic linear-partition DP) —
   kills pipeline "bubbles" from imbalanced stages.
3. **Adaptive per-worker batch sizing** (§V.A, heterogeneous hardware):
   largest-remainder proportional allocation of the global batch to workers
   by measured speed.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def rebalance_experts(load: Sequence[float], n_devices: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """LPT assignment of experts to devices.

    Returns (assignment (E,) device-id per expert, permutation (E,) such that
    experts[permutation] lays experts out contiguously by device with
    balanced per-device load).  E % n_devices == 0 is preserved: each device
    receives exactly E/n_devices experts (capacity-constrained LPT).
    """
    load = np.asarray(load, np.float64)
    E = load.shape[0]
    assert E % n_devices == 0
    cap = E // n_devices
    order = np.argsort(-load)                      # heaviest first
    dev_load = np.zeros(n_devices)
    dev_count = np.zeros(n_devices, np.int64)
    assignment = np.zeros(E, np.int64)
    for e in order:
        open_devs = np.where(dev_count < cap)[0]
        d = open_devs[np.argmin(dev_load[open_devs])]
        assignment[e] = d
        dev_load[d] += load[e]
        dev_count[d] += 1
    permutation = np.argsort(assignment, kind="stable")
    return assignment, permutation


def balance_quality(load: Sequence[float], assignment: np.ndarray,
                    n_devices: int) -> float:
    """max/mean per-device load (1.0 = perfect)."""
    load = np.asarray(load, np.float64)
    per_dev = np.bincount(assignment, weights=load, minlength=n_devices)
    return float(per_dev.max() / max(per_dev.mean(), 1e-12))


def balance_stages(layer_costs: Sequence[float], n_stages: int) -> List[int]:
    """Contiguous partition of layers into stages minimizing max stage cost.

    Returns stage boundaries: list of n_stages+1 indices (b[s], b[s+1]) is
    stage s's layer range.  O(L^2 * S) DP — L is small.
    """
    costs = np.asarray(layer_costs, np.float64)
    L = len(costs)
    prefix = np.concatenate([[0.0], np.cumsum(costs)])

    def seg(i, j):                                  # cost of layers [i, j)
        return prefix[j] - prefix[i]

    INF = float("inf")
    dp = np.full((n_stages + 1, L + 1), INF)
    cut = np.zeros((n_stages + 1, L + 1), np.int64)
    dp[0, 0] = 0.0
    for s in range(1, n_stages + 1):
        for j in range(1, L + 1):
            for i in range(s - 1, j):
                c = max(dp[s - 1, i], seg(i, j))
                if c < dp[s, j]:
                    dp[s, j] = c
                    cut[s, j] = i
    bounds = [L]
    j = L
    for s in range(n_stages, 0, -1):
        j = int(cut[s, j])
        bounds.append(j)
    return bounds[::-1]


def stage_costs(layer_costs: Sequence[float], bounds: List[int]
                ) -> np.ndarray:
    c = np.asarray(layer_costs, np.float64)
    return np.array([c[bounds[s]:bounds[s + 1]].sum()
                     for s in range(len(bounds) - 1)])


def layer_costs_from_stage_times(stage_times: Sequence[float],
                                 bounds: Sequence[int]) -> np.ndarray:
    """Per-layer cost estimate from observed per-stage timings.

    Timing granularity is the stage (one tick = one stage_fn call), so a
    stage's measured time is attributed uniformly to its layers — exact
    when layers inside a stage are homogeneous, and a contraction toward
    the fix-point otherwise (each rebalance re-measures at the new
    partition)."""
    bounds = list(bounds)
    costs = np.zeros(bounds[-1], np.float64)
    for s in range(len(bounds) - 1):
        n = bounds[s + 1] - bounds[s]
        costs[bounds[s]:bounds[s + 1]] = float(stage_times[s]) / max(n, 1)
    return costs


def rebalance_stages(stage_times: Sequence[float], bounds: Sequence[int],
                     n_stages: int = 0) -> List[int]:
    """Close the observe->rebalance loop for pipeline stages (the stage
    analogue of ``rebalance_experts`` -> ``rebalance_moe_params``): observed
    per-tick stage timings re-carve the layer->stage bounds via the same
    linear-partition DP.  Apply the new bounds to live stage params with
    :func:`repro.models.transformer.remap_stage_params` — the remap is
    output-preserving (layer order never changes, only the carve points).
    """
    bounds = list(bounds)
    n_stages = n_stages or len(bounds) - 1
    costs = layer_costs_from_stage_times(stage_times, bounds)
    return balance_stages(costs, n_stages)


def rebalance_from_trace(events, bounds: Sequence[int],
                         n_stages: int = 0) -> List[int]:
    """:func:`rebalance_stages` fed straight from the observability
    timeline: per-stage times are the medians of ``stage_tick`` span
    durations (:func:`repro.obs.timeline.stage_tick_times` — the same
    sort-then-middle reduction ``probe_stage_times`` applies), so a
    recorded trace can drive the rebalance decision in place of a live
    probe."""
    from repro.obs.timeline import stage_tick_times
    bounds = list(bounds)
    n_stages = n_stages or len(bounds) - 1
    times = stage_tick_times(events, n_stages)
    return rebalance_stages(times, bounds, n_stages)


def adaptive_batch_allocation(worker_speeds: Sequence[float],
                              global_batch: int,
                              min_per_worker: int = 1) -> np.ndarray:
    """Largest-remainder proportional split of the global batch by speed."""
    speeds = np.asarray(worker_speeds, np.float64)
    P = len(speeds)
    assert global_batch >= P * min_per_worker
    frac = speeds / speeds.sum() * (global_batch - P * min_per_worker)
    base = np.floor(frac).astype(np.int64) + min_per_worker
    rem = global_batch - base.sum()
    order = np.argsort(-(frac - np.floor(frac)))
    base[order[:rem]] += 1
    return base


def straggler_dropk_weights(arrival_order: Sequence[int], drop_k: int
                            ) -> np.ndarray:
    """Backup-worker semantics: weight 0 for the last ``drop_k`` arrivals,
    renormalized mean over the rest."""
    P = len(arrival_order)
    w = np.ones(P)
    slowest = np.argsort(arrival_order)[-drop_k:] if drop_k else []
    w[slowest] = 0.0
    return w / w.sum()


def rebalance_moe_params(moe_params: dict, permutation: np.ndarray) -> dict:
    """Apply an expert permutation to a live MoE layer (router columns +
    expert-stacked weights).  The model function is permutation-equivariant
    — outputs are bit-identical — but the experts' physical placement on
    the ``model`` mesh axis follows the LPT assignment, balancing
    per-device load (paper C4, closing the observe->rebalance loop).

    Works on one layer's params or on layer-stacked (L, E, ...) arrays
    (same permutation applied to every layer).
    """
    perm = list(permutation)
    out = dict(moe_params)
    out["router"] = moe_params["router"][..., perm]
    for key in ("wi", "wi_gate", "wi_up", "wo"):
        if key in moe_params:
            w = moe_params[key]
            axis = w.ndim - 3                   # (..., E, din, dout)
            out[key] = np.take(w, perm, axis=axis) if isinstance(
                w, np.ndarray) else w.take(jnp_array(perm), axis=axis)
    return out


def jnp_array(x):
    import jax.numpy as jnp
    return jnp.asarray(x)
