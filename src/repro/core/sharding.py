"""Sharding plan: logical-axis partition rules -> PartitionSpecs (paper C1/C8).

Megatron-style tensor parallelism over the ``model`` mesh axis, batch over
``data`` (and ``pod``), MoE experts over ``model`` (expert parallelism),
optimizer state additionally ZeRO-1 sharded over the dp axes, activations
optionally sequence-sharded over ``model`` (Megatron-SP).

Every sharded dim is divisibility-guarded: if a dim does not divide evenly
over its assigned axes the spec falls back to replication for that dim (this
is what makes gemma3-1b's 4-head attention or batch=1 long-context decode
lower cleanly — see DESIGN.md §4).

Embedding tables route through the sparse-embedding subsystem: top-level
param keys named in ``embed_plans`` (e.g. the recsys CF factor tables) take
their placement from an :class:`repro.embeddings.EmbedPlan` — row/col/2D
sharding under the same hybrid mesh — instead of the LM rules, so the
GSPMD train step places them exactly where the shard_map DP path and the
``embed`` benchmark cost them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ArchConfig, ParallelConfig, ShapeConfig
from repro.embeddings.table import EmbedPlan, pspec as embed_pspec


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    dp_axes: Tuple[str, ...]          # ('data',) or ('pod', 'data')
    tp_axis: Optional[str]            # 'model' or None
    seq_shard: bool = True            # Megatron-SP residual stream
    zero1: bool = True
    # dp_heavy (auto-planner, dense archs): batch shards over ALL mesh axes
    # (model included); weights stay model-sharded for storage and are
    # all-gathered at use (FSDP) — activations never reshard.
    dp_heavy: bool = False
    # top-level param keys placed by the embeddings subsystem (EmbedPlan)
    # rather than the LM rules — the recsys CF tables under the hybrid mesh
    embed_plans: Optional[Dict[str, EmbedPlan]] = None

    # -- helpers -----------------------------------------------------------

    @property
    def dp(self) -> Tuple[str, ...]:
        return self.dp_axes

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        if self.dp_heavy and self.tp_axis is not None:
            return self.dp_axes + (self.tp_axis,)
        return self.dp_axes

    def guard(self, spec: Sequence, shape: Sequence[int]) -> P:
        """Drop sharding on any dim that does not divide evenly."""
        out = []
        for dim_spec, size in zip(spec, shape):
            if dim_spec is None:
                out.append(None)
            elif size % _axis_size(self.mesh, dim_spec) == 0 and size > 0:
                out.append(dim_spec)
            else:
                out.append(None)
        return P(*out)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -- parameters ---------------------------------------------------------

    def param_specs(self, cfg: ArchConfig, params_shape) -> Any:
        """Pytree of PartitionSpec matching a params pytree (of arrays or
        ShapeDtypeStructs)."""
        M = self.tp_axis
        q_ok = M is not None and cfg.num_heads % self.mesh.shape[M] == 0
        kv_ok = M is not None and cfg.num_kv_heads % self.mesh.shape[M] == 0

        # FSDP for expert weights: when the per-device expert bytes after
        # EP sharding are still large (jamba: 1 expert/dev = 5.6GB), shard
        # the d_ff dim over the dp axes too.  Weights are all-gathered at
        # use; gradients accumulate *sharded* through the layer scan (the
        # ZeRO-2 constraint alone cannot reach inside scan accumulators).
        fsdp_experts = False
        if cfg.is_moe and M is not None:
            mats = 3 if cfg.mlp_gated else 2
            n_moe_layers = sum(
                1 for i in range(cfg.num_layers)
                if i % cfg.moe_period == cfg.moe_period - 1)
            expert_bytes = (n_moe_layers * cfg.num_experts * mats
                            * cfg.d_model * cfg.d_ff * 2
                            / max(self.mesh.shape[M], 1))
            fsdp_experts = expert_bytes > 2e9

        def rule(path, leaf) -> P:
            names = [getattr(k, "key", getattr(k, "idx", None))
                     for k in path]
            names = [str(n) for n in names]
            last = names[-1]
            shape = leaf.shape
            if self.embed_plans and names[0] in self.embed_plans \
                    and len(shape) == 2:
                plan = self.embed_plans[names[0]]
                return self.guard(tuple(embed_pspec(plan)), shape)
            base: Tuple = ()
            if "moe" in names:
                dp = self.dp_axes if len(self.dp_axes) > 1 \
                    else self.dp_axes[0]
                if last == "router":
                    base = (None, None)
                elif fsdp_experts and last in ("wi", "wi_gate", "wi_up"):
                    base = (M, None, dp)                # (E, d, f): f over dp
                elif fsdp_experts and last == "wo":
                    base = (M, dp, None)                # (E, f, d)
                else:                                   # (E, din, dout)
                    base = (M, None, None)
            elif "mlp" in names or "cmix" in names:
                if last in ("wi", "wi_gate", "wi_up", "Wk"):
                    base = (None, M)
                elif last in ("wo", "Wv"):
                    base = (M, None)
                elif last == "Wr":
                    base = (None, None)
                elif last == "mix":
                    base = (None, None)
                else:
                    base = (None,) * 2
            elif "tmix" in names:
                if last in ("Wr", "Wk", "Wv", "Wg"):
                    base = (None, M)
                elif last == "Wo":
                    base = (M, None)
                elif last == "w_lora_b":
                    base = (None, M)
                elif last == "u":
                    base = (M, None)
                elif last in ("w_base",):
                    base = (M,)
                elif last in ("scale", "bias"):
                    base = (None,)
                elif last == "mix":
                    base = (None, None)
                else:
                    base = (None,) * len(shape)
            elif "m" in names or "mamba" in names:      # mamba inner
                if last in ("in_proj",):
                    base = (None, M)
                elif last in ("conv_w",):
                    base = (None, M)
                elif last in ("x_proj", "A_log", "out_proj"):
                    base = (M, None)
                elif last in ("D", "dt_bias"):
                    base = (M,)
                elif last in ("scale", "bias"):
                    base = (None,)
                else:
                    base = (None,) * len(shape)
            elif "attn" in names or "cross" in names:
                if last == "wq":
                    base = (None, M if q_ok else None)
                elif last in ("wk", "wv"):
                    base = (None, M if kv_ok else None)
                elif last == "wo":
                    base = (M if q_ok else None, None)
                else:                                   # norms, q/k_norm
                    base = (None,) * len(shape)
            elif last == "embed":
                base = (M, None)
            elif last == "lm_head":
                base = (None, M)
            elif last == "dec_pos":
                base = (None, None)
            else:                                       # final norms etc.
                base = (None,) * len(shape)
            # prepend Nones for stacked layer/period dims
            full = (None,) * (len(shape) - len(base)) + tuple(base)
            return self.guard(full, shape)

        return jax.tree_util.tree_map_with_path(rule, params_shape)

    # -- optimizer state (ZeRO-1) --------------------------------------------

    def zero1_spec(self, pspec: P, shape: Sequence[int]) -> P:
        """Add dp axes to the largest unsharded, divisible dim (ZeRO-1)."""
        if not self.zero1:
            return pspec
        dp_n = _axis_size(self.mesh, self.dp_axes)
        spec = list(pspec) + [None] * (len(shape) - len(pspec))
        # already dp-sharded (e.g. FSDP expert weights): nothing to add
        used = set()
        for s in spec:
            if s is None:
                continue
            used.update((s,) if isinstance(s, str) else s)
        if used & set(self.dp_axes):
            return pspec
        best, best_size = -1, 0
        for i, (sp, size) in enumerate(zip(spec, shape)):
            if sp is None and size % dp_n == 0 and size > best_size:
                best, best_size = i, size
        if best >= 0:
            spec[best] = self.dp_axes if len(self.dp_axes) > 1 \
                else self.dp_axes[0]
        return P(*spec)

    def opt_specs(self, cfg: ArchConfig, params_shape) -> Any:
        pspecs = self.param_specs(cfg, params_shape)
        return jax.tree.map(
            lambda sp, leaf: self.zero1_spec(sp, leaf.shape),
            pspecs, params_shape)

    # -- batches -------------------------------------------------------------

    def batch_specs(self, batch_shape) -> Any:
        def rule(path, leaf) -> P:
            shape = leaf.shape
            if len(shape) == 0:
                return P()
            base = (self.batch_axes,) + (None,) * (len(shape) - 1)
            return self.guard(base, shape)
        return jax.tree_util.tree_map_with_path(rule, batch_shape)

    # -- decode caches ---------------------------------------------------------

    def cache_specs(self, cfg: ArchConfig, cache_shape) -> Any:
        M = self.tp_axis

        def rule(path, leaf) -> P:
            names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
            last = names[-1]
            shape = leaf.shape
            nd = len(shape)
            if last in ("k", "v", "cross_k", "cross_v") or \
                    (len(names) >= 2 and names[-2] in ("k", "v")):
                # (..., B, S, Hk, D): batch->dp, seq->model
                if self.dp_heavy:
                    base = (None,) * (nd - 4) + (self.batch_axes, None,
                                                 None, None)
                else:
                    base = (None,) * (nd - 4) + (self.dp_axes, M, None, None)
            elif last == "len":
                base = (self.dp_axes,)
            elif last in ("conv",):                    # (..., B, K-1, d_in)
                base = (None,) * (nd - 3) + (self.dp_axes, None, M)
            elif last in ("ssm",):                     # (..., B, d_in, N)
                base = (None,) * (nd - 3) + (self.dp_axes, M, None)
            elif last == "wkv":                        # (L, B, H, hs, hs)
                base = (None,) * (nd - 4) + (self.dp_axes, M, None, None)
            elif last in ("tmix_last", "cmix_last"):   # (L, B, d)
                base = (None,) * (nd - 2) + (self.dp_axes, M)
            else:
                base = (None,) * nd
            return self.guard(base, shape)

        return jax.tree_util.tree_map_with_path(rule, cache_shape)

    # -- activation hooks ------------------------------------------------------

    def constrain(self, x: jnp.ndarray, name: str) -> jnp.ndarray:
        M = self.tp_axis
        if M is None:
            return x
        shape = x.shape
        if name == "residual" and x.ndim == 3:
            if self.dp_heavy:
                spec = self.guard((self.batch_axes, None, None), shape)
            else:
                seq = M if self.seq_shard else None
                spec = self.guard((self.dp_axes, seq, None), shape)
        elif name in ("heads", "kv_heads") and x.ndim == 4:
            # heads over model when divisible; otherwise REPLICATE over
            # model (Megatron GQA rule: kv replicated tp/kv ways) — mixing
            # head-sharded q with seq-sharded kv causes involuntary remats.
            if self.dp_heavy:
                spec = self.guard((self.batch_axes, None, None, None), shape)
            else:
                spec = self.guard((self.dp_axes, None, M, None), shape)
        elif name == "logits" and x.ndim == 3:
            spec = self.guard(
                (self.batch_axes, None, None) if self.dp_heavy
                else (self.dp_axes, None, M), shape)
        elif name == "moe_groups" and x.ndim == 3:
            spec = self.guard((self.dp_axes, None, None), shape)
        elif name == "embed_onehot" and x.ndim == 2:
            # (flat tokens, V): keep tokens batch-sharded -> psum contraction
            spec = self.guard((self.batch_axes, None), shape)
        elif name == "embed_grad" and x.ndim == 2:
            # (V, d): match the ZeRO-2 gradient layout
            spec = self.guard((M, self.dp_axes), shape)
        elif name == "expert_stack" and x.ndim == 4:
            # (groups, E, C, d) — groups over dp, experts over model (EP)
            spec = self.guard((self.dp_axes, M, None, None), shape)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(spec))


def pp_stage_specs(cfg: ArchConfig, stage_shape, mesh: Mesh,
                   tp_axis: str = "model", stage_axis: str = "stage") -> Any:
    """PartitionSpecs for the stage-stacked uniform blocks pytree
    ({"blocks": (S, L_max, ...), "mask": (S, L_max)} from
    ``transformer.stage_slice_params``): leading dim over ``stage_axis``,
    Megatron TP dims over ``tp_axis`` where head / d_ff counts divide
    (non-dividing dims replicate, same guard rule as ``param_specs``).
    The trainer's shard_map consumes these as in/out specs, and uses
    "has a tp dim" to decide which gradient leaves are exact local shards
    versus per-rank partials needing a psum over ``tp_axis``.
    """
    tp = mesh.shape.get(tp_axis, 1)
    q_ok = cfg.num_heads % tp == 0
    kv_ok = cfg.num_kv_heads % tp == 0
    ff_ok = cfg.d_ff % tp == 0
    M = tp_axis

    def rule(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        last = names[-1]
        nd = len(leaf.shape)
        if last == "mask":
            return P(stage_axis, None)
        if last == "wq":
            base = (None, M if q_ok else None)
        elif last in ("wk", "wv"):
            base = (None, M if kv_ok else None)
        elif last == "wo" and "attn" in names:
            base = (M if q_ok else None, None)
        elif last in ("wi", "wi_gate", "wi_up"):
            base = (None, M if ff_ok else None)
        elif last == "wo":                          # mlp down-projection
            base = (M if ff_ok else None, None)
        else:                                       # norms, qk_norm
            base = (None,) * max(nd - 2, 0)
        full = (stage_axis,) + (None,) * (nd - 1 - len(base)) + tuple(base)
        return P(*full)

    return jax.tree_util.tree_map_with_path(rule, stage_shape)


def spec_has_axis(spec: P, axis: str) -> bool:
    for dim in spec:
        if dim is None:
            continue
        if dim == axis or (isinstance(dim, tuple) and axis in dim):
            return True
    return False


def make_plan(mesh: Mesh, pcfg: ParallelConfig,
              seq_shard: Optional[bool] = None,
              dp_heavy: bool = False,
              embed_plans: Optional[Dict[str, EmbedPlan]] = None
              ) -> ShardingPlan:
    axes = set(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    tp_axis = "model" if "model" in axes and mesh.shape["model"] > 1 else \
        ("model" if "model" in axes else None)
    return ShardingPlan(
        mesh=mesh,
        dp_axes=dp_axes or ("data",),
        tp_axis=tp_axis,
        seq_shard=pcfg.seq_shard_activations if seq_shard is None else seq_shard,
        zero1=True,
        dp_heavy=dp_heavy,
        embed_plans=embed_plans,
    )
