"""Asynchronous data parallelism with delay compensation (paper C7, Eq. 12).

A real parameter server cannot live inside one XLA program, so this is a
faithful *simulation* (DESIGN.md §3): P virtual workers push gradients
computed against stale parameter snapshots; the server applies

    theta_{t+1} = theta_t - eta * g_p / (1 + tau_p)          (Eq. 12)

where tau_p is the staleness of worker p's snapshot.  The staleness process
is configurable (fixed, random, or straggler-heavy) so the convergence /
throughput trade-off the paper discusses is measurable, and delay
compensation can be switched off to reproduce the naive-async degradation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class AsyncConfig:
    n_workers: int = 4
    max_staleness: int = 4
    compensate: bool = True           # Eq. 12 down-weighting
    lr: float = 0.1
    staleness: str = "random"         # fixed | random | straggler


def _staleness_schedule(cfg: AsyncConfig, steps: int, rng: np.random.Generator
                        ) -> np.ndarray:
    """(steps,) worker id + staleness per arriving gradient."""
    if cfg.staleness == "fixed":
        tau = np.full(steps, cfg.max_staleness // 2)
    elif cfg.staleness == "random":
        tau = rng.integers(0, cfg.max_staleness + 1, steps)
    elif cfg.staleness == "straggler":
        # one slow worker contributes maximally stale gradients
        tau = rng.integers(0, 2, steps)
        worker = rng.integers(0, cfg.n_workers, steps)
        tau = np.where(worker == 0, cfg.max_staleness, tau)
    else:
        raise ValueError(cfg.staleness)
    return tau.astype(np.int32)


def simulate_async_sgd(loss_fn: Callable, params0, data_stream,
                       cfg: AsyncConfig, seed: int = 0
                       ) -> Tuple[object, List[float]]:
    """Run the async simulation.

    loss_fn(params, batch) -> scalar; data_stream: iterable of batches.
    Keeps a ring buffer of the last ``max_staleness+1`` parameter snapshots;
    each arriving gradient is computed at snapshot (t - tau_t).
    """
    rng = np.random.default_rng(seed)
    batches = list(data_stream)
    steps = len(batches)
    tau_sched = _staleness_schedule(cfg, steps, rng)

    grad_fn = jax.jit(jax.grad(loss_fn))

    @jax.jit
    def apply_update(params, grads, tau):
        scale = cfg.lr / (1.0 + tau) if cfg.compensate else cfg.lr
        return jax.tree.map(lambda p, g: p - scale * g, params, grads)

    history = [params0] * (cfg.max_staleness + 1)   # ring of snapshots
    params = params0
    losses = []
    loss_jit = jax.jit(loss_fn)
    for t in range(steps):
        tau = int(min(tau_sched[t], t))             # cannot be staler than t
        stale_params = history[(t - tau) % len(history)]
        g = grad_fn(stale_params, batches[t])
        params = apply_update(params, g, jnp.float32(tau))
        history[t % len(history)] = params
        losses.append(float(loss_jit(params, batches[t])))
    return params, losses


def simulate_sync_sgd(loss_fn: Callable, params0, data_stream, lr: float
                      ) -> Tuple[object, List[float]]:
    """Synchronous baseline on the same stream (Eq. 8/9)."""
    grad_fn = jax.jit(jax.grad(loss_fn))
    loss_jit = jax.jit(loss_fn)

    @jax.jit
    def upd(params, g):
        return jax.tree.map(lambda p, gg: p - lr * gg, params, g)

    params = params0
    losses = []
    for batch in data_stream:
        params = upd(params, grad_fn(params, batch))
        losses.append(float(loss_jit(params, batch)))
    return params, losses
