"""Asynchronous data parallelism with delay compensation (paper C7, Eq. 12).

A real parameter server cannot live inside one XLA program, so this is a
faithful *simulation* (DESIGN.md §3): P virtual workers push gradients
computed against stale parameter snapshots; the server applies the
trainer's real update rule with a delay-compensated learning rate

    theta_{t+1} = update(theta_t, g_p, eta / (1 + tau_p))     (Eq. 12)

where tau_p is the staleness of worker p's snapshot.  The optimizer is the
SAME plumbing ``runtime.trainer`` uses for the synchronous steps
(:func:`repro.runtime.trainer.make_update_rule` — AdamW + warmup-cosine),
not a hand-rolled SGD, so staleness comparisons against the sync baseline
isolate staleness rather than optimizer differences.  The staleness
process is configurable (fixed, random, or straggler-heavy) so the
convergence / throughput trade-off the paper discusses is measurable, and
delay compensation can be switched off to reproduce the naive-async
degradation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class AsyncConfig:
    n_workers: int = 4
    max_staleness: int = 4
    compensate: bool = True           # Eq. 12 down-weighting
    lr: float = 0.1
    staleness: str = "random"         # fixed | random | straggler
    warmup_steps: int = 1             # shared update rule's LR warmup


def _staleness_schedule(cfg: AsyncConfig, steps: int, rng: np.random.Generator
                        ) -> np.ndarray:
    """(steps,) worker id + staleness per arriving gradient."""
    if cfg.staleness == "fixed":
        tau = np.full(steps, cfg.max_staleness // 2)
    elif cfg.staleness == "random":
        tau = rng.integers(0, cfg.max_staleness + 1, steps)
    elif cfg.staleness == "straggler":
        # one slow worker contributes maximally stale gradients
        tau = rng.integers(0, 2, steps)
        worker = rng.integers(0, cfg.n_workers, steps)
        tau = np.where(worker == 0, cfg.max_staleness, tau)
    else:
        raise ValueError(cfg.staleness)
    return tau.astype(np.int32)


def _update_plumbing(lr: float, steps: int, warmup_steps: int):
    """The trainer's shared optimizer (AdamW + warmup-cosine), configured
    for a bare convergence study: no weight decay, no clipping."""
    from repro.config import TrainConfig
    from repro.runtime import trainer

    tcfg = TrainConfig(steps=steps, learning_rate=lr,
                       warmup_steps=max(warmup_steps, 1), weight_decay=0.0,
                       grad_clip=0.0, checkpoint_every=0)
    return trainer.make_update_rule(tcfg)


def simulate_async_sgd(loss_fn: Callable, params0, data_stream,
                       cfg: AsyncConfig, seed: int = 0
                       ) -> Tuple[object, List[float]]:
    """Run the async simulation.

    loss_fn(params, batch) -> scalar; data_stream: iterable of batches.
    Keeps a ring buffer of the last ``max_staleness+1`` parameter snapshots;
    each arriving gradient is computed at snapshot (t - tau_t) and applied
    through the trainer's shared update rule with the Eq.-12 LR scale.
    """
    rng = np.random.default_rng(seed)
    batches = list(data_stream)
    steps = len(batches)
    tau_sched = _staleness_schedule(cfg, steps, rng)

    grad_fn = jax.jit(jax.grad(loss_fn))
    init, apply = _update_plumbing(cfg.lr, steps, cfg.warmup_steps)
    apply_jit = jax.jit(apply)

    history = [params0] * (cfg.max_staleness + 1)   # ring of snapshots
    params = params0
    opt = init(params0)
    losses = []
    loss_jit = jax.jit(loss_fn)
    for t in range(steps):
        tau = int(min(tau_sched[t], t))             # cannot be staler than t
        stale_params = history[(t - tau) % len(history)]
        g = grad_fn(stale_params, batches[t])
        scale = 1.0 / (1.0 + tau) if cfg.compensate else 1.0
        params, opt = apply_jit(params, opt, g, jnp.float32(scale))
        history[t % len(history)] = params
        losses.append(float(loss_jit(params, batches[t])))
    return params, losses


def simulate_sync_sgd(loss_fn: Callable, params0, data_stream, lr: float,
                      warmup_steps: int = 1) -> Tuple[object, List[float]]:
    """Synchronous baseline on the same stream (Eq. 8/9), through the same
    shared update rule as the async simulator."""
    batches = list(data_stream)
    grad_fn = jax.jit(jax.grad(loss_fn))
    loss_jit = jax.jit(loss_fn)
    init, apply = _update_plumbing(lr, len(batches), warmup_steps)
    apply_jit = jax.jit(apply)

    params = params0
    opt = init(params0)
    losses = []
    for batch in batches:
        params, opt = apply_jit(params, opt, grad_fn(params, batch),
                                jnp.float32(1.0))
        losses.append(float(loss_jit(params, batch)))
    return params, losses
