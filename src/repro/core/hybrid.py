"""Hybrid parallelism auto-planner (paper C8 — the DeepSpeed/Megatron
auto-scheduled hybrid scheme of Table 2, row 4).

Given (arch, mesh, shape) it derives a per-layer cost model and emits a
``Plan``: which tensors take TP, whether activations are sequence-sharded,
remat policy, gradient-sync mode (flat / hierarchical / compressed), and —
when a ``stage`` axis is present — the balanced pipeline partition.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from jax.sharding import Mesh

from repro.config import (ArchConfig, ParallelConfig, ShapeConfig,
                          HBM_BYTES_PER_CHIP, ICI_BW_PER_LINK,
                          PEAK_FLOPS_BF16)
from repro.core import load_balance
from repro.core.sharding import ShardingPlan, make_plan


def layer_flops(cfg: ArchConfig, kind: str, layer_idx: int, seq: int) -> float:
    """Forward FLOPs for one layer at batch=1, given sequence length."""
    d = cfg.d_model
    if kind in ("attn", "local_attn"):
        proj = 2 * seq * d * (cfg.q_dim + 2 * cfg.kv_dim + cfg.q_dim)
        ctx_len = min(seq, cfg.sliding_window) if kind == "local_attn" and \
            cfg.sliding_window else seq
        attn = 2 * seq * ctx_len * cfg.q_dim * 2
        f = proj + attn
    elif kind == "mamba":
        d_in = cfg.ssm_expand * d
        f = 2 * seq * d * 2 * d_in + 2 * seq * d_in * d \
            + seq * d_in * cfg.ssm_d_state * 6
    elif kind == "rwkv6":
        f = 2 * seq * d * d * 5 + seq * d * cfg.rwkv_head_size * 4
    else:
        raise ValueError(kind)
    # FFN
    mats = 3 if cfg.mlp_gated else 2
    if cfg.is_moe and layer_idx % cfg.moe_period == cfg.moe_period - 1:
        f += 2 * seq * mats * d * cfg.d_ff * cfg.experts_per_token
    else:
        f += 2 * seq * mats * d * cfg.d_ff
    return float(f)


def model_flops(cfg: ArchConfig, seq: int, batch: int,
                training: bool = True) -> float:
    """6*N*D-style total: fwd (+2x bwd when training) over all layers."""
    f = sum(layer_flops(cfg, kind, i, seq)
            for i, kind in enumerate(cfg.layer_kinds()))
    if cfg.encoder_layers:
        f += cfg.encoder_layers * layer_flops(cfg, "attn", 0,
                                              cfg.encoder_frames)
    f += 2 * seq * cfg.d_model * cfg.padded_vocab      # lm head
    f *= batch
    return f * 3 if training else f


def decode_model_flops(cfg: ArchConfig, cache_len: int, batch: int) -> float:
    """One serve_step: 2*N_active per token + attention over the cache.

    No encoder (whisper's runs once at prefill, not per decode step); the
    dominant attention cost is q . K_cache over ``cache_len`` positions."""
    f = 2.0 * cfg.active_params()
    for kind in cfg.layer_kinds():
        if kind == "attn":
            f += 2 * cache_len * cfg.q_dim * 2
        elif kind == "local_attn":
            f += 2 * min(cache_len, cfg.sliding_window or cache_len) \
                * cfg.q_dim * 2
    if cfg.encoder_layers:
        # encoder weights are not touched per decode step; cross-attention
        # reads the precomputed enc K/V cache instead
        f -= 2.0 * cfg.encoder_layers * cfg._layer_params("attn")
        f += cfg.num_layers * 2 * cfg.encoder_frames * cfg.q_dim * 2
    return f * batch


@dataclasses.dataclass(frozen=True)
class Plan:
    sharding: ShardingPlan
    pcfg: ParallelConfig
    remat: bool
    grad_sync: str                    # auto | flat | hierarchical | compressed
    stage_bounds: Optional[Tuple[int, ...]] = None
    notes: Tuple[str, ...] = ()

    @property
    def pp_schedule(self) -> str:
        return self.pcfg.pp_schedule

    @property
    def n_micro(self) -> int:
        return max(self.pcfg.microbatches, 1)


def auto_plan(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig,
              pcfg: ParallelConfig = ParallelConfig(),
              embed_plans=None) -> Plan:
    """``embed_plans``: optional {top-level param key: EmbedPlan} routing
    embedding tables (the recsys CF factors) through the sparse-embedding
    subsystem's placement instead of the LM rules."""
    notes: List[str] = []
    training = shape.kind == "train"

    # --- remat: without it, scan-over-layers saves every inner intermediate
    # (attention chunk tensors, MLP hiddens) for backward — O(10-50x) the
    # residual stream.  Estimate the residual-stream floor; remat whenever
    # even a conservative 8x multiplier of it would pressure HBM.
    n_chips = mesh.size
    tokens = shape.global_batch * shape.seq_len
    act_bytes = tokens * cfg.d_model * 2 * cfg.num_layers / n_chips
    remat = training and 8 * act_bytes > 0.05 * HBM_BYTES_PER_CHIP
    if remat:
        notes.append(f"remat on (residual floor {act_bytes/1e9:.2f}GB/chip)")

    # --- sequence sharding: only when seq divides and is long enough -------
    tp = mesh.shape.get("model", 1)
    seq_shard = pcfg.seq_shard_activations and shape.seq_len % max(tp, 1) == 0 \
        and shape.seq_len >= 16 * max(tp, 1)

    # --- hybrid choice (paper C8): Megatron TP×DP vs dp_heavy (batch over
    # every axis + FSDP weight gathering).  Napkin per-step collective cost:
    #   megatron ≈ 5 activation reshards/layer x 3 passes
    #   dp_heavy ≈ weight bytes x (3 gathers + 1 grad reduce-scatter)
    dp_heavy = False
    dp_n = math.prod(mesh.shape[a] for a in mesh.axis_names if a != "model")
    if (training and not cfg.is_moe and tp > 1
            and shape.global_batch % mesh.size == 0):
        act_bytes = (shape.global_batch // dp_n) * shape.seq_len \
            * cfg.d_model * 2
        megatron_coll = 5 * act_bytes * 3 * cfg.num_layers
        weight_bytes = 2 * cfg.num_params()
        dp_heavy_coll = 4 * weight_bytes
        if dp_heavy_coll < megatron_coll:
            dp_heavy = True
            notes.append(
                f"dp_heavy plan (est coll {dp_heavy_coll/1e9:.0f}GB vs "
                f"megatron {megatron_coll/1e9:.0f}GB)")

    sharding = make_plan(mesh, pcfg, seq_shard=seq_shard, dp_heavy=dp_heavy,
                         embed_plans=embed_plans)
    if embed_plans:
        notes.append("embed tables via EmbedPlan: " + ", ".join(
            f"{k}={p.kind}" for k, p in sorted(embed_plans.items())))

    # --- gradient sync mode -------------------------------------------------
    grad_sync = pcfg.grad_sync
    if grad_sync == "auto":
        grad_sync = "hierarchical" if "pod" in mesh.axis_names else "auto"

    # --- pipeline partition (only when a stage axis exists) -----------------
    bounds = None
    if "stage" in mesh.axis_names:
        costs = [layer_flops(cfg, kind, i, shape.seq_len)
                 for i, kind in enumerate(cfg.layer_kinds())]
        bounds = tuple(load_balance.balance_stages(costs,
                                                   mesh.shape["stage"]))
        notes.append(f"stage bounds {bounds}")
        if mesh.shape["stage"] > 1:
            from repro.core.pipeline import schedule_cost
            bub = schedule_cost(pcfg.pp_schedule, mesh.shape["stage"],
                                max(pcfg.microbatches, 1))["bubble_frac"]
            notes.append(f"pp {pcfg.pp_schedule} x{pcfg.microbatches} "
                         f"bubble {bub:.2f}")

    return Plan(sharding=sharding, pcfg=pcfg, remat=remat,
                grad_sync=grad_sync, stage_bounds=bounds,
                notes=tuple(notes))


# ---------------------------------------------------------------------------
# Analytic DP x TP x PP step model (the ``train-parallel`` benchmark rows)
# ---------------------------------------------------------------------------

def modeled_parallel_step(cfg: ArchConfig, shape: ShapeConfig, *,
                          dp: int = 1, tp: int = 1, pp: int = 1,
                          n_micro: int = 8, schedule: str = "1f1b",
                          zero1: bool = True) -> Dict[str, float]:
    """TPU-scale roofline for one training step under a DP x TP x PP plan.

    Terms (per device, ring-collective byte model as in ``hlo_cost``):

    * compute — ``model_flops / (n_dev * peak)``;
    * DP — gradient all-reduce of this rank's parameter shard;
    * TP — Megatron activation psums: 2 branch reductions per layer forward
      and their backward conjugates (4 activation-sized all-reduces per
      layer-pass) over the device's ``L/pp`` layers, all micro-batches;
    * PP — boundary activation sends (fwd) + cotangent sends (bwd);
    * bubble — the schedule's idle fraction (``pipeline.schedule_cost``)
      stretches the busy span by ``1/(1-bubble)`` when pp > 1.

    Memory feasibility is part of the model (the paper's Table-2 baseline
    is an OOM): per-device bytes = params + grads + optimizer (ZeRO-1 over
    dp when ``zero1``) + residual activations; an infeasible plan reports
    ``throughput = 0`` with ``fits = False``.
    """
    from repro.core.pipeline import schedule_cost
    n_dev = dp * tp * pp
    N = cfg.num_params()
    flops = model_flops(cfg, shape.seq_len, shape.global_batch,
                        training=True)
    t_compute = flops / (n_dev * PEAK_FLOPS_BF16)

    ring = lambda k, b: 2 * b * (k - 1) / k if k > 1 else 0.0  # noqa: E731
    # DP: all-reduce this rank's grad shard (f32 master grads)
    t_dp = ring(dp, 4 * N / (tp * pp)) / ICI_BW_PER_LINK
    # TP: 4 act-sized all-reduces per layer (2 fwd + their 2 backward
    # conjugates) over the device's local layers
    L = cfg.num_layers
    act = (shape.global_batch // max(dp, 1)) * shape.seq_len * cfg.d_model * 2
    t_tp = ring(tp, 4 * (L / pp) * act) / ICI_BW_PER_LINK
    # PP: neighbour sends, activation fwd + cotangent bwd per micro-batch
    t_pp = (2 * act * 2 / ICI_BW_PER_LINK) if pp > 1 else 0.0
    t_coll = t_dp + t_tp + t_pp

    bubble = schedule_cost(schedule, pp, n_micro)["bubble_frac"] \
        if pp > 1 else 0.0
    t_busy = max(t_compute, t_coll)
    t_step = t_busy / max(1.0 - bubble, 1e-9)

    # memory feasibility from the resident *state*: weights bf16 + grads
    # f32 + adamw m/v/master f32 (ZeRO-1 over dp).  Activations are left
    # out — remat plus micro-batching keeps them subdominant — so this is
    # the floor no schedule can dodge: the paper's Table-2 baseline (and
    # any pure-DP carve of a 20B model) fails it.
    state = (2 + 4) * N / (tp * pp) + 12 * N / (tp * pp * (dp if zero1
                                                           else 1))
    fits = state < HBM_BYTES_PER_CHIP
    tput = shape.global_batch / t_step if fits else 0.0
    return {"dp": dp, "tp": tp, "pp": pp, "n_micro": n_micro,
            "schedule": schedule, "fits": bool(fits),
            "state_gb_per_dev": state / 1e9,
            "t_compute_ms": t_compute * 1e3, "t_dp_ms": t_dp * 1e3,
            "t_tp_ms": t_tp * 1e3, "t_pp_ms": t_pp * 1e3,
            "bubble_frac": bubble, "t_step_ms": t_step * 1e3,
            "modeled_throughput": tput}
