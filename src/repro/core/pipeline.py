"""Pipeline parallelism (paper C2): schedule-polymorphic micro-batched
pipelining over a ``stage`` mesh axis via shard_map + lax.ppermute.

Two schedules share one stage contract — ``stage_fn(params_slice, x) -> y``
with shape-uniform inter-stage activations:

* ``gpipe`` — the reference: full-forward / full-backward, backward falls
  out of autodiff through the tick scan (ppermute's transpose is the
  reverse permute).  Activation stash grows with ``n_micro`` (every
  in-flight micro-batch's boundary input is held until the backward
  phase); the published GPipe recovers O(1) activations by rematerializing
  each stage's internals in the backward — recompute the cost model below
  charges for.
* ``1f1b`` — PipeDream-flush: each stage interleaves one forward with one
  backward once warmed up, so at most ``n_stages - s`` micro-batches are
  ever in flight at stage ``s``.  The backward is *manual* (per-tick
  ``jax.vjp`` against a bounded input stash of depth ``n_stages`` instead
  of ``n_micro``) and is gradient-parity-tested against both ``gpipe``
  and the unpipelined model.

The tick schedules are built on the host (`schedule_tables`) as static
(T, n_stages) micro-index tables consumed by a ``lax.scan``; activations
move stage-to-stage through tagged ppermute messages landing in per-stage
ring inboxes whose no-overwrite property is implied by the 1F1B in-flight
bound (and re-checked by the builder).

Stage balancing (bubbles from uneven stages, §V.A) is handled upstream by
``load_balance.balance_stages`` / ``rebalance_stages``.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

SCHEDULES = ("gpipe", "1f1b")


def gpipe(stage_fn: Callable, mesh: Mesh, n_stages: int, n_micro: int,
          stage_axis: str = "stage"):
    """Build a pipelined apply: (stage_params, x_micro) -> y_micro.

    stage_fn(params_slice, x) -> y : one stage's computation, same x/y shape
    (inter-stage activations must be shape-uniform).
    stage_params: pytree with leading dim n_stages (sharded over the axis).
    x_micro: (n_micro, mb, ...) microbatched input, consumed by stage 0.
    Returns (n_micro, mb, ...) outputs produced by the last stage.
    """
    T = n_micro + n_stages - 1                      # GPipe ticks

    def inner(params, x_micro):
        # params leaves: (1, ...) local stage slice; x_micro: (n_micro, ...)
        p_local = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(stage_axis)
        buf0 = jnp.zeros_like(x_micro[0])
        ysink0 = jnp.zeros_like(x_micro)

        def tick(carry, t):
            buf, ysink = carry
            # stage 0 injects microbatch t (clipped index; masked later)
            x_in = jax.lax.dynamic_index_in_dim(
                x_micro, jnp.clip(t, 0, n_micro - 1), keepdims=False)
            inp = jnp.where(sid == 0, x_in, buf)
            y = stage_fn(p_local, inp)
            # last stage banks its output at micro index t-(n_stages-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            bank = (sid == n_stages - 1) & (t >= n_stages - 1)
            ysink = jax.lax.cond(
                bank,
                lambda s: jax.lax.dynamic_update_index_in_dim(
                    s, y, out_idx, axis=0),
                lambda s: s, ysink)
            # send activations downstream (wraps around; wrap is ignored)
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, ysink), None

        (_, ysink), _ = jax.lax.scan(tick, (buf0, ysink0), jnp.arange(T))
        # every stage holds a ysink; only the last stage's is real.
        ysink = jax.lax.psum(
            jnp.where(sid == n_stages - 1, ysink, jnp.zeros_like(ysink)),
            stage_axis)
        return ysink

    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False)


def make_pipeline_loss(stage_fn: Callable, last_fn: Callable, mesh: Mesh,
                       n_stages: int, n_micro: int,
                       stage_axis: str = "stage"):
    """Pipelined loss: stages 0..S-1 run stage_fn; ``last_fn(y, target)``
    maps final activations to per-microbatch scalar loss (e.g. logits + CE).

    Returns loss_fn(stage_params, last_params, x_micro, tgt_micro) -> scalar.
    Differentiable end-to-end (GPipe backward via autodiff).
    """
    pipe = gpipe(stage_fn, mesh, n_stages, n_micro, stage_axis)

    def loss(stage_params, last_params, x_micro, tgt_micro):
        y = pipe(stage_params, x_micro)             # (n_micro, mb, ...)
        per = jax.vmap(lambda yy, tt: last_fn(last_params, yy, tt))(
            y, tgt_micro)
        return jnp.mean(per)

    return loss


def microbatch(x: jnp.ndarray, n_micro: int, pad: bool = False
               ) -> jnp.ndarray:
    """(B, ...) -> (n_micro, ceil(B/n_micro), ...).

    ``pad=True`` right-pads a remainder batch with zero rows (callers mask
    the pad rows out of the loss — see ``pad_batch``); otherwise B must
    divide evenly.
    """
    B = x.shape[0]
    if B % n_micro:
        if not pad:
            raise ValueError(
                f"batch {B} does not divide into {n_micro} micro-batches; "
                f"pass pad=True (and mask the pad rows) or pick a divisor")
        x = pad_batch(x, n_micro)
        B = x.shape[0]
    return x.reshape((n_micro, B // n_micro) + x.shape[1:])


def pad_batch(x: jnp.ndarray, n_micro: int) -> jnp.ndarray:
    """Zero-pad dim 0 up to the next multiple of ``n_micro``."""
    B = x.shape[0]
    r = (-B) % n_micro
    if r == 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((r,) + x.shape[1:], x.dtype)], axis=0)


# ---------------------------------------------------------------------------
# 1F1B (PipeDream-flush) schedule
# ---------------------------------------------------------------------------

def schedule_tables(schedule: str, n_stages: int, n_micro: int
                    ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Host-built tick tables for either schedule.

    Returns (fwd, bwd, depth): fwd/bwd are (T, n_stages) int32 — the
    micro-batch index the stage's forward/backward unit processes that
    tick (-1 = idle) — and ``depth`` is the activation-stash ring size the
    schedule needs (``n_stages`` for 1F1B, ``n_micro`` for GPipe: the
    memory difference that motivates 1F1B).

    One compute unit per stage per tick.  Under ``1f1b`` a stage prefers a
    ready backward (the PipeDream-flush rule) and may only start forward
    ``m`` while fewer than ``n_stages - s`` micro-batches are in flight;
    under ``gpipe`` forwards run unthrottled and backwards drain after.
    """
    S, M = n_stages, n_micro
    one_f_one_b = schedule == "1f1b"
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r} (have {SCHEDULES})")
    f_t = np.full((S, M), -1, np.int64)
    b_t = np.full((S, M), -1, np.int64)
    nf = [0] * S
    nb = [0] * S
    t = 0
    while min(nb) < M:
        for s in range(S):
            m = nb[s]
            can_b = (m < M and 0 <= f_t[s, m] < t
                     and (s == S - 1 or 0 <= b_t[s + 1, m] < t))
            mf = nf[s]
            cap = (S - s) if one_f_one_b else M
            can_f = (mf < M
                     and (s == 0 or 0 <= f_t[s - 1, mf] < t)
                     and nf[s] - nb[s] < cap)
            if can_b and (one_f_one_b or not can_f):
                b_t[s, m] = t
                nb[s] += 1
            elif can_f:
                f_t[s, mf] = t
                nf[s] += 1
        t += 1
        if t > 4 * (M + S) + 8:
            raise RuntimeError(
                f"{schedule} schedule did not converge ({S=}, {M=})")
    T = t
    fwd = np.full((T, S), -1, np.int32)
    bwd = np.full((T, S), -1, np.int32)
    for s in range(S):
        for m in range(M):
            fwd[f_t[s, m], s] = m
            bwd[b_t[s, m], s] = m
    depth = min(S, M) if one_f_one_b else M
    _validate_schedule(f_t, b_t, S, M, depth)
    return fwd, bwd, depth


def _validate_schedule(f_t: np.ndarray, b_t: np.ndarray, S: int, M: int,
                       D: int) -> None:
    """No-overwrite invariants for the depth-D ring buffers.

    Slot ``m % D`` of each per-stage buffer must not be rewritten by micro
    ``m + D`` before micro ``m`` is consumed.  These follow from the
    schedule's in-flight bound; re-checked here (as real raises, immune to
    ``python -O``) so a schedule bug fails loudly at build time instead of
    as silent gradient corruption.
    """

    def need(ok, what, s, m):
        if not ok:
            raise ValueError(
                f"invalid schedule: {what} violated at stage {s}, "
                f"micro {m} (S={S}, M={M}, depth={D})")

    for s in range(S):
        for m in range(M - D):
            # input stash: fwd m+D writes the slot bwd m reads
            need(f_t[s, m + D] > b_t[s, m], "stash reuse", s, m)
            if s >= 1:      # fwd inbox: arrival of m+D vs consumption of m
                need(f_t[s - 1, m + D] + 1 > f_t[s, m], "fwd inbox", s, m)
            if s <= S - 2:  # bwd inbox
                need(b_t[s + 1, m + D] + 1 > b_t[s, m], "bwd inbox", s, m)
    # dependency sanity
    for s in range(S):
        for m in range(M):
            need(b_t[s, m] > f_t[s, m] >= 0, "fwd-before-bwd", s, m)
            if s >= 1:
                need(f_t[s, m] > f_t[s - 1, m], "fwd dependency", s, m)
            if s <= S - 2:
                need(b_t[s, m] > b_t[s + 1, m], "bwd dependency", s, m)


def schedule_cost(schedule: str, n_stages: int, n_micro: int,
                  t_fwd: float = 1.0, t_bwd: float = 2.0) -> Dict[str, float]:
    """Per-step schedule cost model (the bubble column of the
    ``train-parallel`` benchmark).

    This prices the schedules as a TPU deployment would run them:
    ``gpipe`` runs a full forward phase then a full backward phase;
    holding every micro-batch's activations to avoid recompute would cost
    O(n_micro) stash, so the published schedule rematerializes each
    stage's forward inside the backward phase — the backward tick costs
    ``t_fwd + t_bwd``.  ``1f1b`` keeps at most ``n_stages`` boundary
    inputs stashed and need not recompute: every tick costs its nominal
    unit.  Bubble fraction is 1 - useful/span; 1F1B's is strictly below
    GPipe's for n_stages > 1.

    Note the HOST-SIMULATION executor (:func:`make_pipeline_vag_body`)
    recomputes the stage forward inside ``jax.vjp`` on every backward
    tick under BOTH schedules (and computes masked idle ticks), so
    measured host step times will NOT show this model's gpipe-vs-1f1b
    compute gap — on the simulator the schedules differ in stash depth
    and tick count only.  The benchmark's measured and modeled columns
    are therefore reported (and gated) separately.
    """
    S, M = n_stages, n_micro
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r} (have {SCHEDULES})")
    useful = M * (t_fwd + t_bwd)
    if schedule == "gpipe":
        span = (M + S - 1) * t_fwd + (M + S - 1) * (t_fwd + t_bwd)
        stash = M
    else:
        span = (M + S - 1) * (t_fwd + t_bwd)
        stash = min(S, M)
    return {"schedule": schedule, "n_stages": S, "n_micro": M,
            "span": span, "useful": useful,
            "bubble_frac": 1.0 - useful / span,
            "stash_micros": stash}


# ---------------------------------------------------------------------------
# Pipelined value-and-grad (both schedules, one signature)
# ---------------------------------------------------------------------------
#
# Contract shared by gpipe and 1f1b:
#   stage_fn(stage_params_slice, x) -> y           shape-uniform activations
#   last_fn(last_params, y, tgt, mask) -> loss_sum masked NLL *sum* (the
#       pipeline divides by the global mask weight, so remainder-padded
#       micro-batches weight correctly)
#   vag(stage_params, last_params, x_micro, tgt_micro, mask_micro)
#     -> (loss, (g_stage, g_last, g_x))
# with g_x the cotangent of x_micro — the hook the trainer uses to reach
# the (replicated) token-embedding parameters that produced x.


def make_pipeline_value_and_grad(stage_fn: Callable, last_fn: Callable,
                                 mesh: Mesh, n_stages: int, n_micro: int,
                                 schedule: str = "1f1b",
                                 stage_axis: str = "stage"):
    """Standalone shard_map wrapper over the manual schedule executor
    (:func:`make_pipeline_vag_body`) — both schedules, one signature."""
    body = make_pipeline_vag_body(stage_fn, last_fn, n_stages, n_micro,
                                  schedule, stage_axis)
    sm = shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P(), P(), P(), P()),
        out_specs=(P(), P(stage_axis), P(), P()),
        check_rep=False)

    def vag(stage_params, last_params, x_micro, tgt_micro, mask_micro):
        loss, g_stage, g_last, g_x = sm(stage_params, last_params, x_micro,
                                        tgt_micro, mask_micro)
        return loss, (g_stage, g_last, g_x)

    return vag


def gpipe_value_and_grad(stage_fn, last_fn, mesh, n_stages, n_micro,
                         stage_axis: str = "stage"):
    """Autodiff reference: value-and-grad straight through the gpipe tick
    scan (ppermute transposes handled by jax).  Same signature as
    :func:`make_pipeline_value_and_grad` — the parity oracle the manual
    schedule executor is tested against."""
    pipe = gpipe(stage_fn, mesh, n_stages, n_micro, stage_axis)

    def loss(stage_params, last_params, x_micro, tgt_micro, mask_micro):
        y = pipe(stage_params, x_micro)             # (n_micro, mb, ...)
        sums = jax.vmap(
            lambda yy, tt, mm: last_fn(last_params, yy, tt, mm))(
            y, tgt_micro, mask_micro)
        W = jnp.maximum(jnp.sum(mask_micro), 1.0)
        return jnp.sum(sums) / W

    return jax.value_and_grad(loss, argnums=(0, 1, 2))


def make_pipeline_vag_body(stage_fn: Callable, last_fn: Callable,
                           n_stages: int, n_micro: int,
                           schedule: str = "1f1b",
                           stage_axis: str = "stage"):
    """Per-device pipelined value-and-grad body — the manual schedule
    executor, built for embedding inside a larger shard_map (the trainer's
    DP x TP x stage step maps it over ``stage`` alongside its data/model
    axes; :func:`make_pipeline_value_and_grad` wraps it standalone).

    The tick scan walks the host-built :func:`schedule_tables`; each tick a
    stage runs at most one forward (stashing its boundary input in a
    depth-``depth`` ring — ``n_stages`` under 1F1B, ``n_micro`` under
    GPipe) and one ready backward (``jax.vjp`` against the stashed input;
    the last stage's backward folds ``last_fn`` in and seeds itself,
    emitting the per-micro loss as a side product).  Cotangents flow
    upstage through the reverse ppermute.

    body(stage_params, last_params, x_micro, tgt_micro, mask_micro) ->
    (loss, g_stage, g_last, g_x); stage_params leaves carry a leading
    local dim of 1 (the stage shard); loss/g_last/g_x return replicated
    (psum over the stage axis), g_stage local.
    """
    S, M = n_stages, n_micro
    fwd_np, bwd_np, depth = schedule_tables(schedule, S, M)
    down = [(i, (i + 1) % S) for i in range(S)]
    up = [(i, (i - 1) % S) for i in range(S)]

    def inner(stage_params, last_params, x_micro, tgt_micro, mask_micro):
        p_local = jax.tree.map(lambda a: a[0], stage_params)
        sid = jax.lax.axis_index(stage_axis)
        is_last = sid == S - 1
        W = jnp.maximum(jnp.sum(mask_micro), 1.0)
        act0 = jnp.zeros((depth,) + x_micro.shape[1:], x_micro.dtype)
        f32 = lambda t: jax.tree.map(  # noqa: E731
            lambda a: jnp.zeros(a.shape, jnp.float32), t)

        carry0 = {
            "inbox_f": act0, "inbox_b": act0, "stash": act0,
            "g_stage": f32(p_local), "g_last": f32(last_params),
            "g_x": jnp.zeros(x_micro.shape, jnp.float32),
            "loss": jnp.zeros((), jnp.float32),
        }

        def tick(carry, sched):
            fm_row, bm_row = sched
            m_f = fm_row[sid]
            m_b = bm_row[sid]
            # ---- forward unit ------------------------------------------
            act_f = m_f >= 0
            mf = jnp.clip(m_f, 0, M - 1)
            x_in = jnp.where(sid == 0, x_micro[mf],
                             carry["inbox_f"][mf % depth])
            y = stage_fn(p_local, x_in)
            stash = jnp.where(
                act_f, carry["stash"].at[mf % depth].set(x_in),
                carry["stash"])
            send_f = jnp.where(act_f & ~is_last, y, jnp.zeros_like(y))
            tag_f = jnp.where(act_f & ~is_last, m_f, -1)
            # ---- backward unit -----------------------------------------
            act_b = m_b >= 0
            mb = jnp.clip(m_b, 0, M - 1)
            x_s = stash[mb % depth]

            def last_branch(_):
                def f(p, lp, x):
                    return last_fn(lp, stage_fn(p, x), tgt_micro[mb],
                                   mask_micro[mb])
                ls, vjp = jax.vjp(f, p_local, last_params, x_s)
                gp, glp, gx = vjp(jnp.ones((), ls.dtype))
                return ls.astype(jnp.float32), gp, glp, gx

            def mid_branch(_):
                ct = carry["inbox_b"][mb % depth].astype(x_s.dtype)
                _, vjp = jax.vjp(stage_fn, p_local, x_s)
                gp, gx = vjp(ct)
                return jnp.zeros((), jnp.float32), gp, \
                    jax.tree.map(jnp.zeros_like, last_params), gx

            ls, gp, glp, gx = jax.lax.cond(is_last, last_branch, mid_branch,
                                           None)
            acc = lambda a, g: a + jnp.where(  # noqa: E731
                act_b, g.astype(jnp.float32) / W, 0.0)
            g_stage = jax.tree.map(acc, carry["g_stage"], gp)
            g_last = jax.tree.map(acc, carry["g_last"], glp)
            g_x = jnp.where(
                act_b & (sid == 0),
                carry["g_x"].at[mb].set(gx.astype(jnp.float32) / W),
                carry["g_x"])
            loss = carry["loss"] + jnp.where(act_b & is_last, ls, 0.0)
            send_b = jnp.where(act_b & (sid > 0), gx,
                               jnp.zeros_like(x_s)).astype(x_micro.dtype)
            tag_b = jnp.where(act_b & (sid > 0), m_b, -1)
            # ---- message passing (unconditional collectives) ----------
            recv_y, recv_tf = jax.lax.ppermute((send_f, tag_f), stage_axis,
                                               down)
            recv_ct, recv_tb = jax.lax.ppermute((send_b, tag_b), stage_axis,
                                                up)
            inbox_f = jnp.where(
                recv_tf >= 0,
                carry["inbox_f"].at[jnp.clip(recv_tf, 0) % depth].set(recv_y),
                carry["inbox_f"])
            inbox_b = jnp.where(
                recv_tb >= 0,
                carry["inbox_b"].at[jnp.clip(recv_tb, 0) % depth].set(
                    recv_ct),
                carry["inbox_b"])
            return {"inbox_f": inbox_f, "inbox_b": inbox_b, "stash": stash,
                    "g_stage": g_stage, "g_last": g_last, "g_x": g_x,
                    "loss": loss}, None

        carry, _ = jax.lax.scan(
            tick, carry0, (jnp.asarray(fwd_np), jnp.asarray(bwd_np)))

        # the loss / last-params grads / input cotangents live on one stage
        # each — psum replicates them (zeros elsewhere)
        loss = jax.lax.psum(
            jnp.where(is_last, carry["loss"], 0.0), stage_axis) / W
        g_last = jax.tree.map(
            lambda g: jax.lax.psum(jnp.where(is_last, g, 0.0), stage_axis),
            carry["g_last"])
        g_x = jax.lax.psum(
            jnp.where(sid == 0, carry["g_x"], 0.0), stage_axis)
        g_stage = jax.tree.map(lambda g: g[None], carry["g_stage"])
        return loss, g_stage, g_last, g_x

    return inner
