"""Pipeline parallelism (paper C2): GPipe schedule over a ``stage`` mesh axis
via shard_map + lax.ppermute + lax.scan over ticks.

TPU-native mapping of the paper's PP: stage-to-stage activation transfer is
``collective_permute`` (the ICI neighbour send), micro-batches overlap
compute with those sends, and the backward schedule falls out of autodiff
through the scan (ppermute's transpose is the reverse permute), i.e. a
GPipe-style full-forward / full-backward with activation stashing.

Stage balancing (bubbles from uneven stages, §V.A) is handled upstream by
``load_balance.balance_stages``.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe(stage_fn: Callable, mesh: Mesh, n_stages: int, n_micro: int,
          stage_axis: str = "stage"):
    """Build a pipelined apply: (stage_params, x_micro) -> y_micro.

    stage_fn(params_slice, x) -> y : one stage's computation, same x/y shape
    (inter-stage activations must be shape-uniform).
    stage_params: pytree with leading dim n_stages (sharded over the axis).
    x_micro: (n_micro, mb, ...) microbatched input, consumed by stage 0.
    Returns (n_micro, mb, ...) outputs produced by the last stage.
    """
    T = n_micro + n_stages - 1                      # GPipe ticks

    def inner(params, x_micro):
        # params leaves: (1, ...) local stage slice; x_micro: (n_micro, ...)
        p_local = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(stage_axis)
        buf0 = jnp.zeros_like(x_micro[0])
        ysink0 = jnp.zeros_like(x_micro)

        def tick(carry, t):
            buf, ysink = carry
            # stage 0 injects microbatch t (clipped index; masked later)
            x_in = jax.lax.dynamic_index_in_dim(
                x_micro, jnp.clip(t, 0, n_micro - 1), keepdims=False)
            inp = jnp.where(sid == 0, x_in, buf)
            y = stage_fn(p_local, inp)
            # last stage banks its output at micro index t-(n_stages-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            bank = (sid == n_stages - 1) & (t >= n_stages - 1)
            ysink = jax.lax.cond(
                bank,
                lambda s: jax.lax.dynamic_update_index_in_dim(
                    s, y, out_idx, axis=0),
                lambda s: s, ysink)
            # send activations downstream (wraps around; wrap is ignored)
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, ysink), None

        (_, ysink), _ = jax.lax.scan(tick, (buf0, ysink0), jnp.arange(T))
        # every stage holds a ysink; only the last stage's is real.
        ysink = jax.lax.psum(
            jnp.where(sid == n_stages - 1, ysink, jnp.zeros_like(ysink)),
            stage_axis)
        return ysink

    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False)


def make_pipeline_loss(stage_fn: Callable, last_fn: Callable, mesh: Mesh,
                       n_stages: int, n_micro: int,
                       stage_axis: str = "stage"):
    """Pipelined loss: stages 0..S-1 run stage_fn; ``last_fn(y, target)``
    maps final activations to per-microbatch scalar loss (e.g. logits + CE).

    Returns loss_fn(stage_params, last_params, x_micro, tgt_micro) -> scalar.
    Differentiable end-to-end (GPipe backward via autodiff).
    """
    pipe = gpipe(stage_fn, mesh, n_stages, n_micro, stage_axis)

    def loss(stage_params, last_params, x_micro, tgt_micro):
        y = pipe(stage_params, x_micro)             # (n_micro, mb, ...)
        per = jax.vmap(lambda yy, tt: last_fn(last_params, yy, tt))(
            y, tgt_micro)
        return jnp.mean(per)

    return loss


def microbatch(x: jnp.ndarray, n_micro: int) -> jnp.ndarray:
    """(B, ...) -> (n_micro, B/n_micro, ...)."""
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    return x.reshape((n_micro, B // n_micro) + x.shape[1:])
