"""Hierarchical All-Reduce (paper C5, §III.B) via shard_map.

The paper's rack hierarchy maps to TPU pod locality: gradients are
reduce-scattered over the fast intra-pod ``data`` axis, all-reduced over the
slow cross-pod ``pod`` axis on the 1/P-sized shard, then all-gathered back
intra-pod.  Versus a flat all-reduce over (pod x data), the cross-pod link —
the bandwidth bottleneck — carries 1/16th of the bytes.

These functions run *inside* ``shard_map`` over the dp axes (the DP-pure
training path, mirroring the paper's 8-GPU setup), or standalone through
``dp_gradient_sync`` which wraps a gradient pytree.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat


def _pad_to(x: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, pad


def flat_allreduce_mean(g: jnp.ndarray, axes) -> jnp.ndarray:
    """Baseline: single all-reduce over all dp axes (ring over the flat
    communicator — the paper's 'synchronous DP' Eq. 8)."""
    return jax.lax.pmean(g, axes)


def hierarchical_allreduce_mean(g: jnp.ndarray, intra_axis: str = "data",
                                inter_axis: Optional[str] = "pod"):
    """reduce-scatter(intra) -> all-reduce(inter) -> all-gather(intra)."""
    shape = g.shape
    flat = g.reshape(-1)
    n_intra = compat.axis_size(intra_axis)
    flat, pad = _pad_to(flat, n_intra)
    shard = jax.lax.psum_scatter(flat, intra_axis, scatter_dimension=0,
                                 tiled=True)
    total = n_intra
    if inter_axis is not None:
        shard = jax.lax.psum(shard, inter_axis)
        total *= compat.axis_size(inter_axis)
    out = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)
    if pad:
        out = out[:-pad]
    return out.reshape(shape) / total


def make_sync_fn(mode: str, intra_axis: str = "data",
                 inter_axis: Optional[str] = None):
    """Leaf-wise gradient synchronizer for use *inside* a shard_map'd train
    step.  mode: 'flat' (Eq. 8) | 'hierarchical' (C5)."""
    axes = (intra_axis,) + ((inter_axis,) if inter_axis else ())

    def sync(g):
        if mode == "flat":
            return flat_allreduce_mean(g, axes)
        if mode == "hierarchical":
            return hierarchical_allreduce_mean(g, intra_axis, inter_axis)
        raise ValueError(mode)

    return lambda grads: jax.tree.map(sync, grads)
