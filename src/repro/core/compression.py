"""Compressed gradient synchronization with error feedback (paper C6,
Eq. 10–11), running inside a shard_map'd DP train step.

* 1-bit (EF-signSGD): each rank packs sign bits 8-per-uint8 with per-block L1
  scales (Pallas kernel), all-gathers the uint8 payload + scales over the dp
  axis (wire bytes = N/8 + 4N/block vs 4N for fp32), locally dequantizes and
  averages.  The quantization error accumulates into a per-rank residual
  (error feedback) that is added to the next step's gradient — Eq. 11.
* top-k: each rank keeps the per-block top-k magnitudes, all-gathers (values,
  indices) = 8k bytes per block of ``block`` elements, scatter-adds locally.

Both return (synced_mean_gradient, new_residual).  Residuals are per-rank
state stored in the optimizer state with a leading dp-sharded device dim.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, (treedef, shapes, sizes)


def _unflatten(flat, meta):
    treedef, shapes, sizes = meta
    out, off = [], 0
    for shape, size in zip(shapes, sizes):
        out.append(flat[off:off + size].reshape(shape))
        off += size
    return jax.tree.unflatten(treedef, out)


def flat_size(tree, mult: int) -> int:
    n = sum(l.size for l in jax.tree.leaves(tree))
    return n + ((-n) % mult)


def onebit_sync(grads, residual: jnp.ndarray, *, axis: str = "data",
                block: int = 512, use_kernel: bool = True
                ) -> Tuple[object, jnp.ndarray]:
    """EF-signSGD sync inside shard_map.  residual: flat (N_pad,) f32."""
    flat, meta = _flatten(grads)
    npad = residual.shape[0] - flat.shape[0]
    flat = jnp.pad(flat, (0, npad)) + residual
    impl = "kernel" if use_kernel else "ref"
    packed, scales = ops.onebit_quantize(flat, block, impl=impl)
    local_hat = ops.onebit_dequantize(packed, scales, block, impl=impl)
    new_residual = flat - local_hat
    # exchange compressed payloads (uint8 + per-block scales on the wire)
    packed_all = jax.lax.all_gather(packed, axis)            # (P, N/8) u8
    scales_all = jax.lax.all_gather(scales, axis)            # (P, nb) f32
    deq = jax.vmap(lambda pk, sc: ops.onebit_dequantize(pk, sc, block,
                                                        impl=impl))
    g_hat = jnp.mean(deq(packed_all, scales_all), axis=0)
    n = flat.shape[0] - npad
    return _unflatten(g_hat[:n], meta), new_residual


def topk_sync(grads, residual: jnp.ndarray, *, axis: str = "data",
              block: int = 2048, k: int = 32, use_kernel: bool = True
              ) -> Tuple[object, jnp.ndarray]:
    """Top-k sparsified sync (Eq. 11) inside shard_map."""
    flat, meta = _flatten(grads)
    npad = residual.shape[0] - flat.shape[0]
    flat = jnp.pad(flat, (0, npad)) + residual
    impl = "kernel" if use_kernel else "ref"
    kept, _ = ops.topk_sparsify(flat, k, block, impl=impl)
    # extract exactly-k (values, indices) per block -> the wire payload
    # (ties beyond k fall back into the residual: error feedback keeps them)
    nb = flat.shape[0] // block
    kept2d = kept.reshape(nb, block)
    _, idx = jax.lax.top_k(jnp.abs(kept2d), k)               # (nb, k)
    vals = jnp.take_along_axis(kept2d, idx, axis=-1)         # signed values

    def scatter(v, i):
        return jnp.zeros((nb, block), jnp.float32) \
            .at[jnp.arange(nb)[:, None], i].add(v)

    new_residual = flat - scatter(vals, idx).reshape(-1)
    vals_all = jax.lax.all_gather(vals, axis)                # (P, nb, k)
    idx_all = jax.lax.all_gather(idx, axis)
    g_hat = jnp.mean(jax.vmap(scatter)(vals_all, idx_all), axis=0).reshape(-1)
    n = flat.shape[0] - npad
    return _unflatten(g_hat[:n], meta), new_residual


def make_compressed_sync(mode: str, *, axis: str = "data", block: int = 512,
                         k: int = 32, use_kernel: bool = True):
    """Returns sync(grads, residual) -> (mean_grads, new_residual)."""
    if mode == "onebit":
        return partial(onebit_sync, axis=axis, block=block,
                       use_kernel=use_kernel)
    if mode == "topk":
        return partial(topk_sync, axis=axis, block=block, k=k,
                       use_kernel=use_kernel)
    raise ValueError(mode)
