"""One explicit KV-cache layout spec shared by kernels, models, serving, launch.

Before this module the decode-cache configuration was smeared across stacked
dispatch sites: ``make_backend(kv=..., decode_impl=...)``, int8 booleans in
the backends, per-class layout assumptions (ring vs linear window), and four
separate kernel entry points.  :class:`CacheLayout` collapses all of that
into one frozen, hashable value that

* :func:`repro.kernels.ops.decode_attention` keys its dispatch (and the
  :mod:`repro.kernels.ref` oracles) off,
* :mod:`repro.serving.engine` uses to pick a slot backend and (for
  ``kind="paged"``) to size the shared block pool, and
* ``launch/serve.py`` builds from CLI flags.

Fields:

``kind``
    ``"dense"`` — per-slot padded rows ``(n_slots, S_max, ...)`` (the
    classical layout); ``"paged"`` — a shared block pool
    ``(num_blocks, block_size, ...)`` plus per-slot block tables, so
    resident KV is bounded by *live tokens* instead of padded capacity.
``kv_bits``
    16 (model dtype) or 8 (int8 values + per-(position, head) f32 scales).
``impl``
    decode-attention implementation: ``"dense"`` (XLA einsum over the
    padded / gathered cache), ``"flash"`` (Pallas flash-decode kernel,
    length-aware block skipping; block-table indexed when paged), or
    ``"ref"`` (pure-jnp oracle).
``block_size``
    paged only: tokens per pool block (also the paged kernel's KV tile).
``num_blocks``
    paged only: pool capacity in blocks; 0 = auto
    (:func:`resolved_num_blocks` — dense-equivalent capacity plus the
    reserved null block).
``prefix_sharing``
    paged only: hash-index full prompt blocks so identical live prefixes
    share physical blocks (copy-on-write on first divergent write).
``window`` / ``ring``
    kernel-level masking variant of *one attention call*: sliding-window
    band over a linear cache, or gemma's wraparound ring buffer.  Engine
    level layouts keep the defaults; per-layer call sites
    ``dataclasses.replace`` them in.
``block_k``
    flash-decode KV tile for the dense layout (paged tiles are
    ``block_size``).
"""
from __future__ import annotations

import dataclasses

__all__ = ["CacheLayout", "resolved_num_blocks", "blocks_per_slot"]


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    kind: str = "dense"          # dense | paged
    kv_bits: int = 16            # 16 | 8
    impl: str = "dense"          # ref | dense | flash
    block_size: int = 16         # paged: tokens per pool block
    num_blocks: int = 0          # paged: pool capacity (0 = auto)
    prefix_sharing: bool = True  # paged: hash-share full prompt blocks
    window: int = 0              # sliding-window band (one attention call)
    ring: bool = False           # ring-buffer window layout
    block_k: int = 128           # flash-decode KV tile (dense layout)

    def __post_init__(self):
        if self.kind not in ("dense", "paged"):
            raise ValueError(f"kind {self.kind!r} (want dense|paged)")
        if self.kv_bits not in (8, 16):
            raise ValueError(f"kv_bits {self.kv_bits!r} (want 8|16)")
        if self.impl not in ("ref", "dense", "flash"):
            raise ValueError(f"impl {self.impl!r} (want ref|dense|flash)")
        if self.block_size <= 0:
            raise ValueError(f"block_size must be positive: {self.block_size}")
        if self.ring and self.window <= 0:
            raise ValueError("ring=True needs window > 0")

    @property
    def paged(self) -> bool:
        return self.kind == "paged"

    @property
    def quantized(self) -> bool:
        return self.kv_bits == 8

    def replace(self, **kw) -> "CacheLayout":
        return dataclasses.replace(self, **kw)


def blocks_per_slot(layout: CacheLayout, max_len: int) -> int:
    """Block-table width: virtual blocks covering one slot's serving window.

    ``max_len`` must be a multiple of ``block_size`` so dense and paged
    states describe the same position space (validated here, once, for
    every consumer)."""
    if max_len % layout.block_size:
        raise ValueError(
            f"max_len={max_len} must be a multiple of "
            f"block_size={layout.block_size} for the paged layout")
    return max_len // layout.block_size


def resolved_num_blocks(layout: CacheLayout, n_slots: int,
                        max_len: int) -> int:
    """Pool capacity in blocks: ``layout.num_blocks``, or (when 0) the
    dense-equivalent capacity ``n_slots * max_len / block_size``.  Either
    way one extra block is included: block 0 is the reserved *null sink*
    (never allocated; dead table entries point at it)."""
    nb = blocks_per_slot(layout, max_len)
    cap = layout.num_blocks if layout.num_blocks > 0 else n_slots * nb
    return cap + 1
