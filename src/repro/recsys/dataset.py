"""Synthetic Amazon-Electronics-like recommendation dataset (paper Table 1).

The real dataset is not available offline; this generator reproduces its
*statistics* at a configurable scale: 192,403 users, 63,001 items, ~2M
interactions, zipf item popularity, log-normal user activity, and a
chronological 80/10/10 split.  Sequences are per-user item histories for
next-item prediction (the standard LLM-recsys formulation, Fig. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np

FULL_USERS = 192_403
FULL_ITEMS = 63_001
FULL_INTERACTIONS = 1_735_654 + 216_957 + 216_956   # paper Table 1


@dataclasses.dataclass(frozen=True)
class RecDataset:
    n_users: int
    n_items: int
    # (N,) arrays sorted chronologically
    user: np.ndarray
    item: np.ndarray
    time: np.ndarray
    split: Tuple[int, int]          # train/val boundary indices

    @property
    def train(self):
        return self.user[:self.split[0]], self.item[:self.split[0]]

    @property
    def val(self):
        s = slice(self.split[0], self.split[1])
        return self.user[s], self.item[s]

    @property
    def test(self):
        return self.user[self.split[1]:], self.item[self.split[1]:]


def generate(scale: float = 0.02, seed: int = 0) -> RecDataset:
    """scale=1.0 reproduces the full Table 1 sizes."""
    rng = np.random.default_rng(seed)
    n_users = max(32, int(FULL_USERS * scale))
    n_items = max(64, int(FULL_ITEMS * scale))
    n_inter = max(1024, int(FULL_INTERACTIONS * scale))

    # item popularity: zipf; user activity: log-normal
    item_pop = 1.0 / np.arange(1, n_items + 1) ** 1.1
    item_pop /= item_pop.sum()
    user_act = rng.lognormal(0.0, 1.0, n_users)
    user_act /= user_act.sum()

    users = rng.choice(n_users, n_inter, p=user_act)
    # per-user taste cluster: users prefer a popularity-biased item window
    centers = rng.integers(0, n_items, n_users)
    window = max(16, n_items // 20)
    base_items = rng.choice(n_items, n_inter, p=item_pop)
    offset = rng.integers(-window, window + 1, n_inter)
    clustered = (centers[users] + offset) % n_items
    use_cluster = rng.random(n_inter) < 0.6
    items = np.where(use_cluster, clustered, base_items).astype(np.int64)

    times = np.sort(rng.integers(0, 2 ** 31, n_inter))
    order = np.arange(n_inter)                   # already time-sorted
    b1 = int(n_inter * 0.8)
    b2 = int(n_inter * 0.9)
    return RecDataset(n_users=n_users, n_items=n_items,
                      user=users[order], item=items[order],
                      time=times[order], split=(b1, b2))


def user_histories(ds: RecDataset, part: str = "train") -> Dict[int, np.ndarray]:
    u, i = getattr(ds, part)
    hist: Dict[int, list] = {}
    for uu, ii in zip(u, i):
        hist.setdefault(int(uu), []).append(int(ii))
    return {k: np.asarray(v, np.int64) for k, v in hist.items()}


def seq_batches(ds: RecDataset, batch: int, seq_len: int, steps: int,
                seed: int = 0, part: str = "train",
                item_offset: int = 3) -> Iterator[Dict[str, np.ndarray]]:
    """Next-item prediction batches.  Token ids = item id + offset
    (0=pad, 1=bos, 2=mask reserved).  targets[t] = tokens[t+1]."""
    hist = user_histories(ds, part)
    users = [u for u, h in hist.items() if len(h) >= 3]
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        tok = np.zeros((batch, seq_len + 1), np.int64)
        for b in range(batch):
            h = hist[users[rng.integers(len(users))]]
            take = h[-(seq_len):] if len(h) >= seq_len else h
            tok[b, 0] = 1                               # bos
            tok[b, 1:1 + len(take)] = take + item_offset
        yield {"tokens": tok[:, :-1].astype(np.int32),
               "targets": tok[:, 1:].astype(np.int32),
               "mask": (tok[:, 1:] > 0).astype(np.float32),
               "user": np.zeros((batch,), np.int32)}


def eval_examples(ds: RecDataset, seq_len: int, max_users: int = 512,
                  item_offset: int = 3, part: str = "test"):
    """Leave-one-out eval: history (from train) -> held-out item (from part).

    Returns (tokens (U, seq), gold (U,)) for HR@K / NDCG@K ranking."""
    train_hist = user_histories(ds, "train")
    u_eval, i_eval = getattr(ds, part)
    seen = set()
    toks, gold, lens = [], [], []
    for uu, ii in zip(u_eval, i_eval):
        uu = int(uu)
        if uu in seen or uu not in train_hist:
            continue
        seen.add(uu)
        h = train_hist[uu][-(seq_len - 1):]
        row = np.zeros(seq_len, np.int64)
        row[0] = 1
        row[1:1 + len(h)] = h + item_offset
        toks.append(row)
        gold.append(int(ii) + item_offset)
        lens.append(len(h))                     # last filled position
        if len(toks) >= max_users:
            break
    return (np.stack(toks).astype(np.int32),
            np.asarray(gold, np.int32),
            np.asarray(lens, np.int32))
