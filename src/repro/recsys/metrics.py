"""Recommendation quality metrics: HR@K and NDCG@K (paper §IV.B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def hr_ndcg_at_k(scores: jnp.ndarray, gold: jnp.ndarray, k: int = 10,
                 exclude: jnp.ndarray = None):
    """scores: (U, V) full-ranking scores; gold: (U,) gold item ids.

    exclude: optional (U, V) bool — items to remove from ranking (e.g. the
    user's own history, standard leave-one-out protocol).
    Returns (hr@k, ndcg@k) scalars.
    """
    s = scores.astype(jnp.float32)
    if exclude is not None:
        gold_onehot = jax.nn.one_hot(gold, s.shape[-1], dtype=bool)
        s = jnp.where(exclude & ~gold_onehot, -jnp.inf, s)
    gold_score = jnp.take_along_axis(s, gold[:, None], axis=-1)
    # rank = number of items scoring strictly higher than gold
    rank = jnp.sum(s > gold_score, axis=-1)
    hit = rank < k
    hr = jnp.mean(hit.astype(jnp.float32))
    ndcg = jnp.mean(jnp.where(hit, 1.0 / jnp.log2(rank + 2.0), 0.0))
    return hr, ndcg


def history_exclusion(tokens: np.ndarray, n_vocab: int) -> np.ndarray:
    """(U, S) history tokens -> (U, V) bool mask of seen items (+specials)."""
    U = tokens.shape[0]
    mask = np.zeros((U, n_vocab), bool)
    for u in range(U):
        mask[u, tokens[u]] = True
    mask[:, :3] = True                         # pad/bos/mask tokens
    return mask
