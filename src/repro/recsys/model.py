"""RecLLM: the paper's LLM-based recommender (Fig. 1).

A decoder-only LM over item-token sequences produces next-item logits; a CF
(matrix-factorization) head over user/item embeddings provides collaborative
signals; a learned fusion gate combines the two — the cross-modal
collaborative fusion of Fig. 1.  Trained end-to-end with next-item CE.

The CF factor tables are ``repro.embeddings`` tables: inits come from
:func:`embeddings.init_table`, the user lookup goes through the dedup path
(unique -> gather -> inverse — recsys batches revisit users heavily), and
:func:`embed_specs`/:func:`embed_id_fns` expose the placement/sparse-sync
hooks the trainer and benchmarks consume.  ``cf_item`` participates as a
dense factor product (every item is scored every step), so only ``cf_user``
— and the LM's item-token ``embed`` table — have sparse row gradients.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.embeddings import EmbedSpec, dedup_lookup, init_table
from repro.models import layers, transformer as tf
from repro.models.transformer import ModelCtx


def embed_specs(cfg: ArchConfig, n_users: int, cf_dim: int = 64
                ) -> Dict[str, EmbedSpec]:
    """The model's embedding tables, as subsystem specs (placement/cost)."""
    return {
        "cf_user": EmbedSpec("cf_user", rows=n_users, dim=cf_dim),
        "cf_item": EmbedSpec("cf_item", rows=cfg.padded_vocab, dim=cf_dim),
    }


def embed_id_fns() -> Dict[str, Callable[[Dict], jnp.ndarray]]:
    """batch -> touched-row ids per sparse-synced table, for the trainer's
    rows-touched DP gradient exchange (``cf_item`` is dense — excluded)."""
    return {"cf_user": lambda batch: batch["user"]}


def embed_plans(kind: str = "row", row_axis: str = "model",
                col_axis: str = "data"):
    """Default :class:`~repro.embeddings.EmbedPlan` placement for the CF
    tables under the hybrid GSPMD mesh — pass to ``auto_plan(...,
    embed_plans=...)`` / ``ShardingPlan.embed_plans`` so the train step
    places the tables where the embeddings subsystem costs them (row-
    sharded vocab by default; any non-dividing table falls back to
    replication via the plan guard)."""
    from repro.embeddings import make_plan
    plan = make_plan(kind, row_axis=row_axis, col_axis=col_axis)
    return {"cf_user": plan, "cf_item": plan}


def init_recllm(key, cfg: ArchConfig, n_users: int, cf_dim: int = 64
                ) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    specs = embed_specs(cfg, n_users, cf_dim)
    return {
        "lm": tf.init_params(k1, cfg),
        "cf_user": init_table(k2, specs["cf_user"]),
        "cf_item": init_table(k3, specs["cf_item"]),
        "fusion_gate": jnp.zeros((), jnp.float32),      # sigmoid-gated alpha
    }


def fuse(lm_logits, cf_scores, fusion_gate):
    """The cross-modal fusion gate (Fig. 1): LM logits plus sigmoid-gated
    CF scores, in f32.  One function so training (:func:`rec_logits`) and
    the serving CF head (:mod:`repro.serving.cf_head`) combine the two
    signals identically — shapes just need to broadcast."""
    alpha = jax.nn.sigmoid(fusion_gate)
    return jnp.asarray(lm_logits, jnp.float32) + alpha * cf_scores


def rec_logits(cfg: ArchConfig, params: Dict, batch: Dict,
               ctx: ModelCtx = ModelCtx()):
    """LM logits fused with CF scores.  batch: tokens (B,S), user (B,)."""
    lm_logits, aux, _ = tf.forward(cfg, params["lm"], batch, ctx)
    u = dedup_lookup(params["cf_user"], batch["user"])   # (B, dc)
    cf = u @ params["cf_item"].T                         # (B, V)
    fused = fuse(lm_logits, cf[:, None, :], params["fusion_gate"])
    return fused, aux


def recllm_loss(cfg: ArchConfig, params: Dict, batch: Dict,
                ctx: ModelCtx = ModelCtx()) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = rec_logits(cfg, params, batch, ctx)
    loss = layers.cross_entropy_loss(logits, batch["targets"],
                                     batch.get("mask"))
    return loss, {"ce": loss}


def score_users(cfg: ArchConfig, params: Dict, tokens, users, lens,
                ctx: ModelCtx = ModelCtx()):
    """Scores for ranking: logits at each user's last history position.

    ``lens`` is clamped to the final sequence position: a full-window
    history (``lens == S``) must read the last token's logits, not one past
    them (jax gather clamps silently; numpy-backed callers would crash).
    """
    batch = {"tokens": tokens, "user": users}
    logits, _ = rec_logits(cfg, params, batch, ctx)
    B, S = tokens.shape
    pos = jnp.minimum(lens, S - 1)
    return logits[jnp.arange(B), pos]                    # (B, V)
