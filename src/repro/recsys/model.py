"""RecLLM: the paper's LLM-based recommender (Fig. 1).

A decoder-only LM over item-token sequences produces next-item logits; a CF
(matrix-factorization) head over user/item embeddings provides collaborative
signals; a learned fusion gate combines the two — the cross-modal
collaborative fusion of Fig. 1.  Trained end-to-end with next-item CE.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers, transformer as tf
from repro.models.transformer import ModelCtx


def init_recllm(key, cfg: ArchConfig, n_users: int, cf_dim: int = 64
                ) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "lm": tf.init_params(k1, cfg),
        "cf_user": (jax.random.normal(k2, (n_users, cf_dim), jnp.float32)
                    * 0.02),
        "cf_item": (jax.random.normal(k3, (cfg.padded_vocab, cf_dim),
                                      jnp.float32) * 0.02),
        "fusion_gate": jnp.zeros((), jnp.float32),      # sigmoid-gated alpha
    }


def rec_logits(cfg: ArchConfig, params: Dict, batch: Dict,
               ctx: ModelCtx = ModelCtx()):
    """LM logits fused with CF scores.  batch: tokens (B,S), user (B,)."""
    lm_logits, aux, _ = tf.forward(cfg, params["lm"], batch, ctx)
    u = params["cf_user"][batch["user"]]                 # (B, dc)
    cf = u @ params["cf_item"].T                         # (B, V)
    alpha = jax.nn.sigmoid(params["fusion_gate"])
    fused = lm_logits.astype(jnp.float32) + alpha * cf[:, None, :]
    return fused, aux


def recllm_loss(cfg: ArchConfig, params: Dict, batch: Dict,
                ctx: ModelCtx = ModelCtx()) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = rec_logits(cfg, params, batch, ctx)
    loss = layers.cross_entropy_loss(logits, batch["targets"],
                                     batch.get("mask"))
    return loss, {"ce": loss}


def score_users(cfg: ArchConfig, params: Dict, tokens, users, lens,
                ctx: ModelCtx = ModelCtx()):
    """Scores for ranking: logits at each user's last history position."""
    batch = {"tokens": tokens, "user": users}
    logits, _ = rec_logits(cfg, params, batch, ctx)
    B = tokens.shape[0]
    return logits[jnp.arange(B), lens]                   # (B, V)
