"""AdamW with fp32 master weights + ZeRO-1-shardable state (no optax in this
environment — implemented from scratch).  Optionally routes the elementwise
update through the fused Pallas kernel (``kernels/fused_adamw.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class _Upd(tuple):
    """Sentinel tuple marking one leaf's (p, m, v) update triple."""


def init_opt_state(params) -> Dict[str, Any]:
    """m, v and fp32 master copy, all shaped like params (specs from
    ``ShardingPlan.opt_specs`` make this ZeRO-1)."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # copy=True: f32 params must not alias the master copy (donation safety)
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                          params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "master": master,
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _leaf_update(p32, g, m, v, lr, bc1, bc2, tc: TrainConfig,
                 use_kernel: bool):
    g = g.astype(jnp.float32)
    if use_kernel and p32.size % (8 * 128) == 0:
        from repro.kernels import ops
        p1, m1, v1 = ops.adamw_update(
            p32.reshape(-1), g.reshape(-1), m.reshape(-1), v.reshape(-1),
            lr, bc1, bc2, b1=tc.b1, b2=tc.b2, eps=tc.eps, wd=tc.weight_decay)
        return (p1.reshape(p32.shape), m1.reshape(p32.shape),
                v1.reshape(p32.shape))
    m1 = tc.b1 * m + (1 - tc.b1) * g
    v1 = tc.b2 * v + (1 - tc.b2) * jnp.square(g)
    mh = m1 / bc1
    vh = v1 / bc2
    p1 = p32 - lr * (mh / (jnp.sqrt(vh) + tc.eps) + tc.weight_decay * p32)
    return p1, m1, v1


def adamw_apply(params, grads, opt: Dict[str, Any], lr, tc: TrainConfig,
                use_kernel: bool = False) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step.  Returns (new_params_in_model_dtype, new_opt)."""
    step = opt["step"] + 1
    bc1 = 1.0 - tc.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - tc.b2 ** step.astype(jnp.float32)
    if tc.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, tc.grad_clip)
    out = jax.tree.map(
        lambda p32, g, m, v: _Upd(_leaf_update(p32, g, m, v, lr, bc1, bc2,
                                               tc, use_kernel)),
        opt["master"], grads, opt["m"], opt["v"])
    # out is a pytree of _Upd 3-tuples at param leaves; transpose it
    # (_Upd is a sentinel type so params pytrees containing plain tuples —
    # e.g. gemma's unrolled blocks — are not mistaken for update leaves)
    is_upd = lambda x: isinstance(x, _Upd)  # noqa: E731
    master = jax.tree.map(lambda t: t[0], out, is_leaf=is_upd)
    m = jax.tree.map(lambda t: t[1], out, is_leaf=is_upd)
    v = jax.tree.map(lambda t: t[2], out, is_leaf=is_upd)
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype),
                              master, params)
    return new_params, {"m": m, "v": v, "master": master, "step": step}
