"""Serving-side embedding lookups: a frequency-cached hot-row replica in
front of the sharded shard_map exchange.

Zipfian id traffic concentrates lookups on a small head of rows ("Training
Recommender Systems at Scale"): a request batch of C candidate ids mostly
revisits the same few hundred hot items.  Under the row/col/2D sharding
plans every one of those lookups pays a cross-shard exchange — a psum of
(U, D) partials and/or an all-to-all of column slices — even though the
answer was the same bytes as last request.  This module converts that
exchange from O(C·D) to O(C_tail·D):

* :class:`FreqTracker` — exact decayed-count popularity over row ids (the
  sketch-free baseline; counts halve every ``1/(1-decay)`` observations so
  yesterday's hot head ages out).
* :class:`HotRowCache` — a replicated host-side copy of the top-K rows by
  decayed count, with an id -> slot map.  Rows are **exact copies** of the
  authoritative table rows, re-gathered at election and after table
  updates, so a cache hit is bit-identical to the sharded path.
* :class:`CachedLookup` — the serving lookup over one table: partition the
  requested ids into hits (gathered from the replica — no collective) and
  misses (bucket-padded through the existing ``make_sharded_lookup``
  shard_map exchange), stitched back in request order.  Rows-touched
  refresh (:func:`repro.embeddings.update.rows_touched`) keeps the replica
  exact after trainer updates.

Exactness argument: the sharded lookup is bit-identical to a replicated
gather (the psum adds exact-zero partials from non-owner shards, the
all-to-all is pure data movement), and cache rows are byte copies of the
same table — so the cached path equals the uncached path bit-for-bit at
every plan, which the tests and the 8-device check assert.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.embeddings.lookup import make_sharded_lookup
from repro.embeddings.table import (EmbedPlan, EmbedSpec, make_plan,
                                    named_sharding)
from repro.embeddings.update import rows_touched


class FreqTracker:
    """Exact decayed-count row popularity (host side, numpy).

    ``observe`` decays every count by ``decay`` then adds 1 per requested
    id; ``top_k`` returns the hottest row ids (sorted, count > 0 only) —
    the election set for :class:`HotRowCache`.  Exact counting keeps the
    cache contents deterministic for a given request stream; a CM-sketch
    drop-in would trade that for O(1) memory at web-scale vocabularies.
    """

    def __init__(self, n_rows: int, decay: float = 0.98):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.n_rows = n_rows
        self.decay = decay
        self.counts = np.zeros(n_rows, np.float64)

    def observe(self, ids: np.ndarray) -> None:
        flat = np.asarray(ids, np.int64).reshape(-1)
        self.counts *= self.decay
        np.add.at(self.counts, flat, 1.0)

    def top_k(self, k: int) -> np.ndarray:
        k = min(int(k), self.n_rows)
        if k <= 0:
            return np.empty(0, np.int64)
        idx = np.argpartition(-self.counts, k - 1)[:k]
        idx = idx[self.counts[idx] > 0.0]
        return np.sort(idx.astype(np.int64))


class HotRowCache:
    """Replicated copy of the top-K hottest rows of one table.

    ``rows[slot_of[id]]`` is a byte copy of ``table[id]``; hits skip the
    cross-shard exchange entirely.  ``refresh`` re-elects the head from
    the tracker; ``refresh_touched`` re-gathers only the cached rows a
    table update touched (the trainer's rows-touched set), restoring
    bit-exactness without a full re-election.
    """

    def __init__(self, n_rows: int, capacity: int, decay: float = 0.98):
        self.capacity = int(capacity)
        self.tracker = FreqTracker(n_rows, decay)
        self.ids = np.empty(0, np.int64)
        self.slot_of: Dict[int, int] = {}
        self.rows = np.empty((0, 0), np.float32)
        self.hits = 0
        self.misses = 0

    @property
    def n_cached(self) -> int:
        return len(self.ids)

    def refresh(self, host_table: np.ndarray) -> None:
        """Re-elect the top-K head; gather rows only for newly elected
        ids.  Rows already cached keep their bytes — the replica is not
        re-read from the table on election, which is what makes the
        rows-touched refresh after updates load-bearing (and what a real
        deployment does: election moves the membership set, not the
        data)."""
        new_ids = self.tracker.top_k(self.capacity)
        rows = np.empty((len(new_ids), host_table.shape[1]), np.float32)
        held = np.fromiter((self.slot_of.get(int(i), -1) for i in new_ids),
                           np.int64, count=len(new_ids))
        keep = held >= 0
        if keep.any():
            rows[keep] = self.rows[held[keep]]
        if (~keep).any():
            rows[~keep] = host_table[new_ids[~keep]]
        self.ids = new_ids
        self.slot_of = {int(i): s for s, i in enumerate(new_ids)}
        self.rows = rows

    def refresh_touched(self, touched: np.ndarray,
                        host_table: np.ndarray) -> None:
        """Re-gather cached rows intersecting ``touched`` (unique row ids
        from the update batch); untouched cache slots keep their bytes."""
        if not len(self.ids):
            return
        stale = np.isin(self.ids, np.asarray(touched, np.int64))
        if stale.any():
            self.rows[stale] = host_table[self.ids[stale]]

    def plan_lookup(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(hit mask, cache slot per id; -1 on miss) + hit/miss counters."""
        flat = np.asarray(ids, np.int64).reshape(-1)
        slots = np.fromiter((self.slot_of.get(int(i), -1) for i in flat),
                            np.int64, count=len(flat))
        hit = slots >= 0
        self.hits += int(hit.sum())
        self.misses += int((~hit).sum())
        return hit, slots


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Knobs of the hot-row replica on one serving lookup."""

    rows: int = 0                  # cache capacity (0 = cache off)
    decay: float = 0.98            # per-observation count decay
    elect_every: int = 1           # lookups between head re-elections
    #   (election is host-side top-K + a <= capacity-row gather — cheap
    #   next to the exchange it saves; raise it to model a server that
    #   re-elects on a timer instead of per request)
    miss_quantum: int = 8          # miss-path pad bucket (x dp size)


class CachedLookup:
    """One table's serving lookup: hot-row replica first, shard_map
    exchange only for the cold tail.

    ``table`` is the authoritative (rows, dim) array, placed under
    ``plan`` on ``mesh`` (trivial 1-device meshes work; ``mesh=None``
    keeps the table replicated and skips shard_map entirely).  Calls are
    host-side: ``lookup(ids) -> (n, D) float32`` exactly equal to
    ``table[ids]``, plus per-call hit/miss stats.  The miss path pads to
    a bucket (a multiple of the DP-axis size times ``miss_quantum``) so
    the jitted shard_map sees a handful of static shapes.
    """

    def __init__(self, spec: EmbedSpec, plan: EmbedPlan,
                 table, mesh: Optional[Mesh] = None,
                 cache: CacheConfig = CacheConfig(),
                 dp_axis: str = "data"):
        self.spec, self.plan, self.ccfg = spec, plan, cache
        self.dp_axis = dp_axis
        # always copy: the caller's array may be a read-only jax buffer
        # view, and update_rows writes in place
        self._host = np.array(table, dtype=np.float32, order="C")
        if self._host.shape != (spec.rows, spec.dim):
            raise ValueError(f"{spec.name}: table shape {self._host.shape} "
                             f"!= spec ({spec.rows}, {spec.dim})")
        self.mesh = mesh
        self._ndp = 1
        self._sharded = None
        if mesh is not None and plan.kind != "replicated":
            self._sharded = make_sharded_lookup(mesh, spec, plan, dp_axis)
            self._ndp = dict(mesh.shape)[dp_axis]
            self._table_dev = jax.device_put(
                jnp.asarray(self._host), named_sharding(mesh, plan))
            self._ids_sharding = NamedSharding(mesh, P(dp_axis))
        else:
            self._table_dev = jnp.asarray(self._host)
        self.cache = (HotRowCache(spec.rows, cache.rows, cache.decay)
                      if cache.rows > 0 else None)
        self.calls = 0
        self.exchanged_ids = 0          # ids that took the sharded path

    # -- cache bookkeeping ---------------------------------------------------

    @property
    def hits(self) -> int:
        return self.cache.hits if self.cache else 0

    @property
    def misses(self) -> int:
        return self.cache.misses if self.cache else 0

    @property
    def n_cached(self) -> int:
        return self.cache.n_cached if self.cache else 0

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    # -- the lookup ----------------------------------------------------------

    def _miss_bucket(self, n: int) -> int:
        """Static miss-path shapes: the next power-of-two multiple of
        (quantum x DP size) — col plans shard the id vector over the DP
        axis, so the padded count must divide by it."""
        q = max(1, self.ccfg.miss_quantum) * self._ndp
        b = q
        while b < n:
            b *= 2
        return b

    def _exchange(self, ids: np.ndarray) -> np.ndarray:
        """table[ids] through the sharded (or replicated) path."""
        n = len(ids)
        if self._sharded is None:
            out = np.asarray(self._table_dev[jnp.asarray(ids, jnp.int32)])
            self.exchanged_ids += n
            return out
        pad = self._miss_bucket(n)
        padded = np.zeros(pad, np.int32)
        padded[:n] = ids
        ids_dev = jax.device_put(jnp.asarray(padded), self._ids_sharding)
        out = np.asarray(self._sharded(self._table_dev, ids_dev))[:n]
        self.exchanged_ids += pad
        return out

    def __call__(self, ids) -> Tuple[np.ndarray, Dict[str, int]]:
        """(rows (n, D) float32 == table[ids] bit-for-bit, stats)."""
        flat = np.asarray(ids, np.int64).reshape(-1)
        self.calls += 1
        if self.cache is None:
            rows = self._exchange(flat)
            return rows, {"hits": 0, "misses": len(flat)}
        self.cache.tracker.observe(flat)
        hit, slots = self.cache.plan_lookup(flat)
        rows = np.empty((len(flat), self.spec.dim), np.float32)
        if hit.any():
            rows[hit] = self.cache.rows[slots[hit]]
        n_miss = int((~hit).sum())
        if n_miss:
            rows[~hit] = self._exchange(flat[~hit])
        if self.ccfg.elect_every and \
                self.calls % self.ccfg.elect_every == 0:
            self.cache.refresh(self._host)
        return rows, {"hits": int(hit.sum()), "misses": n_miss}

    # -- table updates / staleness -------------------------------------------

    def _sync_device(self) -> None:
        if self._sharded is not None:
            self._table_dev = jax.device_put(
                jnp.asarray(self._host), named_sharding(self.mesh, self.plan))
        else:
            self._table_dev = jnp.asarray(self._host)

    def update_rows(self, ids, rows, refresh: bool = True) -> np.ndarray:
        """Land a trainer update: ``table[ids] = rows`` (duplicate ids:
        last write wins, matching a sequential scatter).  With ``refresh``
        the cached copies of the touched rows are re-gathered immediately
        (the rows-touched hook); ``refresh=False`` leaves the replica
        stale until :meth:`refresh_touched` — what the staleness tests
        exercise.  Returns the unique touched-row ids."""
        flat = np.asarray(ids, np.int64).reshape(-1)
        self._host[flat] = np.asarray(rows, np.float32)
        self._sync_device()
        touched = np.asarray(
            rows_touched(jnp.asarray(flat), self.spec.rows))
        touched = touched[touched < self.spec.rows]
        if refresh:
            self.refresh_touched(touched)
        return touched

    def refresh_touched(self, touched) -> None:
        """Rows-touched cache refresh: restore bit-exactness for the
        cached rows a table update invalidated."""
        if self.cache is not None:
            self.cache.refresh_touched(np.asarray(touched, np.int64),
                                       self._host)

    def summary(self) -> Dict:
        return {
            "table": self.spec.name, "plan": self.plan.kind,
            "cache_rows": self.ccfg.rows, "cached_now": self.n_cached,
            "hits": self.hits, "misses": self.misses,
            "hit_rate": self.hit_rate,
            "lookups": self.calls, "exchanged_ids": self.exchanged_ids,
        }


def make_cached_lookup(name: str, table, kind: str = "replicated",
                       mesh: Optional[Mesh] = None,
                       cache: CacheConfig = CacheConfig(),
                       row_axis: str = "model", col_axis: str = "data",
                       ) -> CachedLookup:
    """Convenience: spec from the table's shape, plan from ``kind``."""
    t = np.asarray(table)
    spec = EmbedSpec(name, rows=t.shape[0], dim=t.shape[1])
    plan = make_plan(kind, row_axis=row_axis, col_axis=col_axis)
    return CachedLookup(spec, plan, t, mesh=mesh, cache=cache)
