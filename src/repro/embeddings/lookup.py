"""Embedding lookups: dedup path and the sharded shard_map exchanges.

Dedup (unique -> gather -> inverse-scatter) exploits the Zipfian id
distribution of recsys batches: a batch of B ids hits U <= B unique rows,
so the gather moves U rows and — under the row-sharded plans — the psum
exchanges U-row payloads instead of B-row ones.  ``jnp.unique(size=...)``
keeps everything statically shaped (sentinel-padded) for jit.

The sharded lookups run the whole (gather + exchange) inside ``shard_map``
so the collectives appear explicitly in the compiled HLO and
``analysis/hlo_cost.py`` can count their bytes:

* ``row``      — each device owns a vocab slice; masked local gather, then
                 ``psum`` of the (U, D) partials over the row axis.
* ``col``      — DLRM-style: features sharded over the DP ranks; ids are
                 all-gathered over the col axis, each rank computes its
                 column slice for the whole global batch, and an
                 ``all_to_all`` swaps batch-slices for column-slices.
* ``row_col``  — both: masked gather, psum over rows, all_to_all over cols.

Gradients flow through the transposed collectives automatically (psum's
transpose is free, all_to_all's is all_to_all), so a table shard's gradient
lands on its owner without any dense full-table exchange.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.embeddings.table import EmbedPlan, EmbedSpec, pspec
from repro.kernels import ops


def dedup_ids(ids: jnp.ndarray, cap: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(unique ids (cap,), inverse (n,)) with sentinel padding (repeats of
    the smallest id) — ``u[inv]`` reconstructs ``ids`` exactly."""
    flat = ids.reshape(-1)
    u, inv = jnp.unique(flat, return_inverse=True,
                        size=cap or flat.shape[0])
    return u, inv.reshape(-1)


def dedup_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                 use_kernel: bool = False) -> jnp.ndarray:
    """``table[ids]`` via unique -> gather -> inverse-scatter.

    Bit-identical to the direct gather; moves U <= n rows.  With
    ``use_kernel`` the gather is the Pallas scalar-prefetch DMA kernel
    (``kernels/embedding_ops.py``); default is the jnp gather, which keeps
    lowering-path HLO clean for the cost analyzer.
    """
    u, inv = dedup_ids(ids)
    rows = ops.embedding_gather(table, u) if use_kernel else table[u]
    return rows[inv].reshape(ids.shape + (table.shape[-1],))


# ---------------------------------------------------------------------------
# sharded lookups
# ---------------------------------------------------------------------------

def _local_gather(tshard, u, plan: EmbedPlan):
    """Gather the shard's slice of rows ``u`` (global ids), masking rows
    another shard owns; psum over the row axis completes them."""
    if plan.row_axis is None:
        return tshard[u]
    vr = tshard.shape[0]
    lo = jax.lax.axis_index(plan.row_axis) * vr
    local = u - lo
    own = (local >= 0) & (local < vr)
    rows = jnp.where(own[:, None],
                     tshard[jnp.clip(local, 0, vr - 1)],
                     jnp.zeros((), tshard.dtype))
    return jax.lax.psum(rows, plan.row_axis)


def sharded_lookup_body(tshard: jnp.ndarray, ids_loc: jnp.ndarray,
                        plan: EmbedPlan) -> jnp.ndarray:
    """The per-device lookup, for use *inside* shard_map: local table
    shard + local ids -> (B_loc, D) complete embeddings.  Composable into
    larger shard_map'd steps (the DP trainer, the benchmark payload)."""
    q = (jax.lax.all_gather(ids_loc, plan.col_axis, axis=0, tiled=True)
         if plan.col_axis else ids_loc)
    if plan.dedup:
        u, inv = dedup_ids(q)
    else:
        u, inv = q, jnp.arange(q.shape[0])
    rows = _local_gather(tshard, u, plan)              # (U, Dc)
    out = rows[inv]                                    # (Bq, Dc)
    if plan.col_axis:
        # (B_glob, D/nc): swap batch-slices for column-slices
        out = jax.lax.all_to_all(out, plan.col_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
    return out                                         # (B_loc, D)


def make_sharded_lookup(mesh: Mesh, spec: EmbedSpec, plan: EmbedPlan,
                        dp_axis: str = "data"):
    """Returns jitted ``lookup(table, ids) -> (B, D)``.

    ``table`` is the global (rows, dim) array (placed by ``in_shardings``
    from the plan's PartitionSpec); ``ids`` is the global (B,) id vector,
    sharded over ``dp_axis``.  The result is (B, D), batch-sharded over
    ``dp_axis`` and replicated over the table axes.
    """
    if plan.col_axis is not None and plan.col_axis != dp_axis:
        raise ValueError(
            f"col sharding must use the DP axis (got col_axis="
            f"{plan.col_axis!r}, dp_axis={dp_axis!r}): the all-to-all "
            f"swaps batch slices for column slices across DP ranks")
    del spec                            # shapes come from the shards

    fn = shard_map(partial(sharded_lookup_body, plan=plan), mesh=mesh,
                   in_specs=(pspec(plan), P(dp_axis)),
                   out_specs=P(dp_axis, None),
                   check_rep=False)
    return jax.jit(fn)


def replicated_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                      dedup: bool = True) -> jnp.ndarray:
    """The baseline every plan is checked against: plain (optionally
    deduped) gather on a replicated table."""
    return dedup_lookup(table, ids) if dedup else table[ids]
