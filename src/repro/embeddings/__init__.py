"""Sharded sparse-embedding subsystem.

* :mod:`repro.embeddings.table`  — placement: EmbedSpec/EmbedPlan, shard
  shapes/bytes, PartitionSpecs, the modeled exchange-cost summary.
* :mod:`repro.embeddings.lookup` — dedup lookup (unique -> gather ->
  inverse) and the shard_map lookups for each sharding plan.
* :mod:`repro.embeddings.update` — rows-touched sparse-gradient DP sync
  and segment-sum gradients, with optional payload compression.
* :mod:`repro.embeddings.serving` — the serving-side hot-row replica:
  frequency-tracked top-K cache in front of the sharded lookup (hits skip
  the exchange; rows-touched refresh keeps it exact after updates).
"""
from repro.embeddings.table import (  # noqa: F401
    PLANS, EmbedPlan, EmbedSpec, exchange_bytes, init_table, make_plan,
    named_sharding, plan_summary, pspec, shard_bytes, shard_shape,
    sparse_exchange_bytes)
from repro.embeddings.lookup import (  # noqa: F401
    dedup_ids, dedup_lookup, make_sharded_lookup, replicated_lookup,
    sharded_lookup_body)
from repro.embeddings.update import (  # noqa: F401
    gather_grad_rows, make_row_compressor, rows_touched, scatter_rows,
    sparse_grad_from_lookup, sparse_row_sync)
from repro.embeddings.serving import (  # noqa: F401
    CacheConfig, CachedLookup, FreqTracker, HotRowCache,
    make_cached_lookup)
