"""Sharded sparse-embedding tables.

The recsys workloads live or die on their embedding tables (CF user/item
factors, the LM's item-token table).  Keeping them replicated makes every
DP replica pay full-table memory *and* a full dense-gradient all-reduce —
the bandwidth waste the paper's compression/sparsification section targets.
This module is the placement half of the subsystem: which slice of a table
each device owns, and what that costs.

Four plans over the ``launch/mesh.py`` meshes (axis names ``data`` = DP
batch axis, ``model`` = the table-parallel axis):

============  ==========================  =============================
plan          shard per device            lookup exchange (shard_map)
============  ==========================  =============================
replicated    full (V, D)                 none (dense grad all-reduce)
row           (V / |model|, D)            psum of (U, D) over ``model``
col           (V, D / |data|)             all-gather ids + all-to-all of
                                          (B, D/|data|) over ``data``
row_col       (V/|model|, D/|data|)       psum over ``model`` then
                                          all-to-all over ``data``
============  ==========================  =============================

``col``/``row_col`` follow the DLRM 2D-parallel layout: the embedding dim
is sharded over the *data* ranks, so each rank computes its column slice
for the whole global batch and an all-to-all swaps (batch slice) for
(column slice).  Exchange is activation-sized — independent of V — while
the replicated baseline's gradient all-reduce scales with the full table.

Lookups and gradients are in :mod:`repro.embeddings.lookup` /
:mod:`repro.embeddings.update`; this module is pure placement math so the
benchmark and the dry-run can cost plans without touching device state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PLANS = ("replicated", "row", "col", "row_col")


@dataclasses.dataclass(frozen=True)
class EmbedSpec:
    """One logical table: ``rows`` ids x ``dim`` features."""

    name: str
    rows: int
    dim: int
    init_scale: float = 0.02
    dtype: str = "float32"

    @property
    def bytes(self) -> int:
        return self.rows * self.dim * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class EmbedPlan:
    """Placement of a table over a mesh.

    ``row_axis`` shards the vocab dim (classic model parallelism);
    ``col_axis`` shards the feature dim over the DP ranks (DLRM 2D).
    Either may be ``None``; both ``None`` is the replicated baseline.
    """

    kind: str = "replicated"            # replicated | row | col | row_col
    row_axis: Optional[str] = None      # vocab-dim mesh axis
    col_axis: Optional[str] = None      # feature-dim mesh axis
    dedup: bool = True                  # unique->gather->inverse lookups

    def __post_init__(self):
        if self.kind not in PLANS:
            raise ValueError(f"unknown plan kind {self.kind!r}")
        want = {"replicated": (False, False), "row": (True, False),
                "col": (False, True), "row_col": (True, True)}[self.kind]
        have = (self.row_axis is not None, self.col_axis is not None)
        if want != have:
            raise ValueError(
                f"plan {self.kind!r} needs row_axis={want[0]}, "
                f"col_axis={want[1]}; got {self.row_axis!r}/{self.col_axis!r}")


def make_plan(kind: str, *, row_axis: str = "model",
              col_axis: str = "data", dedup: bool = True) -> EmbedPlan:
    """Plan with the conventional axis assignment for each kind."""
    return EmbedPlan(
        kind=kind,
        row_axis=row_axis if kind in ("row", "row_col") else None,
        col_axis=col_axis if kind in ("col", "row_col") else None,
        dedup=dedup)


def _axis(mesh_shape: Dict[str, int], name: Optional[str]) -> int:
    return mesh_shape[name] if name else 1


def shard_shape(spec: EmbedSpec, plan: EmbedPlan,
                mesh_shape: Dict[str, int]) -> Tuple[int, int]:
    """Per-device (rows, cols) under the plan; dims must divide evenly."""
    nr = _axis(mesh_shape, plan.row_axis)
    nc = _axis(mesh_shape, plan.col_axis)
    if spec.rows % nr or spec.dim % nc:
        raise ValueError(
            f"{spec.name}: ({spec.rows}, {spec.dim}) does not divide over "
            f"({nr}, {nc}) shards")
    return spec.rows // nr, spec.dim // nc


def shard_bytes(spec: EmbedSpec, plan: EmbedPlan,
                mesh_shape: Dict[str, int]) -> int:
    r, c = shard_shape(spec, plan, mesh_shape)
    return r * c * jnp.dtype(spec.dtype).itemsize


def pspec(plan: EmbedPlan) -> P:
    """PartitionSpec of the (rows, dim) table under the plan."""
    return P(plan.row_axis, plan.col_axis)


def named_sharding(mesh: Mesh, plan: EmbedPlan) -> NamedSharding:
    return NamedSharding(mesh, pspec(plan))


def init_table(key, spec: EmbedSpec) -> jnp.ndarray:
    """Full-table init (scaled normal, the CF-factor convention)."""
    return (jax.random.normal(key, (spec.rows, spec.dim),
                              jnp.dtype(spec.dtype))
            * spec.init_scale)


# ---------------------------------------------------------------------------
# Cost model — what the benchmark's roofline projection and the example's
# --embed-plan summary print.  Wire-byte formulas mirror hlo_cost's ring
# model: all-reduce 2*n*(P-1)/P, all-gather / all-to-all n*(P-1)/P.
# ---------------------------------------------------------------------------

def exchange_bytes(spec: EmbedSpec, plan: EmbedPlan,
                   mesh_shape: Dict[str, int], batch_per_dev: int,
                   dp_axis: str = "data") -> Dict[str, float]:
    """Modeled per-device wire bytes per step (lookup fwd+bwd + grad sync).

    ``batch_per_dev`` is ids looked up per DP rank; dedup caps the reduced
    payload at that many unique rows (worst case, no repeats).
    """
    itemsize = jnp.dtype(spec.dtype).itemsize
    nr = _axis(mesh_shape, plan.row_axis)
    nc = _axis(mesh_shape, plan.col_axis)
    ndp = mesh_shape.get(dp_axis, 1)
    ring = lambda n: (n - 1) / n if n > 1 else 0.0  # noqa: E731
    b_glob = batch_per_dev * ndp

    look = 0.0
    if plan.row_axis:                    # psum of (U, D/nc) partials; with
        # col sharding the ids were all-gathered first, so the dedup set
        # is drawn from the GLOBAL batch (worst case b_glob unique rows)
        u = b_glob if plan.col_axis else batch_per_dev
        look += 2 * u * (spec.dim // nc) * itemsize * ring(nr)
    if plan.col_axis:                    # ids all-gather + all-to-all swap
        look += b_glob * 4 * ring(nc)
        look += b_glob * (spec.dim // nc) * itemsize * ring(nc)

    # gradient path: transposed lookup collectives + DP sync of whatever
    # table shard is replicated across DP ranks (col-sharded tables are
    # disjoint per DP rank — no table sync at all)
    grad = look                          # transpose costs mirror forward
    if plan.col_axis is None:
        grad += 2 * (spec.rows // nr) * spec.dim * itemsize * ring(ndp)
    return {"lookup": look, "grad": grad, "total": look + grad}


def sparse_exchange_bytes(spec: EmbedSpec, mesh_shape: Dict[str, int],
                          batch_per_dev: int, dp_axis: str = "data"
                          ) -> float:
    """Per-device wire bytes of the sparse rows-touched DP sync (all-gather
    of (U, D) values + (U,) ids) replacing the dense table all-reduce."""
    itemsize = jnp.dtype(spec.dtype).itemsize
    ndp = mesh_shape.get(dp_axis, 1)
    ring = (ndp - 1) / ndp if ndp > 1 else 0.0
    return batch_per_dev * (spec.dim * itemsize + 4) * ring


def plan_summary(spec: EmbedSpec, plan: EmbedPlan,
                 mesh_shape: Dict[str, int], batch_per_dev: int) -> Dict:
    """One-stop numbers for logs/artifacts."""
    r, c = shard_shape(spec, plan, mesh_shape)
    ex = exchange_bytes(spec, plan, mesh_shape, batch_per_dev)
    return {
        "table": spec.name, "plan": plan.kind,
        "mesh": dict(mesh_shape),
        "shard_rows": r, "shard_cols": c,
        "table_bytes_per_dev": shard_bytes(spec, plan, mesh_shape),
        "modeled_exchange_bytes": ex,
        "modeled_sparse_sync_bytes": sparse_exchange_bytes(
            spec, mesh_shape, batch_per_dev),
    }
