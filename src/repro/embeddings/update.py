"""Sparse-gradient updates: sync only the rows a step touched.

The backward of an embedding lookup is zero everywhere except the rows the
batch hit, yet replicated-dense DP all-reduces the full (V, D) gradient
every step.  This module replaces that with a rows-touched exchange inside
the shard_map'd DP step:

    u    = unique(local ids)                # (U,) + sentinel padding
    rows = dense_grad[u]                    # (U, D) — all the mass there is
    all-gather (u, rows) over the dp axes   # wire: P * U * (D*4 + 4) bytes
    scatter-add into (V, D), divide by P    # == pmean(dense_grad) exactly

Wire bytes scale with the batch's unique-id count instead of the vocab:
for the recsys tables (V ~ 1e5..1e7, U ~ batch) that is orders of
magnitude.  The payload can additionally ride the existing compression
kernels — ``make_row_compressor("topk", k)`` keeps the top-k magnitudes
per row via ``kernels/topk_sparsify.py`` before the gather (lossy; the
dropped mass is bounded by the per-row tail, and unlike dense top-k DP
sync no error-feedback residual is needed because untouched rows carry no
gradient to remember).

``sparse_row_sync`` is numerically the mean of the per-rank dense
gradients: every touched row appears in its rank's unique set, untouched
rows are zero on every rank.  On a 1-device mesh it is bit-for-bit equal.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ops


def rows_touched(ids: jnp.ndarray, n_rows: int,
                 cap: Optional[int] = None) -> jnp.ndarray:
    """Unique ids padded with the out-of-range sentinel ``n_rows``."""
    flat = ids.reshape(-1)
    return jnp.unique(flat, size=cap or flat.shape[0], fill_value=n_rows)


def gather_grad_rows(dense_grad: jnp.ndarray, u: jnp.ndarray
                     ) -> jnp.ndarray:
    """(U, D) gradient rows for unique ids; sentinel entries read as 0."""
    v = dense_grad.shape[0]
    valid = u < v
    rows = dense_grad[jnp.clip(u, 0, v - 1)]
    return jnp.where(valid[:, None], rows, jnp.zeros((), dense_grad.dtype))


def scatter_rows(u: jnp.ndarray, rows: jnp.ndarray, n_rows: int,
                 use_kernel: bool = False) -> jnp.ndarray:
    """(V, D) dense gradient from (ids, rows); sentinel ids drop onto a
    dump row that is sliced off."""
    idx = jnp.minimum(u, n_rows)
    if use_kernel:
        return ops.embedding_scatter_add(rows, idx, n_rows + 1)[:n_rows]
    return (jnp.zeros((n_rows + 1, rows.shape[-1]), rows.dtype)
            .at[idx].add(rows)[:n_rows])


def make_row_compressor(mode: str, k: int = 8, use_kernel: bool = True
                        ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Per-row payload compressor for the exchanged gradient rows.

    ``topk`` keeps the k largest-magnitude entries of each row (block size
    = the embedding dim) through the existing Pallas sparsifier.
    """
    if mode != "topk":
        raise ValueError(f"unknown row compressor {mode!r}")

    def compress(rows: jnp.ndarray) -> jnp.ndarray:
        u, d = rows.shape
        kept, _ = ops.topk_sparsify(rows.reshape(-1), min(k, d), block=d,
                                    impl="kernel" if use_kernel else "ref")
        return kept.reshape(u, d)

    return compress


def sparse_row_sync(dense_grad: jnp.ndarray, ids: jnp.ndarray,
                    axes: Sequence[str], *, cap: Optional[int] = None,
                    compress: Optional[Callable] = None) -> jnp.ndarray:
    """Mean DP gradient via rows-touched all-gather (inside shard_map).

    dense_grad: this rank's (V, D) gradient; ids: the local batch's ids
    (any shape).  Returns the (V, D) mean over the dp ``axes`` — what
    ``pmean(dense_grad, axes)`` computes, at U-row wire cost.

    ``cap`` bounds the exchanged row count; it must cover the batch's
    unique-id count (``cap >= unique(ids)``, trivially true for the
    default ``cap = len(ids)``): ``jnp.unique(size=cap)`` truncates
    silently, and a truncated row is dropped from the sync entirely —
    zero gradient, not even the local contribution.
    """
    v = dense_grad.shape[0]
    u = rows_touched(ids, v, cap)
    rows = gather_grad_rows(dense_grad, u)
    if compress is not None:
        rows = compress(rows)
    n_ranks = 1
    for ax in axes:
        u = jax.lax.all_gather(u, ax, axis=0, tiled=True)
        rows = jax.lax.all_gather(rows, ax, axis=0, tiled=True)
        n_ranks *= compat.axis_size(ax)
    return scatter_rows(u, rows, v) / n_ranks


def sparse_grad_from_lookup(dout: jnp.ndarray, ids: jnp.ndarray,
                            n_rows: int, cap: Optional[int] = None,
                            use_kernel: bool = False
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(unique ids, per-unique-row gradient) from the lookup cotangent —
    the segment-sum form, for optimizers that update touched rows only.

    dout: (..., D) cotangent of ``table[ids]``; returns (u (U,),
    grad_rows (U, D)) with ``scatter_rows(u, grad_rows, n_rows)`` equal to
    the dense gradient.
    """
    flat = ids.reshape(-1)
    d = dout.shape[-1]
    g2d = dout.reshape(-1, d)
    size = cap or flat.shape[0]
    u, inv = jnp.unique(flat, return_inverse=True, size=size)
    inv = inv.reshape(-1)
    if use_kernel:
        rows = ops.embedding_scatter_add(g2d, inv, size)
    else:
        rows = jnp.zeros((size, d), g2d.dtype).at[inv].add(g2d)
    # sentinel-padded tail repeats u[...]=fill; only the first occurrence
    # accumulated anything (inv never points at padding), so rows there
    # are zero and scattering them back is harmless.
    return u, rows
