"""Straggler mitigation (paper §V.B robustness): simulation harness for
heterogeneous / flaky workers and the three mitigation policies.

Policies over a step with per-worker speeds s_p (samples/sec):
* ``uniform``  — B/P samples each; step time = max_p((B/P)/s_p).
* ``adaptive`` — batch allocated by ``load_balance.adaptive_batch_allocation``
  (paper's adaptive batch sizing): step time = max_p(b_p/s_p).
* ``dropk``    — uniform batches but the slowest k workers' gradients are
  dropped (backup-worker semantics); effective samples shrink accordingly.

The accumulators live on a :class:`repro.obs.metrics.MetricsRegistry`
(a private one per call when none is handed in): a step-time histogram,
useful-samples counter, and per-step gauges — the simulated step clock is
an injectable :class:`repro.obs.trace.ManualClock`, so the gauge series
advance on simulation time, not wall time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import load_balance
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import ManualClock


@dataclasses.dataclass
class StragglerSim:
    n_workers: int = 8
    base_speed: float = 1000.0        # samples/sec/worker
    hetero_cv: float = 0.3            # speed coefficient of variation
    flaky_prob: float = 0.05          # per-step chance a worker runs 4x slow
    seed: int = 0

    def speeds(self, steps: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        base = self.base_speed * np.maximum(
            0.1, rng.normal(1.0, self.hetero_cv, self.n_workers))
        out = np.tile(base, (steps, 1))
        flaky = rng.random((steps, self.n_workers)) < self.flaky_prob
        out[flaky] /= 4.0
        return out


def run_policy(sim: StragglerSim, global_batch: int, steps: int,
               policy: str = "uniform", drop_k: int = 1,
               realloc_every: int = 10,
               metrics: Optional[MetricsRegistry] = None,
               clock: Optional[ManualClock] = None) -> Dict[str, float]:
    """Returns effective throughput (useful samples/sec) and step stats.

    ``metrics``: obs registry the per-step accumulators live on —
    ``straggler.step_time_s`` histogram, ``straggler.useful_samples``
    counter, ``straggler.slowest_worker_t`` gauge (timestamped by
    ``clock``, the simulated step clock, which ends at the total simulated
    duration).  The returned dict reads back out of the registry, so an
    attached caller sees exactly the reported numbers."""
    metrics = metrics if metrics is not None else MetricsRegistry()
    clock = clock if clock is not None else ManualClock()
    metrics.clock = clock
    hist = metrics.histogram("straggler.step_time_s")
    useful_c = metrics.counter("straggler.useful_samples")
    gauge = metrics.gauge("straggler.slowest_worker_t")
    speeds = sim.speeds(steps)
    P = sim.n_workers
    alloc = np.full(P, global_batch // P)
    for t in range(steps):
        s = speeds[t]
        if policy == "adaptive" and t % realloc_every == 0:
            # allocate by trailing observed speed (causal: use step t-1)
            obs = speeds[max(t - 1, 0)]
            alloc = load_balance.adaptive_batch_allocation(obs, global_batch)
        elif policy != "adaptive":
            alloc = np.full(P, global_batch // P)
        per_worker_t = alloc / s
        if policy == "dropk":
            # step completes when the (P-k)-th worker finishes
            finish = np.sort(per_worker_t)
            t_step = finish[P - 1 - drop_k]
            done = per_worker_t <= t_step + 1e-12
            useful_c.inc(float(alloc[done].sum()))
        else:
            t_step = per_worker_t.max()
            useful_c.inc(float(alloc.sum()))
        clock.advance(float(t_step))        # simulated step clock
        hist.observe(float(t_step))
        gauge.set(float(per_worker_t.max()))
    total_t = hist.total
    return {"throughput": float(useful_c.value / total_t),
            "mean_step_time": total_t / steps,
            "useful_frac": float(useful_c.value / (global_batch * steps))}


def compare_policies(sim: StragglerSim, global_batch: int = 1024,
                     steps: int = 200) -> Dict[str, Dict[str, float]]:
    return {p: run_policy(sim, global_batch, steps, p)
            for p in ("uniform", "adaptive", "dropk")}
