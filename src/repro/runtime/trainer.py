"""Training runtime: hybrid-parallel (GSPMD) and DP-shard_map train steps,
checkpoint/restart fault tolerance, and the training loop.

Two step builders:

* ``make_hybrid_train_step`` — the production path: jit with in/out shardings
  from the ``ShardingPlan`` (TP over ``model``, DP over ``data``/``pod``,
  ZeRO-1 optimizer state, optional remat + Megatron-SP).  Gradient sync is
  GSPMD-emitted (hierarchical across pods by construction of the mesh).
* ``make_dp_train_step`` — the paper's explicit DP path (its 8-GPU setup):
  the whole step runs inside shard_map over the dp axes with *manual*
  gradient sync: flat ring all-reduce (Eq. 8), hierarchical all-reduce (C5),
  or compressed all-gather with error feedback (C6, Eq. 10–11).
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import partial
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.checkpoint import manager as ckpt
from repro.config import ArchConfig, ParallelConfig, TrainConfig
from repro.core import compression, hierarchical
from repro.core import pipeline as pipe_lib
from repro.core import sharding as sharding_lib
from repro.core.hybrid import Plan
from repro.embeddings import update as embed_update
from repro.models import layers, transformer as tf
from repro.models.transformer import ModelCtx
from repro.obs import timeline as obs_timeline
from repro.obs.trace import Tracer, or_null
from repro.optimizer import adamw, schedule


# ---------------------------------------------------------------------------
# Hybrid (GSPMD) train step — production path
# ---------------------------------------------------------------------------

def make_hybrid_train_step(cfg: ArchConfig, plan: Plan, tcfg: TrainConfig,
                           loss_fn: Optional[Callable] = None,
                           donate: bool = True):
    """Returns (step_fn, shardings) — step_fn(params, opt, batch) ->
    (params, opt, metrics)."""
    sh = plan.sharding
    tp_n = sh.mesh.shape.get("model", 1)
    ctx = ModelCtx(remat=plan.remat, constrain=sh.constrain,
                   flash_vjp=sh.dp_heavy or tp_n == 1)
    if loss_fn is None:
        loss_fn = lambda p, b: tf.loss_fn(cfg, p, b, ctx)  # noqa: E731

    accum = max(plan.pcfg.microbatches, 1)

    def _grads(params, batch):
        if accum == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: scan over microbatches (batch dim 0 split),
        # grads accumulated in f32 — memory ~1/accum of the monolithic step
        mb = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def one(carry, b):
            g_acc, l_acc = carry
            (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, b)
            g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                 g_acc, g)
            return (g_acc, l_acc + loss), aux

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss), auxs = jax.lax.scan(one, (g0, jnp.zeros((), jnp.float32)),
                                       mb)
        g = jax.tree.map(lambda x: x / accum, g)
        aux = jax.tree.map(lambda a: jnp.mean(a, axis=0), auxs)
        return (loss / accum, aux), g

    def step(params, opt, batch):
        lr = schedule.warmup_cosine(opt["step"], tcfg.learning_rate,
                                    tcfg.warmup_steps, tcfg.steps)
        (loss, aux), grads = _grads(params, batch)
        # ZeRO-2: reduce-scatter gradients onto the optimizer-state sharding
        # (dp axes added) so full model-sharded-only gradients never
        # materialize — each dp rank only holds the shard it will update.
        gspecs = sh.opt_specs(cfg, jax.tree.map(
            lambda g: jax.ShapeDtypeStruct(g.shape, g.dtype), grads))
        grads = jax.tree.map(
            lambda g, sp: jax.lax.with_sharding_constraint(g, sh.named(sp)),
            grads, gspecs)
        new_params, new_opt = adamw.adamw_apply(params, grads, opt, lr, tcfg)
        metrics = {"loss": loss, "lr": lr,
                   "grad_norm": adamw.global_norm(grads)}
        return new_params, new_opt, metrics

    def shardings_for(params_shape, batch_shape):
        pspec = sh.param_specs(cfg, params_shape)
        ospec = {"m": sh.opt_specs(cfg, params_shape),
                 "v": sh.opt_specs(cfg, params_shape),
                 "master": sh.opt_specs(cfg, params_shape),
                 "step": P()}
        bspec = sh.batch_specs(batch_shape)
        to_named = lambda t: jax.tree.map(sh.named, t,  # noqa: E731
                                          is_leaf=lambda x: isinstance(x, P))
        return to_named(pspec), to_named(ospec), to_named(bspec)

    def jitted(params_shape, batch_shape):
        psh, osh, bsh = shardings_for(params_shape, batch_shape)
        return jax.jit(
            step,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1) if donate else (),
        )

    return step, jitted, shardings_for


# ---------------------------------------------------------------------------
# DP shard_map train step — the paper's explicit path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPSyncConfig:
    mode: str = "flat"              # flat | hierarchical | onebit | topk
    intra_axis: str = "data"
    inter_axis: Optional[str] = None
    block: int = 512
    topk_block: int = 2048
    k: int = 32
    use_kernel: bool = True


@dataclasses.dataclass(frozen=True)
class EmbedSyncConfig:
    """Rows-touched sparse sync for embedding-table gradients.

    ``id_fns`` maps top-level param keys (the embedding tables) to
    ``batch -> ids`` extractors; those tables' gradients skip the dense
    all-reduce (and the compressed flatten path) and are exchanged as
    (unique ids, gradient rows) all-gathers instead — wire bytes scale
    with the batch, not the vocab.  ``compress="topk"`` additionally
    sparsifies each exchanged row via the Pallas top-k kernel.
    """

    id_fns: Dict[str, Callable[[Dict], jnp.ndarray]]
    # unique-id cap (default: len(ids)).  Must be >= the max unique ids a
    # rank's batch can touch: an undersized cap silently truncates the
    # exchanged row set and the dropped rows get ZERO gradient.
    cap: Optional[int] = None
    compress: Optional[str] = None  # None | "topk"
    k: int = 8
    use_kernel: bool = True
    # ZeRO over the vocab dim: the named tables' AdamW moments + master
    # rows live only on the owning dp shard (composes with the row plan —
    # per-device optimizer bytes drop 1/P).  Each rank updates its row
    # slice of the synced gradient and the fresh rows are all-gathered
    # back into the replicated table.  Requires rows % dp_world == 0 and
    # ``params_shape`` at step-build time (the opt specs become per-leaf).
    zero_opt: bool = False

    @property
    def exclude(self) -> Tuple[str, ...]:
        """Param keys outside the dense/compressed sync path — pass to
        ``residual_size(params, scfg, exclude=...)`` when compressing."""
        return tuple(self.id_fns)


def residual_size(params, scfg: DPSyncConfig,
                  exclude: Tuple[str, ...] = ()) -> int:
    """Flat padded size of the compression error-feedback state.  Params
    under top-level keys in ``exclude`` (sparse-synced embedding tables)
    carry no residual — their sync is outside the compressed path."""
    if exclude:
        params = {k: v for k, v in params.items() if k not in exclude}
    n = sum(l.size for l in jax.tree.leaves(params))
    mult = 8 * scfg.block if scfg.mode == "onebit" else scfg.topk_block
    return n + ((-n) % mult)


def make_dp_train_step(loss_fn: Callable, mesh: Mesh, tcfg: TrainConfig,
                       scfg: DPSyncConfig = DPSyncConfig(),
                       embed_sync: Optional[EmbedSyncConfig] = None,
                       params_shape=None):
    """step(params, opt, residual, batch) -> (params, opt, residual, loss).

    params/opt replicated over dp axes; batch sharded on dim 0; residual is
    per-rank error-feedback state (leading device dim, dp-sharded).  With
    ``embed_sync``, params must be a dict and the named tables' gradients
    are synced sparsely (rows touched only) instead of densely; when also
    compressing (mode onebit/topk), size the residual with
    ``residual_size(params, scfg, exclude=embed_sync.exclude)`` — the
    embedding tables never enter the flattened compressed payload.

    ``embed_sync.zero_opt`` row-shards the tables' AdamW state over the dp
    axes (ZeRO over the vocab dim): the opt in/out specs split dim 0, each
    rank updates only its row slice of the synced gradient, and the
    updated rows all-gather back into the replicated table — trajectory-
    identical to the replicated optimizer (AdamW is elementwise), at 1/P
    the optimizer bytes per device.  Needs ``params_shape`` (an
    ``eval_shape`` of params) to emit the per-leaf opt specs.
    """
    axes = (scfg.intra_axis,) + ((scfg.inter_axis,) if scfg.inter_axis
                                 else ())
    zero_opt = embed_sync is not None and embed_sync.zero_opt
    if zero_opt and params_shape is None:
        raise ValueError("embed_sync.zero_opt needs params_shape")
    compressed = scfg.mode in ("onebit", "topk")
    if compressed:
        csync = compression.make_compressed_sync(
            scfg.mode, axis=scfg.intra_axis,
            block=scfg.block if scfg.mode == "onebit" else scfg.topk_block,
            k=scfg.k, use_kernel=scfg.use_kernel)
    else:
        gsync = hierarchical.make_sync_fn(scfg.mode, scfg.intra_axis,
                                          scfg.inter_axis)
    row_compress = None
    if embed_sync is not None and embed_sync.compress:
        row_compress = embed_update.make_row_compressor(
            embed_sync.compress, embed_sync.k, embed_sync.use_kernel)

    def sync_embed_grads(grads, batch):
        """Pop embedding-table grads; sync rows-touched over all dp axes."""
        emb = {}
        for key, id_fn in embed_sync.id_fns.items():
            emb[key] = embed_update.sparse_row_sync(
                grads[key], id_fn(batch), axes, cap=embed_sync.cap,
                compress=row_compress)
        rest = {k: v for k, v in grads.items()
                if k not in embed_sync.id_fns}
        return emb, rest

    from repro import compat
    world = math.prod(mesh.shape[a] for a in axes)
    tables = tuple(embed_sync.id_fns) if embed_sync else ()
    if zero_opt:
        for key in tables:
            rows = jax.tree.leaves(params_shape[key])[0].shape[0]
            if rows % world:
                raise ValueError(
                    f"zero_opt table {key!r}: {rows} rows do not divide "
                    f"over {world} dp ranks")

    def _flat_rank():
        r = jnp.zeros((), jnp.int32)
        for ax in axes:
            r = r * compat.axis_size(ax) + jax.lax.axis_index(ax)
        return r

    def inner(params, opt, residual, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axes)
        if embed_sync is not None:
            emb_grads, grads = sync_embed_grads(grads, batch)
        if compressed:
            grads, new_res = csync(grads, residual[0])
            if scfg.inter_axis:                     # hierarchy: pods too
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, scfg.inter_axis), grads)
            new_res = new_res[None]
        else:
            grads = gsync(grads)
            new_res = residual
        if embed_sync is not None:
            grads = {**grads, **emb_grads}
        lr = schedule.warmup_cosine(opt["step"], tcfg.learning_rate,
                                    tcfg.warmup_steps, tcfg.steps)
        if not zero_opt:
            new_params, new_opt = adamw.adamw_apply(params, grads, opt, lr,
                                                    tcfg)
            return new_params, new_opt, new_res, loss
        # ZeRO over the vocab dim: this rank updates only its row slice of
        # each table; everything else is replicated as before
        r = _flat_rank()
        for key in tables:
            g = grads[key]
            rows = g.shape[0] // world
            grads = {**grads,
                     key: jax.lax.dynamic_slice_in_dim(g, r * rows, rows, 0)}
        tcfg_eff = tcfg
        if tcfg.grad_clip > 0:
            # global norm with shard-aware accounting (table rows are
            # disjoint per rank; the rest is replicated) so every rank
            # clips by the same scale
            sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                     for k, g in grads.items() if k not in tables)
            sq = sq + jax.lax.psum(
                sum(jnp.sum(jnp.square(grads[k].astype(jnp.float32)))
                    for k in tables), axes)
            scale = jnp.minimum(1.0, tcfg.grad_clip
                                / jnp.maximum(jnp.sqrt(sq), 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
            tcfg_eff = dataclasses.replace(tcfg, grad_clip=0.0)
        new_params, new_opt = adamw.adamw_apply(params, grads, opt, lr,
                                                tcfg_eff)
        # fresh rows all-gather back into the replicated tables (reversed
        # axes order => first listed axis ends up major, matching r)
        for key in tables:
            full = new_params[key]
            for ax in reversed(axes):
                full = jax.lax.all_gather(full, ax, axis=0, tiled=True)
            new_params = {**new_params, key: full}
        return new_params, new_opt, new_res, loss

    dp_spec = P(axes if len(axes) > 1 else axes[0])
    if zero_opt:
        ax_spec = axes if len(axes) > 1 else axes[0]

        def opt_rule(path, leaf):
            top = str(getattr(path[0], "key", ""))
            if top in tables:
                return P(ax_spec, *([None] * (len(leaf.shape) - 1)))
            return P()

        one = jax.tree_util.tree_map_with_path(opt_rule, params_shape)
        opt_specs = {"m": one, "v": one, "master": one, "step": P()}
    else:
        opt_specs = P()
    inner_sm = shard_map(
        inner, mesh=mesh,
        in_specs=(P(), opt_specs, dp_spec, dp_spec),
        out_specs=(P(), opt_specs, dp_spec, P()),
        check_rep=False)
    return jax.jit(inner_sm, donate_argnums=(0, 1, 2))


# ---------------------------------------------------------------------------
# Pipelined DP x TP x stage train step (the unified training-parallelism
# path: planner stage bounds -> 1F1B/GPipe schedule -> manual Megatron TP ->
# composed DP gradient sync)
# ---------------------------------------------------------------------------


def pp_trainable(pp_params, tied: bool):
    """The optimizer's view of the pipeline param tree (drops the pad
    mask, which is layout metadata, not a weight)."""
    t = {"stage": {"blocks": pp_params["stage"]["blocks"]},
         "last": pp_params["last"]}
    if not tied:
        t["embed"] = pp_params["embed"]
    return t


def pp_residual_size(cfg: ArchConfig, pp_params_shape, mesh,
                     scfg: DPSyncConfig,
                     embed_sync: Optional[EmbedSyncConfig] = None) -> int:
    """Flat padded size of one device's compression residual under the
    pipelined step: stage blocks count their LOCAL shard (1/S stages,
    1/tp of each TP-sliced dim), replicated extras count in full, and
    sparse-synced embedding tables are excluded (as in
    :func:`residual_size`)."""
    S = mesh.shape["stage"]
    tp = mesh.shape.get("model", 1)
    specs = sharding_lib.pp_stage_specs(
        cfg, pp_params_shape["stage"], mesh)["blocks"]
    is_p = lambda x: isinstance(x, jax.sharding.PartitionSpec)  # noqa: E731
    spec_leaves = jax.tree.leaves(specs, is_leaf=is_p)
    blk_leaves = jax.tree.leaves(pp_params_shape["stage"]["blocks"])
    n = 0
    for leaf, sp in zip(blk_leaves, spec_leaves):
        n += leaf.size // S // (tp if sharding_lib.spec_has_axis(sp, "model")
                                else 1)
    exclude = tuple(embed_sync.id_fns) if embed_sync else ()
    for key in ("last", "embed"):
        if key in pp_params_shape and key not in exclude:
            n += sum(l.size for l in jax.tree.leaves(pp_params_shape[key]))
    mult = 8 * scfg.block if scfg.mode == "onebit" else scfg.topk_block
    return n + ((-n) % mult)


def make_pp_train_step(cfg: ArchConfig, mesh: Mesh, tcfg: TrainConfig,
                       bounds, pp_params_shape, n_micro: int = 4,
                       pp_schedule: str = "1f1b",
                       scfg: DPSyncConfig = DPSyncConfig(),
                       embed_sync: Optional[EmbedSyncConfig] = None,
                       ctx: Optional[ModelCtx] = None):
    """The full DP x TP x stage pipelined train step, one shard_map.

    step(pp_params, opt, residual, batch) -> (pp_params, opt, residual,
    loss); ``pp_params`` from :func:`transformer.pp_partition_params` at
    the planner's ``bounds``, ``opt`` = ``adamw.init_opt_state`` over the
    trainable view (everything but the pad mask), ``residual`` shaped
    (dp, tp, S, :func:`pp_residual_size`).

    Inside the body: the token embedding runs replicated (its gradient
    arrives through the pipeline's input cotangent), micro-batches pad a
    remainder batch with masked rows, the 1F1B/GPipe executor
    (:func:`repro.core.pipeline.make_pipeline_vag_body`) drives the stage
    axis with Megatron-TP stage bodies over ``model``, TP-partial gradients
    (the replicated norm leaves) are psum'd over ``model``, and the
    existing DP sync stack — flat / hierarchical / onebit / topk plus the
    rows-touched :class:`EmbedSyncConfig` path — runs across ``data``
    exactly as in :func:`make_dp_train_step`.
    """
    S = mesh.shape["stage"]
    tp = mesh.shape.get("model", 1)
    if len(bounds) - 1 != S:
        raise ValueError(f"bounds {bounds} vs stage axis {S}")
    if tp > 1 and cfg.num_heads % tp:
        raise ValueError(f"num_heads {cfg.num_heads} must divide tp {tp}")
    if tp > 1 and cfg.num_kv_heads % tp and \
            (cfg.num_heads // tp) % cfg.num_kv_heads:
        # kv falls back to replication when it doesn't divide; the GQA
        # grouping then needs local q heads divisible by the FULL kv count
        raise ValueError(
            f"tp {tp} leaves {cfg.num_heads // tp} local q heads over "
            f"{cfg.num_kv_heads} replicated kv heads — GQA grouping is "
            f"unexpressible; pick tp with num_kv_heads % tp == 0 or "
            f"(num_heads/tp) % num_kv_heads == 0")
    tied = cfg.tie_embeddings
    if embed_sync is not None and tied:
        raise NotImplementedError(
            "sparse embed sync under pp needs an untied embedding (the "
            "tied table also carries the dense lm-head gradient)")
    ctx = ctx if ctx is not None else ModelCtx(attn_chunk=8)
    stage_fn = tf.make_stage_fn_tp(cfg, ctx)
    last_fn = tf.make_last_fn(cfg, ctx)
    vag_body = pipe_lib.make_pipeline_vag_body(stage_fn, last_fn, S,
                                               n_micro, pp_schedule)

    stage_specs = sharding_lib.pp_stage_specs(cfg, pp_params_shape["stage"],
                                              mesh)
    is_p = lambda x: isinstance(x, P)  # noqa: E731
    has_model = jax.tree.map(
        lambda sp: sharding_lib.spec_has_axis(sp, "model"),
        stage_specs["blocks"], is_leaf=is_p)

    compressed = scfg.mode in ("onebit", "topk")
    if compressed:
        csync = compression.make_compressed_sync(
            scfg.mode, axis=scfg.intra_axis,
            block=scfg.block if scfg.mode == "onebit" else scfg.topk_block,
            k=scfg.k, use_kernel=scfg.use_kernel)
    else:
        gsync = hierarchical.make_sync_fn(scfg.mode, scfg.intra_axis,
                                          scfg.inter_axis)
    row_compress = None
    if embed_sync is not None and embed_sync.compress:
        row_compress = embed_update.make_row_compressor(
            embed_sync.compress, embed_sync.k, embed_sync.use_kernel)
    tcfg_noclip = dataclasses.replace(tcfg, grad_clip=0.0)

    def clip_scale(g):
        """Global-norm clip scale with shard-aware accounting: stage
        blocks psum disjoint shards over (model, stage) — replicated
        leaves (post-psum over model) weighted 1/tp first — while the
        everywhere-replicated extras count once locally."""
        sq = jnp.zeros((), jnp.float32)
        for leaf, hm in zip(jax.tree.leaves(g["stage"]["blocks"]),
                            jax.tree.leaves(has_model)):
            sq = sq + jnp.sum(jnp.square(leaf)) / (1.0 if hm else tp)
        sq = jax.lax.psum(sq, ("model", "stage"))
        for key in ("last", "embed"):
            if key in g:
                sq = sq + sum(jnp.sum(jnp.square(l))
                              for l in jax.tree.leaves(g[key]))
        norm = jnp.sqrt(sq)
        if tcfg.grad_clip <= 0:
            return jnp.ones((), jnp.float32), norm
        return jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(norm, 1e-9)), \
            norm

    def inner(params, opt, residual, batch):
        tokens, targets = batch["tokens"], batch["targets"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones(tokens.shape, jnp.float32)
        emb_tab = params["last"]["embed"] if tied else params["embed"]
        h, emb_vjp = jax.vjp(
            lambda e: layers.embed_tokens(e, tokens), emb_tab)
        x_mic = pipe_lib.microbatch(h, n_micro, pad=True)
        t_mic = pipe_lib.microbatch(targets, n_micro, pad=True)
        m_mic = pipe_lib.microbatch(mask, n_micro, pad=True)
        loss, g_stage, g_last, g_x = vag_body(
            params["stage"], params["last"], x_mic, t_mic, m_mic)
        loss = jax.lax.pmean(loss, scfg.intra_axis)
        # TP: replicated-leaf grads are per-rank partials -> reduce once
        g_blocks = jax.tree.map(
            lambda gl, hm: gl if hm else jax.lax.psum(gl, "model"),
            g_stage["blocks"], has_model)
        # embed grad via the pipeline's input cotangent (pad rows sliced)
        B_loc = tokens.shape[0]
        g_h = g_x.reshape((-1,) + g_x.shape[2:])[:B_loc].astype(h.dtype)
        (g_emb,) = emb_vjp(g_h)
        grads = {"stage": {"blocks": g_blocks}, "last": dict(g_last)}
        if tied:
            grads["last"]["embed"] = grads["last"]["embed"] \
                + g_emb.astype(jnp.float32)
        else:
            grads["embed"] = g_emb.astype(jnp.float32)
        # DP sync across `data`: sparse rows-touched tables first, then
        # the dense/compressed path over the rest
        emb_grads = {}
        if embed_sync is not None:
            for key, id_fn in embed_sync.id_fns.items():
                emb_grads[key] = embed_update.sparse_row_sync(
                    grads[key], id_fn(batch), (scfg.intra_axis,),
                    cap=embed_sync.cap, compress=row_compress)
            grads = {k: v for k, v in grads.items() if k not in emb_grads}
        if compressed:
            grads, new_res = csync(grads, residual[0, 0, 0])
            if scfg.inter_axis:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, scfg.inter_axis), grads)
            new_res = new_res[None, None, None]
        else:
            grads = gsync(grads)
            new_res = residual
        grads = {**grads, **emb_grads}
        scale, _ = clip_scale(grads)
        grads = jax.tree.map(lambda g: g * scale, grads)
        lr = schedule.warmup_cosine(opt["step"], tcfg.learning_rate,
                                    tcfg.warmup_steps, tcfg.steps)
        trainable = pp_trainable(params, tied)
        new_tr, new_opt = adamw.adamw_apply(trainable, grads, opt, lr,
                                            tcfg_noclip)
        new_params = {"stage": {"blocks": new_tr["stage"]["blocks"],
                                "mask": params["stage"]["mask"]},
                      "last": new_tr["last"]}
        if not tied:
            new_params["embed"] = new_tr["embed"]
        return new_params, new_opt, new_res, loss

    param_specs = {"stage": stage_specs,
                   "last": jax.tree.map(lambda _: P(),
                                        pp_params_shape["last"])}
    if not tied:
        param_specs["embed"] = P()
    tr_specs = {"stage": {"blocks": stage_specs["blocks"]},
                "last": param_specs["last"]}
    if not tied:
        tr_specs["embed"] = P()
    opt_specs = {"m": tr_specs, "v": tr_specs, "master": tr_specs,
                 "step": P()}
    res_spec = P(scfg.intra_axis, "model", "stage", None)
    inner_sm = shard_map(
        inner, mesh=mesh,
        in_specs=(param_specs, opt_specs, res_spec, P(scfg.intra_axis)),
        out_specs=(param_specs, opt_specs, res_spec, P()),
        check_rep=False)
    return jax.jit(inner_sm, donate_argnums=(0, 1, 2))


def probe_stage_times(cfg: ArchConfig, pp_params, bounds, ctx=None,
                      batch: int = 2, seq: int = 16, iters: int = 3,
                      jit_cache: Optional[Dict] = None,
                      tracer: Optional[Tracer] = None):
    """Host-measured per-stage forward times over each stage's REAL
    (unpadded) layers — the observe half of the observe->rebalance loop.

    The padded executor runs every stage at the widest stage's layer count
    (masked identity slots), so its own tick times cannot see imbalance;
    the probe instead times each stage's true layer slice, which is what a
    production (unpadded) pipeline — and the analytic bubble model — pays.
    Returns per-stage median seconds over ``iters`` timed calls.

    ``jit_cache`` (a dict the caller keeps alive, e.g.
    :class:`PPRebalancer`'s): reuses one jitted stage program across
    probes, so repeated probing only compiles when a stage's layer count
    first appears — a converged partition probes compile-free.

    ``tracer``: every timed call lands as one ``stage_tick`` span on track
    ``stage{s}`` (args ``stage``/``phase``/``iter``), with the *exact*
    measured duration the returned medians reduce over — so
    :func:`repro.obs.timeline.stage_tick_times` (and
    :func:`repro.core.load_balance.rebalance_from_trace`) recover the
    same per-stage times from the timeline.
    """
    tracer = or_null(tracer)
    ctx = ctx if ctx is not None else ModelCtx(attn_chunk=8)
    bounds = list(bounds)
    blocks = tf.unstack_stage_params(pp_params["stage"], bounds)
    if jit_cache is not None and "fn" in jit_cache:
        fn = jit_cache["fn"]
    else:
        fn = jax.jit(tf.make_stage_fn(cfg, ctx))
        if jit_cache is not None:
            jit_cache["fn"] = fn
    x = jnp.zeros((batch, seq, cfg.d_model),
                  jax.tree.leaves(blocks)[0].dtype)
    times = []
    for s in range(len(bounds) - 1):
        n = bounds[s + 1] - bounds[s]
        sl = jax.tree.map(lambda a: a[bounds[s]:bounds[s + 1]], blocks)
        p = {"blocks": sl, "mask": jnp.ones((n,), jnp.float32)}
        jax.block_until_ready(fn(p, x))                      # compile+warm
        samples = []
        for it in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(p, x))
            t1 = time.perf_counter()
            samples.append(t1 - t0)
            tracer.complete("stage_tick", t0, t1, track=f"stage{s}",
                            stage=s, phase="fwd", iter=it)
        samples.sort()
        times.append(samples[len(samples) // 2])
    return times


class PPRebalancer:
    """Rebalance-in-the-loop for the pipelined train step.

    Every invocation (``train_loop`` calls it every ``rebalance_every``
    steps): probe per-stage times at the current bounds, re-carve the
    layer->stage partition with :func:`repro.core.load_balance.
    rebalance_stages`, and — when the carve points move — live-remap the
    stage params *and* their AdamW moments with
    :func:`repro.models.transformer.remap_stage_params` semantics, then
    rebuild the jitted step for the new bounds.  The model function is
    invariant under the remap (layer order never changes); only the stage
    assignment, pad width, and per-stage cost change.  A compressed-sync
    residual whose flat size changes with the pad width is re-zeroed
    (error feedback restarts warm).
    """

    def __init__(self, cfg: ArchConfig, mesh: Mesh, tcfg: TrainConfig,
                 bounds, n_micro: int = 4, pp_schedule: str = "1f1b",
                 scfg: DPSyncConfig = DPSyncConfig(), ctx=None,
                 probe_batch: int = 2, probe_seq: int = 16,
                 tracer: Optional[Tracer] = None):
        self.cfg, self.mesh, self.tcfg = cfg, mesh, tcfg
        self.bounds = list(bounds)
        self.n_micro, self.pp_schedule, self.scfg = n_micro, pp_schedule, scfg
        self.ctx = ctx
        self.probe_batch, self.probe_seq = probe_batch, probe_seq
        self.history = [list(bounds)]
        self.last_stage_times = None
        self._probe_jit: Dict = {}      # shared stage program across probes
        self.tracer = or_null(tracer)

    def _remap_blocks(self, blocks_tree, new_bounds):
        return tf.remap_stage_params({"blocks": blocks_tree}, self.bounds,
                                     new_bounds)["blocks"]

    def __call__(self, state, step_fn):
        from repro.core import load_balance
        n_stages = len(self.bounds) - 1
        if self.tracer.enabled:
            # with a tracer the rebalancer is a *timeline consumer*: the
            # probe emits stage_tick spans into a probe-local tracer (its
            # own clock domain), the session trace absorbs them, and the
            # stage times come back OUT of the trace — the rebalance
            # decision and the visualized timeline cannot disagree
            probe_tr = Tracer(capacity=4096)
            probe_stage_times(self.cfg, state["params"], self.bounds,
                              self.ctx, self.probe_batch, self.probe_seq,
                              jit_cache=self._probe_jit, tracer=probe_tr)
            self.tracer.extend(probe_tr.events)
            times = obs_timeline.stage_tick_times(probe_tr.events, n_stages)
        else:
            times = probe_stage_times(self.cfg, state["params"], self.bounds,
                                      self.ctx, self.probe_batch,
                                      self.probe_seq,
                                      jit_cache=self._probe_jit)
        self.last_stage_times = times
        new_bounds = load_balance.rebalance_stages(times, self.bounds)
        self.tracer.instant(
            "rebalance.decision", track="train",
            old_bounds=list(self.bounds), new_bounds=list(new_bounds),
            stage_times=[float(t) for t in times],
            changed=new_bounds != self.bounds)
        if new_bounds == self.bounds:
            return None
        params = dict(state["params"])
        params["stage"] = tf.remap_stage_params(params["stage"],
                                                self.bounds, new_bounds)
        opt = dict(state["opt"])
        for key in ("m", "v", "master"):
            if key in opt and "stage" in opt[key]:
                moment = dict(opt[key])
                moment["stage"] = {"blocks": self._remap_blocks(
                    opt[key]["stage"]["blocks"], new_bounds)}
                opt[key] = moment
        new_state = {**state, "params": params, "opt": opt,
                     "stage_bounds": jnp.asarray(new_bounds, jnp.int32)}
        pp_shape = jax.eval_shape(lambda: params)
        if "residual" in state:
            # always restart error feedback: even at an unchanged flat
            # size, moving the carve point re-aligns residual entries to
            # different layers' gradients
            n_res = pp_residual_size(self.cfg, pp_shape, self.mesh,
                                     self.scfg)
            new_state["residual"] = jnp.zeros(
                state["residual"].shape[:-1] + (n_res,),
                state["residual"].dtype)
        new_step = make_pp_train_step(
            self.cfg, self.mesh, self.tcfg, new_bounds, pp_shape,
            n_micro=self.n_micro, pp_schedule=self.pp_schedule,
            scfg=self.scfg, ctx=self.ctx)
        self.bounds = new_bounds
        self.history.append(list(new_bounds))
        return new_state, new_step


def make_update_rule(tcfg: TrainConfig):
    """The trainer's shared optimizer plumbing (AdamW + warmup-cosine LR),
    packaged so other training simulators — :mod:`repro.core.async_dp`'s
    sync/async parameter-server models — step parameters through exactly
    the update rule the real train steps use.

    Returns (init, apply): ``init(params) -> opt``;
    ``apply(params, opt, grads, lr_scale=1.0) -> (params, opt)`` where
    ``lr_scale`` is the per-update multiplier hooks like delay
    compensation (Eq. 12's 1/(1+tau)) plug into.
    """

    def init(params):
        return adamw.init_opt_state(params)

    def apply(params, opt, grads, lr_scale=1.0):
        lr = schedule.warmup_cosine(opt["step"], tcfg.learning_rate,
                                    tcfg.warmup_steps, tcfg.steps)
        return adamw.adamw_apply(params, grads, opt, lr * lr_scale, tcfg)

    return init, apply


# ---------------------------------------------------------------------------
# Training loop with checkpoint/restart
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: list
    throughput: float               # samples/sec (host wall clock)


def train_loop(state: Dict[str, Any], batches: Iterator, step_fn: Callable,
               tcfg: TrainConfig, *, start_step: int = 0,
               tokens_per_batch: int = 0, samples_per_batch: int = 0,
               fail_at: Optional[int] = None,
               rebalance_every: int = 0,
               rebalance_fn: Optional[Callable] = None,
               log_every: int = 10, verbose: bool = False,
               tracer: Optional[Tracer] = None) -> TrainResult:
    """Generic loop: state = {'params', 'opt', ['residual']}.

    ``fail_at``: inject a simulated node failure (raises RuntimeError) after
    that step commits — the fault-tolerance tests restart from checkpoint.

    ``rebalance_every`` / ``rebalance_fn``: close the observe->rebalance
    loop in-training.  Every K committed steps the loop calls
    ``rebalance_fn(state, step_fn)``; a ``None`` return keeps the current
    partition, otherwise the returned ``(state, step_fn)`` — e.g. from
    :class:`PPRebalancer`, which re-carves the pipeline's layer->stage
    bounds from measured per-stage times — replaces both for the steps
    that follow.

    ``tracer``: per-step ``train_step`` spans (host wall clock, args
    ``step``/``loss``), ``rebalance.probe`` spans around each rebalance
    hook, and ``checkpoint`` spans — the training half of the unified
    timeline (``launch/train.py --trace-out``).
    """
    tr = or_null(tracer)
    losses = []
    t0 = time.perf_counter()
    step = start_step
    n = 0
    for batch in batches:
        if rebalance_every and rebalance_fn is not None and n > 0 \
                and n % rebalance_every == 0:
            with tr.span("rebalance.probe", track="train", step=step):
                new = rebalance_fn(state, step_fn)
            if new is not None:
                state, step_fn = new
                if verbose:
                    print(f"step {step}: rebalanced "
                          f"(bounds {getattr(rebalance_fn, 'bounds', '?')})")
        with tr.span("train_step", track="train", step=step) as sp:
            if "residual" in state:
                state["params"], state["opt"], state["residual"], loss = \
                    step_fn(state["params"], state["opt"],
                            state["residual"], batch)
                metrics = {"loss": loss}
            else:
                state["params"], state["opt"], metrics = step_fn(
                    state["params"], state["opt"], batch)
            losses.append(float(metrics["loss"]))
            if tr.enabled:
                sp.args["loss"] = losses[-1]
        step += 1
        n += 1
        if verbose and step % log_every == 0:
            print(f"step {step}: loss {losses[-1]:.4f}")
        if tcfg.checkpoint_every and step % tcfg.checkpoint_every == 0:
            with tr.span("checkpoint", track="train", step=step):
                ckpt.save(tcfg.checkpoint_dir, step,
                          {"params": state["params"], "opt": state["opt"],
                           **({"residual": state["residual"]}
                              if "residual" in state else {}),
                           # a rebalanced pipeline's carve points must ride
                           # along: restore rebuilds the step at THESE
                           # bounds
                           **({"stage_bounds": state["stage_bounds"]}
                              if "stage_bounds" in state else {})},
                          keep=tcfg.keep_checkpoints)
        if fail_at is not None and step >= fail_at:
            raise RuntimeError(f"injected failure at step {step}")
    dt = time.perf_counter() - t0
    tput = samples_per_batch * n / dt if dt > 0 else 0.0
    return TrainResult(steps_run=n, final_step=step, losses=losses,
                       throughput=tput)


def resume_or_init(init_state: Dict[str, Any], tcfg: TrainConfig,
                   shardings=None) -> Tuple[int, Dict[str, Any]]:
    """Restore the latest valid checkpoint (fault tolerance) or start fresh."""
    step, tree = ckpt.restore_latest(tcfg.checkpoint_dir, init_state,
                                     shardings)
    if step is None:
        return 0, init_state
    return step, tree
