"""Training runtime: hybrid-parallel (GSPMD) and DP-shard_map train steps,
checkpoint/restart fault tolerance, and the training loop.

Two step builders:

* ``make_hybrid_train_step`` — the production path: jit with in/out shardings
  from the ``ShardingPlan`` (TP over ``model``, DP over ``data``/``pod``,
  ZeRO-1 optimizer state, optional remat + Megatron-SP).  Gradient sync is
  GSPMD-emitted (hierarchical across pods by construction of the mesh).
* ``make_dp_train_step`` — the paper's explicit DP path (its 8-GPU setup):
  the whole step runs inside shard_map over the dp axes with *manual*
  gradient sync: flat ring all-reduce (Eq. 8), hierarchical all-reduce (C5),
  or compressed all-gather with error feedback (C6, Eq. 10–11).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.checkpoint import manager as ckpt
from repro.config import ArchConfig, ParallelConfig, TrainConfig
from repro.core import compression, hierarchical
from repro.core.hybrid import Plan
from repro.embeddings import update as embed_update
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx
from repro.optimizer import adamw, schedule


# ---------------------------------------------------------------------------
# Hybrid (GSPMD) train step — production path
# ---------------------------------------------------------------------------

def make_hybrid_train_step(cfg: ArchConfig, plan: Plan, tcfg: TrainConfig,
                           loss_fn: Optional[Callable] = None,
                           donate: bool = True):
    """Returns (step_fn, shardings) — step_fn(params, opt, batch) ->
    (params, opt, metrics)."""
    sh = plan.sharding
    tp_n = sh.mesh.shape.get("model", 1)
    ctx = ModelCtx(remat=plan.remat, constrain=sh.constrain,
                   flash_vjp=sh.dp_heavy or tp_n == 1)
    if loss_fn is None:
        loss_fn = lambda p, b: tf.loss_fn(cfg, p, b, ctx)  # noqa: E731

    accum = max(plan.pcfg.microbatches, 1)

    def _grads(params, batch):
        if accum == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: scan over microbatches (batch dim 0 split),
        # grads accumulated in f32 — memory ~1/accum of the monolithic step
        mb = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def one(carry, b):
            g_acc, l_acc = carry
            (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, b)
            g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                 g_acc, g)
            return (g_acc, l_acc + loss), aux

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss), auxs = jax.lax.scan(one, (g0, jnp.zeros((), jnp.float32)),
                                       mb)
        g = jax.tree.map(lambda x: x / accum, g)
        aux = jax.tree.map(lambda a: jnp.mean(a, axis=0), auxs)
        return (loss / accum, aux), g

    def step(params, opt, batch):
        lr = schedule.warmup_cosine(opt["step"], tcfg.learning_rate,
                                    tcfg.warmup_steps, tcfg.steps)
        (loss, aux), grads = _grads(params, batch)
        # ZeRO-2: reduce-scatter gradients onto the optimizer-state sharding
        # (dp axes added) so full model-sharded-only gradients never
        # materialize — each dp rank only holds the shard it will update.
        gspecs = sh.opt_specs(cfg, jax.tree.map(
            lambda g: jax.ShapeDtypeStruct(g.shape, g.dtype), grads))
        grads = jax.tree.map(
            lambda g, sp: jax.lax.with_sharding_constraint(g, sh.named(sp)),
            grads, gspecs)
        new_params, new_opt = adamw.adamw_apply(params, grads, opt, lr, tcfg)
        metrics = {"loss": loss, "lr": lr,
                   "grad_norm": adamw.global_norm(grads)}
        return new_params, new_opt, metrics

    def shardings_for(params_shape, batch_shape):
        pspec = sh.param_specs(cfg, params_shape)
        ospec = {"m": sh.opt_specs(cfg, params_shape),
                 "v": sh.opt_specs(cfg, params_shape),
                 "master": sh.opt_specs(cfg, params_shape),
                 "step": P()}
        bspec = sh.batch_specs(batch_shape)
        to_named = lambda t: jax.tree.map(sh.named, t,  # noqa: E731
                                          is_leaf=lambda x: isinstance(x, P))
        return to_named(pspec), to_named(ospec), to_named(bspec)

    def jitted(params_shape, batch_shape):
        psh, osh, bsh = shardings_for(params_shape, batch_shape)
        return jax.jit(
            step,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1) if donate else (),
        )

    return step, jitted, shardings_for


# ---------------------------------------------------------------------------
# DP shard_map train step — the paper's explicit path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPSyncConfig:
    mode: str = "flat"              # flat | hierarchical | onebit | topk
    intra_axis: str = "data"
    inter_axis: Optional[str] = None
    block: int = 512
    topk_block: int = 2048
    k: int = 32
    use_kernel: bool = True


@dataclasses.dataclass(frozen=True)
class EmbedSyncConfig:
    """Rows-touched sparse sync for embedding-table gradients.

    ``id_fns`` maps top-level param keys (the embedding tables) to
    ``batch -> ids`` extractors; those tables' gradients skip the dense
    all-reduce (and the compressed flatten path) and are exchanged as
    (unique ids, gradient rows) all-gathers instead — wire bytes scale
    with the batch, not the vocab.  ``compress="topk"`` additionally
    sparsifies each exchanged row via the Pallas top-k kernel.
    """

    id_fns: Dict[str, Callable[[Dict], jnp.ndarray]]
    # unique-id cap (default: len(ids)).  Must be >= the max unique ids a
    # rank's batch can touch: an undersized cap silently truncates the
    # exchanged row set and the dropped rows get ZERO gradient.
    cap: Optional[int] = None
    compress: Optional[str] = None  # None | "topk"
    k: int = 8
    use_kernel: bool = True

    @property
    def exclude(self) -> Tuple[str, ...]:
        """Param keys outside the dense/compressed sync path — pass to
        ``residual_size(params, scfg, exclude=...)`` when compressing."""
        return tuple(self.id_fns)


def residual_size(params, scfg: DPSyncConfig,
                  exclude: Tuple[str, ...] = ()) -> int:
    """Flat padded size of the compression error-feedback state.  Params
    under top-level keys in ``exclude`` (sparse-synced embedding tables)
    carry no residual — their sync is outside the compressed path."""
    if exclude:
        params = {k: v for k, v in params.items() if k not in exclude}
    n = sum(l.size for l in jax.tree.leaves(params))
    mult = 8 * scfg.block if scfg.mode == "onebit" else scfg.topk_block
    return n + ((-n) % mult)


def make_dp_train_step(loss_fn: Callable, mesh: Mesh, tcfg: TrainConfig,
                       scfg: DPSyncConfig = DPSyncConfig(),
                       embed_sync: Optional[EmbedSyncConfig] = None):
    """step(params, opt, residual, batch) -> (params, opt, residual, loss).

    params/opt replicated over dp axes; batch sharded on dim 0; residual is
    per-rank error-feedback state (leading device dim, dp-sharded).  With
    ``embed_sync``, params must be a dict and the named tables' gradients
    are synced sparsely (rows touched only) instead of densely; when also
    compressing (mode onebit/topk), size the residual with
    ``residual_size(params, scfg, exclude=embed_sync.exclude)`` — the
    embedding tables never enter the flattened compressed payload.
    """
    axes = (scfg.intra_axis,) + ((scfg.inter_axis,) if scfg.inter_axis
                                 else ())
    compressed = scfg.mode in ("onebit", "topk")
    if compressed:
        csync = compression.make_compressed_sync(
            scfg.mode, axis=scfg.intra_axis,
            block=scfg.block if scfg.mode == "onebit" else scfg.topk_block,
            k=scfg.k, use_kernel=scfg.use_kernel)
    else:
        gsync = hierarchical.make_sync_fn(scfg.mode, scfg.intra_axis,
                                          scfg.inter_axis)
    row_compress = None
    if embed_sync is not None and embed_sync.compress:
        row_compress = embed_update.make_row_compressor(
            embed_sync.compress, embed_sync.k, embed_sync.use_kernel)

    def sync_embed_grads(grads, batch):
        """Pop embedding-table grads; sync rows-touched over all dp axes."""
        emb = {}
        for key, id_fn in embed_sync.id_fns.items():
            emb[key] = embed_update.sparse_row_sync(
                grads[key], id_fn(batch), axes, cap=embed_sync.cap,
                compress=row_compress)
        rest = {k: v for k, v in grads.items()
                if k not in embed_sync.id_fns}
        return emb, rest

    def inner(params, opt, residual, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axes)
        if embed_sync is not None:
            emb_grads, grads = sync_embed_grads(grads, batch)
        if compressed:
            grads, new_res = csync(grads, residual[0])
            if scfg.inter_axis:                     # hierarchy: pods too
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, scfg.inter_axis), grads)
            new_res = new_res[None]
        else:
            grads = gsync(grads)
            new_res = residual
        if embed_sync is not None:
            grads = {**grads, **emb_grads}
        lr = schedule.warmup_cosine(opt["step"], tcfg.learning_rate,
                                    tcfg.warmup_steps, tcfg.steps)
        new_params, new_opt = adamw.adamw_apply(params, grads, opt, lr, tcfg)
        return new_params, new_opt, new_res, loss

    dp_spec = P(axes if len(axes) > 1 else axes[0])
    inner_sm = shard_map(
        inner, mesh=mesh,
        in_specs=(P(), P(), dp_spec, dp_spec),
        out_specs=(P(), P(), dp_spec, P()),
        check_rep=False)
    return jax.jit(inner_sm, donate_argnums=(0, 1, 2))


# ---------------------------------------------------------------------------
# Training loop with checkpoint/restart
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: list
    throughput: float               # samples/sec (host wall clock)


def train_loop(state: Dict[str, Any], batches: Iterator, step_fn: Callable,
               tcfg: TrainConfig, *, start_step: int = 0,
               tokens_per_batch: int = 0, samples_per_batch: int = 0,
               fail_at: Optional[int] = None,
               log_every: int = 10, verbose: bool = False) -> TrainResult:
    """Generic loop: state = {'params', 'opt', ['residual']}.

    ``fail_at``: inject a simulated node failure (raises RuntimeError) after
    that step commits — the fault-tolerance tests restart from checkpoint.
    """
    losses = []
    t0 = time.perf_counter()
    step = start_step
    n = 0
    for batch in batches:
        if "residual" in state:
            state["params"], state["opt"], state["residual"], loss = step_fn(
                state["params"], state["opt"], state["residual"], batch)
            metrics = {"loss": loss}
        else:
            state["params"], state["opt"], metrics = step_fn(
                state["params"], state["opt"], batch)
        step += 1
        n += 1
        losses.append(float(metrics["loss"]))
        if verbose and step % log_every == 0:
            print(f"step {step}: loss {losses[-1]:.4f}")
        if tcfg.checkpoint_every and step % tcfg.checkpoint_every == 0:
            ckpt.save(tcfg.checkpoint_dir, step,
                      {"params": state["params"], "opt": state["opt"],
                       **({"residual": state["residual"]}
                          if "residual" in state else {})},
                      keep=tcfg.keep_checkpoints)
        if fail_at is not None and step >= fail_at:
            raise RuntimeError(f"injected failure at step {step}")
    dt = time.perf_counter() - t0
    tput = samples_per_batch * n / dt if dt > 0 else 0.0
    return TrainResult(steps_run=n, final_step=step, losses=losses,
                       throughput=tput)


def resume_or_init(init_state: Dict[str, Any], tcfg: TrainConfig,
                   shardings=None) -> Tuple[int, Dict[str, Any]]:
    """Restore the latest valid checkpoint (fault tolerance) or start fresh."""
    step, tree = ckpt.restore_latest(tcfg.checkpoint_dir, init_state,
                                     shardings)
    if step is None:
        return 0, init_state
    return step, tree
