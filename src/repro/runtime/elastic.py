"""Elastic scaling: rebuild the mesh after membership changes and reshard
live state onto it (paper §V.B: 'dynamic expansion ... maintaining training
continuity when nodes decrease').

Checkpoints are topology-free (full logical arrays), so restore-onto-new-mesh
is just ``device_put`` with the new plan's shardings; live-state resharding
works the same way without a round-trip to disk.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh_for(n_devices: int, *, model: int = 1,
                  axis_names: Tuple[str, str] = ("data", "model"),
                  devices: Optional[Sequence] = None) -> Mesh:
    """Largest (data, model) mesh that fits the surviving device set."""
    devs = list(devices if devices is not None else jax.devices())[:n_devices]
    data = len(devs) // model
    devs = devs[:data * model]
    arr = np.asarray(devs).reshape(data, model)
    return Mesh(arr, axis_names)


def reshard(tree: Any, shardings: Any) -> Any:
    """Reshard a pytree of (possibly sharded) arrays onto new shardings.
    Works across dp-degree changes because every array is logically global."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)


def shrink_batch(batch: Any, new_dp: int, old_dp: int) -> Any:
    """Trim the global batch so it divides the surviving dp degree."""
    def fix(x):
        b = x.shape[0]
        nb = (b // new_dp) * new_dp
        return x[:nb]
    return jax.tree.map(fix, batch)
