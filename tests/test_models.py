"""Per-architecture smoke tests (reduced configs, CPU) + decode parity.

Every assigned arch instantiates a reduced same-family config, runs one
forward/train step asserting output shapes and finite values, and (for the
decode families) checks prefill-vs-decode consistency.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.config import get_arch, list_archs, reduced
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx

ARCHS = [a for a in list_archs()]
CTX = ModelCtx(attn_chunk=8, mamba_chunk=4, moe_group=8)
# decode parity needs drop-free MoE (capacity drops are batch-dependent)
CTX_NODROP = ModelCtx(attn_chunk=8, mamba_chunk=4, moe_group=8,
                      moe_capacity_factor=64.0)


def make_batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)),
                               jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.pos_type == "mrope":
        s_img = int(cfg.image_prefix_frac * S)
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, s_img, cfg.d_model)), jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCHS:
        cfg = dataclasses.replace(reduced(get_arch(name)), dtype="float32")
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        out[name] = (cfg, params)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_loss(built, name):
    cfg, params = built[name]
    batch = make_batch(cfg)
    logits, aux, _ = tf.forward(cfg, params, batch, CTX)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = tf.loss_fn(cfg, params, batch, CTX)
    assert np.isfinite(float(loss))
    if cfg.is_moe:
        # every token routes k experts
        assert float(jnp.sum(aux["expert_load"])) > 0


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step_no_nans(built, name):
    cfg, params = built[name]
    batch = make_batch(cfg)
    grads = jax.grad(lambda p: tf.loss_fn(cfg, p, batch, CTX)[0])(params)
    new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                       params, grads)
    loss2, _ = tf.loss_fn(cfg, new, batch, CTX)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(built, name):
    """Teacher-forced decode reproduces the full-sequence forward logits."""
    cfg, params = built[name]
    B, S = 2, 12
    batch = make_batch(cfg, B, S)
    if cfg.pos_type == "mrope":
        pytest.skip("vlm decode positions tested separately")
    ctx = CTX_NODROP if cfg.is_moe else CTX
    logits_full, _, _ = tf.forward(cfg, params, batch, ctx)

    cache = tf.init_cache(cfg, B, S)
    if cfg.encoder_layers:
        ck, cv = tf.whisper_prefill_cross(cfg, params, batch["frames"], CTX)
        cache["cross_k"], cache["cross_v"] = ck, cv
    outs = []
    for t in range(S):
        tok = batch["tokens"][:, t:t + 1]
        lg, cache = tf.decode_step(cfg, params, cache, tok, ctx)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert_allclose(np.asarray(dec, np.float32),
                    np.asarray(logits_full, np.float32),
                    atol=2e-3, rtol=2e-3)


def test_vlm_decode_runs(built):
    cfg, params = built["qwen2-vl-2b"]
    cache = tf.init_cache(cfg, 2, 8)
    pos = jnp.zeros((2, 1, 3), jnp.int32)
    lg, cache = tf.decode_step(cfg, params, cache,
                               jnp.ones((2, 1), jnp.int32), CTX,
                               positions=pos)
    assert np.isfinite(np.asarray(lg)).all()
    assert int(cache["len"][0]) == 1


def test_gemma_ring_buffer_window():
    """Local-attention ring cache gives same result as full cache once the
    window is the only visible context."""
    cfg = dataclasses.replace(reduced(get_arch("gemma3-1b")),
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 1, 14
    batch = make_batch(cfg, B, S)
    logits_full, _, _ = tf.forward(cfg, params, batch, CTX)
    cache = tf.init_cache(cfg, B, S)
    for t in range(S):
        lg, cache = tf.decode_step(cfg, params, cache,
                                   batch["tokens"][:, t:t + 1], CTX)
    # ring caches must be window-sized
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        if kind == "local_attn":
            assert cache["k"][i].shape[1] == cfg.sliding_window
    assert_allclose(np.asarray(lg[:, 0]),
                    np.asarray(logits_full[:, -1]), atol=2e-3, rtol=2e-3)


def test_whisper_encoder_shapes(built):
    cfg, params = built["whisper-medium"]
    frames = jnp.ones((2, cfg.encoder_frames, cfg.d_model), jnp.float32)
    enc = tf.whisper_encode(cfg, params, frames, CTX)
    assert enc.shape == (2, cfg.encoder_frames, cfg.d_model)
    assert np.isfinite(np.asarray(enc)).all()
