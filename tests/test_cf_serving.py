"""CF head serving: hot-row cache exactness at every sharding plan,
rows-touched refresh semantics, traffic candidate streams, engine
integration, and the cf_lookup_bytes comms model."""
import numpy as np
import pytest

from repro import compat
from repro.embeddings import (CacheConfig, CachedLookup, EmbedSpec,
                              FreqTracker, HotRowCache, init_table,
                              make_plan)
from repro.obs import MetricsRegistry, Tracer
from repro.serving import (CFHead, Clock, EngineConfig, ServingEngine,
                           TrafficConfig, cf_lookup_bytes, generate)

import jax

PLAN_KINDS = ["replicated", "row", "col", "row_col"]


@pytest.fixture(scope="module")
def mesh():
    # trivial 1x1 mesh: exercises every plan's shard_map code path
    # in-process without multi-device requirements.
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def table():
    spec = EmbedSpec("cf_item", rows=96, dim=16)
    return spec, np.asarray(init_table(jax.random.PRNGKey(0), spec))


def _zipf_ids(n, rows, seed=0, a=1.3):
    rng = np.random.default_rng(seed)
    return np.clip(rng.zipf(a, size=n), 1, rows) - 1


# ---------------------------------------------------------------------------
# FreqTracker / HotRowCache mechanics
# ---------------------------------------------------------------------------

def test_freq_tracker_decayed_counts_rank_hot_rows_first():
    tr = FreqTracker(16, decay=0.5)
    tr.observe(np.array([3, 3, 3, 7]))
    top = tr.top_k(2)
    assert top[0] == 3 and set(top) == {3, 7}
    # decay: old mass fades, fresh traffic takes over
    for _ in range(12):
        tr.observe(np.array([9]))
    assert tr.top_k(1)[0] == 9
    # top_k never returns never-seen rows, even with spare capacity
    assert set(tr.top_k(16)) <= {3, 7, 9}


def test_hot_row_cache_refresh_is_incremental(table):
    spec, host = table
    cache = HotRowCache(spec.rows, capacity=4)
    cache.tracker.observe(np.array([1, 2, 3]))
    cache.refresh(host)
    stale = host.copy()
    stale[2] += 1.0                      # host moves on; cache holds old bytes
    cache.tracker.observe(np.array([2, 3, 5]))
    cache.refresh(stale)                 # 1,2,3 kept; 5 newly elected
    hit, slots = cache.plan_lookup(np.array([2, 5]))
    assert hit.all()
    np.testing.assert_array_equal(cache.rows[slots[0]], host[2])   # stale kept
    np.testing.assert_array_equal(cache.rows[slots[1]], stale[5])  # fresh read
    cache.refresh_touched(np.array([2]), stale)
    hit, slots = cache.plan_lookup(np.array([2]))
    np.testing.assert_array_equal(cache.rows[slots[0]], stale[2])


# ---------------------------------------------------------------------------
# CachedLookup: cached == uncached bit-for-bit at every plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", PLAN_KINDS)
def test_cached_lookup_exact_at_every_plan(mesh, table, kind):
    spec, host = table
    plan = make_plan(kind)
    ids = _zipf_ids(256, spec.rows)
    cached = CachedLookup(spec, plan, host, mesh=mesh,
                          cache=CacheConfig(rows=24))
    uncached = CachedLookup(spec, plan, host, mesh=mesh)
    for lo in range(0, len(ids), 32):
        chunk = ids[lo:lo + 32]
        rows_c, _ = cached(chunk)
        rows_u, _ = uncached(chunk)
        np.testing.assert_array_equal(rows_c, rows_u)
        np.testing.assert_array_equal(rows_u, host[chunk])
    assert cached.hits > 0 and cached.hit_rate > 0.5
    assert uncached.hits == 0


@pytest.mark.parametrize("kind", PLAN_KINDS)
def test_update_rows_touched_refresh_restores_parity(mesh, table, kind):
    spec, host = table
    lk = CachedLookup(spec, make_plan(kind), host, mesh=mesh,
                      cache=CacheConfig(rows=24))
    ids = _zipf_ids(128, spec.rows, seed=3)
    lk(ids)                                       # warm the cache
    hot = np.asarray(lk.cache.ids)
    assert hot.size > 0
    new_rows = np.full((hot.size, spec.dim), 7.5, np.float32)

    # refresh=False: the replica serves stale bytes for cached rows —
    # staleness persists across lookups/elections (election is incremental)
    stale = CachedLookup(spec, make_plan(kind), host, mesh=mesh,
                         cache=CacheConfig(rows=24))
    stale(ids)
    stale.update_rows(hot, new_rows, refresh=False)
    got, _ = stale(hot)
    assert not np.array_equal(got, new_rows)
    # rows-touched refresh restores exactness
    stale.refresh_touched(hot)
    got, _ = stale(hot)
    np.testing.assert_array_equal(got, new_rows)

    # refresh=True (the default) is exact immediately
    touched = lk.update_rows(hot, new_rows)
    assert set(np.asarray(touched).tolist()) == set(hot.tolist())
    got, _ = lk(hot)
    np.testing.assert_array_equal(got, new_rows)


# ---------------------------------------------------------------------------
# traffic: candidate sets
# ---------------------------------------------------------------------------

def test_candidates_leave_base_workload_unperturbed():
    base_cfg = TrafficConfig(n_requests=32, vocab_size=64, seed=5)
    with_cand = generate(TrafficConfig(n_requests=32, vocab_size=64, seed=5,
                                       candidates=8))
    base = generate(base_cfg)
    assert all(r.candidates is None for r in base)
    for b, c in zip(base, with_cand):
        assert len(c.candidates) == 8
        assert all(0 <= i < 64 for i in c.candidates)
        assert (b.prompt, b.user_id, b.arrival, b.max_new_tokens, b.slo,
                b.temperature) == (c.prompt, c.user_id, c.arrival,
                                   c.max_new_tokens, c.slo, c.temperature)
    # deterministic under the seed
    again = generate(TrafficConfig(n_requests=32, vocab_size=64, seed=5,
                                   candidates=8))
    assert [r.candidates for r in again] == [r.candidates for r in with_cand]


def test_candidate_sets_are_head_heavy():
    reqs = generate(TrafficConfig(n_requests=64, vocab_size=256,
                                  candidates=16, zipf_items=1.3))
    ids = np.concatenate([np.asarray(r.candidates) for r in reqs])
    head = (ids < 26).mean()              # top 10% of the item vocab
    assert head > 0.5, head


# ---------------------------------------------------------------------------
# engine integration: scores + tokens identical cached vs uncached
# ---------------------------------------------------------------------------

class _ToyBackend:
    """Deterministic toy: next token = (last token + 1) mod V."""
    V = 64

    def init_cache(self, n_slots, max_len):
        return {"len": np.zeros(n_slots, np.int64)}

    def prefill(self, cache, tokens, true_len, slot):
        logits = np.zeros(self.V, np.float32)
        logits[(int(tokens[0, true_len - 1]) + 1) % self.V] = 1.0
        return logits, cache

    def decode(self, cache, tokens):
        B = tokens.shape[0]
        logits = np.zeros((B, 1, self.V), np.float32)
        for b in range(B):
            logits[b, 0, (int(tokens[b, 0]) + 1) % self.V] = 1.0
        return logits, cache


def _run(reqs, cf_head, tracer=None, metrics=None):
    engine = ServingEngine(_ToyBackend(), EngineConfig(n_slots=4, max_len=64),
                           Clock(0.01, 0.05, None, 0.002),
                           tracer=tracer, metrics=metrics, cf_head=cf_head)
    outputs, recs, summary = engine.run(reqs)
    return engine, outputs, recs, summary


@pytest.mark.parametrize("kind", PLAN_KINDS)
def test_engine_cf_scores_exact_cached_vs_uncached(mesh, kind):
    reqs = generate(TrafficConfig(n_requests=16, rate=200.0, vocab_size=64,
                                  n_users=100, candidates=12, prompt_max=16))
    heads = {rows: CFHead.build(n_users=100, n_items=64, cf_dim=8, plan=kind,
                                cache_rows=rows, mesh=mesh)
             for rows in (0, 32)}
    runs = {rows: _run(reqs, head) for rows, head in heads.items()}
    eng_c, out_c, _, s_c = runs[32]
    eng_u, out_u, _, s_u = runs[0]
    assert out_c == out_u                     # token streams untouched
    assert s_c["cf"]["requests_scored"] == len(reqs)
    assert s_c["cf"]["hit_rate"] > 0.5
    assert s_u["cf"]["hits"] == 0
    for rid in eng_u.cf_results:
        rc, ru = eng_c.cf_results[rid], eng_u.cf_results[rid]
        np.testing.assert_array_equal(rc["cf"], ru["cf"])
        np.testing.assert_array_equal(rc["fused"], ru["fused"])
        np.testing.assert_array_equal(rc["ranking"], ru["ranking"])
        assert set(rc["ranking"]) == set(reqs[rid].candidates)


def test_engine_cf_obs_spans_and_counters(mesh):
    reqs = generate(TrafficConfig(n_requests=12, rate=200.0, vocab_size=64,
                                  n_users=100, candidates=8, prompt_max=16))
    tracer, metrics = Tracer(), MetricsRegistry()
    head = CFHead.build(n_users=100, n_items=64, cf_dim=8, plan="row",
                        cache_rows=24, mesh=mesh)
    _, _, recs, summary = _run(reqs, head, tracer=tracer, metrics=metrics)

    counters = metrics.snapshot()["counters"]
    assert counters["cf_cache.hits"] + counters["cf_cache.misses"] \
        == head.hits + head.misses
    assert "cf.lookup" in tracer.span_names()

    # cf time lands inside req.prefill, so ttft_reconciled stays green
    spans = {}
    for e in tracer.events:
        if e.get("ph") == "X" and "rid" in e.get("args", {}):
            spans.setdefault(e["args"]["rid"], {})[e["name"]] = e
    for r in recs:
        if r.finished is None:
            continue
        sp = spans[r.rid]
        cf, pf = sp["cf.lookup"], sp["req.prefill"]
        assert pf["ts"] <= cf["ts"]
        assert cf["ts"] + cf["dur"] <= pf["ts"] + pf["dur"] + 1e-9
        ttft = sp["req.queue_wait"]["dur"] + pf["dur"]
        assert ttft == pytest.approx(r.ttft, abs=1e-9)


def test_engine_without_candidates_skips_cf(mesh):
    reqs = generate(TrafficConfig(n_requests=6, rate=200.0, vocab_size=64,
                                  prompt_max=16))
    head = CFHead.build(n_users=100, n_items=64, cf_dim=8, mesh=mesh)
    engine, _, _, summary = _run(reqs, head)
    assert engine.cf_results == {}
    assert summary["cf"]["requests_scored"] == 0


# ---------------------------------------------------------------------------
# roofline: cf_lookup_bytes comms model
# ---------------------------------------------------------------------------

def test_cf_lookup_bytes_model():
    spec = EmbedSpec("cf_item", rows=1024, dim=32)
    mesh_shape = {"data": 2, "model": 4}
    for kind in ("row", "col", "row_col"):
        m = cf_lookup_bytes(spec, make_plan(kind), mesh_shape, batch=17,
                            hit_rate=0.6)
        assert m["uncached_bytes"] > 0
        assert m["cached_bytes"] == pytest.approx(0.4 * m["uncached_bytes"])
        assert m["saved_frac"] == pytest.approx(0.6)
        z = cf_lookup_bytes(spec, make_plan(kind), mesh_shape, batch=17)
        assert z["cached_bytes"] == z["uncached_bytes"]
    rep = cf_lookup_bytes(spec, make_plan("replicated"), mesh_shape,
                          batch=17, hit_rate=0.6)
    assert rep["uncached_bytes"] == 0 and rep["cached_bytes"] == 0
    # row+col plan exchanges at least as much as either single-axis plan
    row = cf_lookup_bytes(spec, make_plan("row"), mesh_shape, 17)
    both = cf_lookup_bytes(spec, make_plan("row_col"), mesh_shape, 17)
    assert both["uncached_bytes"] > 0 and row["uncached_bytes"] > 0
    with pytest.raises(ValueError):
        cf_lookup_bytes(spec, make_plan("row"), mesh_shape, 17, hit_rate=1.5)
