"""Int8 KV-cache quantization: roundtrip error and decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention as al
from repro.models import kvquant as kq


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_quant_roundtrip_error_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, 2, 16)) * 3.0
    q, s = kq.quantize_kv(x)
    y = kq.dequantize_kv(q, s, jnp.float32)
    err = np.abs(np.asarray(y - x))
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    assert (err <= amax / 127.0 * 1.01 + 1e-7).all()


def test_decode_attention_quant_close_to_exact():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, Hk, D = 2, 32, 4, 2, 16
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    lengths = jnp.asarray([S, S // 2], jnp.int32)
    exact = al.decode_attention(q, k, v, lengths)
    kq_, ks_ = kq.quantize_kv(k)
    vq_, vs_ = kq.quantize_kv(v)
    quant = kq.decode_attention_quant(q, kq_, ks_, vq_, vs_, lengths)
    # correctness: exactly equals attention over the dequantized cache
    kd = kq.dequantize_kv(kq_, ks_, jnp.float32)
    vd = kq.dequantize_kv(vq_, vs_, jnp.float32)
    ref = al.decode_attention(q, kd, vd, lengths)
    assert_allclose = np.testing.assert_allclose
    assert_allclose(np.asarray(quant, np.float32),
                    np.asarray(ref, np.float32), atol=3e-6)
    # accuracy: int8 quantization noise through softmax stays small
    err = np.abs(np.asarray(quant, np.float32)
                 - np.asarray(exact, np.float32))
    rel = err.max() / np.abs(np.asarray(exact)).max()
    assert rel < 2e-2, rel                      # <2% relative error


def test_cache_insert_and_decode():
    cache = kq.init_quant_cache(batch=2, max_len=8, n_kv=2, head_dim=4,
                                layers=1)
    k_new = jnp.ones((2, 2, 4)) * 2.0
    pos = jnp.asarray([0, 3], jnp.int32)
    kq2, ks2 = kq.cache_insert(cache["k_q"][0], cache["k_s"][0], pos, k_new)
    assert int(kq2[0, 0, 0, 0]) == 127          # amax position quantizes to 127
    assert int(kq2[1, 3, 0, 0]) == 127
    assert float(ks2[0, 0, 0]) == pytest.approx(2.0 / 127.0)
    # untouched slots remain zero
    assert int(kq2[0, 1, 0, 0]) == 0


def test_cache_bytes_halved():
    full = kq.init_quant_cache(2, 1024, 8, 128, 4)
    q_bytes = full["k_q"].nbytes + full["k_s"].nbytes
    bf16_bytes = 4 * 2 * 1024 * 8 * 128 * 2
    assert q_bytes < 0.6 * bf16_bytes
