"""Deep unit tests for MoE dispatch semantics and SSM chunked-scan parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.config import get_arch, reduced
from repro.models import moe, ssm


def moe_cfg(E=4, k=2, d=16, f=32):
    return dataclasses.replace(
        reduced(get_arch("qwen3-moe-30b-a3b")), num_experts=E,
        experts_per_token=k, d_model=d, d_ff=f, dtype="float32")


def test_moe_single_expert_equals_dense_mlp():
    """E=1, k=1, no drops: MoE must equal the plain expert MLP."""
    cfg = moe_cfg(E=1, k=1)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = moe.moe_ffn(cfg, p, x, capacity_factor=64.0, group_size=8)
    # dense reference with the same weights
    h = jax.nn.silu(x @ p["wi_gate"][0]) * (x @ p["wi_up"][0])
    want = h @ p["wo"][0]
    assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = moe_cfg(E=4, k=1)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    # logits rigged so ALL tokens pick expert 0 -> capacity must drop some
    # (x positive so sum(x) > 0 and the +10 row always wins)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                  (1, 64, cfg.d_model))) + 0.1
    out_tight, _ = moe.moe_ffn(cfg, p, x, capacity_factor=0.5, group_size=64)
    out_loose, _ = moe.moe_ffn(cfg, p, x, capacity_factor=64.0, group_size=64)
    # dropped tokens produce zero output -> the two differ
    diff = np.abs(np.asarray(out_tight) - np.asarray(out_loose)).max(-1)
    assert (diff > 1e-6).any()
    # exactly capacity tokens survive
    nonzero = (np.abs(np.asarray(out_tight)).max(-1) > 1e-9).sum()
    cap = max(8, -(-int(64 * 1 / 4 * 0.5) // 8) * 8)
    assert nonzero == cap


def test_moe_load_stats_sum():
    cfg = moe_cfg(E=8, k=2)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    _, aux = moe.moe_ffn(cfg, p, x, group_size=32)
    # every token routes k experts (pre-capacity counts)
    assert float(jnp.sum(aux["expert_load"])) == 2 * 32 * cfg.experts_per_token
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_moe_router_gates_sum_to_one(seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (4, 6, 8))
    gates, idx, probs = moe.router_topk(logits, 3)
    assert_allclose(np.asarray(gates.sum(-1)), np.ones((4, 6)), atol=1e-5)
    # indices are distinct per token
    i = np.asarray(idx).reshape(-1, 3)
    assert all(len(set(row)) == 3 for row in i)


# --- mamba ---------------------------------------------------------------

def test_mamba_chunked_equals_full_scan():
    cfg = dataclasses.replace(reduced(get_arch("jamba-v0.1-52b")),
                              d_model=16, dtype="float32")
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16)) * 0.5
    y1, s1 = ssm.mamba_forward(cfg, p, x, chunk=24)
    y2, s2 = ssm.mamba_forward(cfg, p, x, chunk=4)
    assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    assert_allclose(np.asarray(s1["ssm"]), np.asarray(s2["ssm"]),
                    atol=1e-4, rtol=1e-4)


def test_mamba_decode_matches_forward():
    cfg = dataclasses.replace(reduced(get_arch("jamba-v0.1-52b")),
                              d_model=16, dtype="float32")
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 16)) * 0.5
    y_full, _ = ssm.mamba_forward(cfg, p, x, chunk=10)
    st_ = ssm.init_mamba_state(cfg, 1)
    outs = []
    for t in range(10):
        y, st_ = ssm.mamba_decode_step(cfg, p, x[:, t:t + 1], st_)
        outs.append(y[:, 0])
    dec = jnp.stack(outs, 1)
    assert_allclose(np.asarray(dec), np.asarray(y_full), atol=1e-4,
                    rtol=1e-4)


# --- rwkv6 ---------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8, 24]))
def test_wkv6_chunked_equals_sequential(seed, chunk):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, T, H, hs = 2, 24, 2, 8
    r, k, v = (jax.random.normal(kk, (B, T, H, hs)) for kk in ks[:3])
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, hs)) * 2 - 2))
    u = jax.random.normal(ks[4], (H, hs)) * 0.1
    o1, S1 = ssm._wkv6_scan(r, k, v, w, u)
    o2, S2 = ssm._wkv6_chunked(r, k, v, w, u, chunk=chunk)
    assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-4, rtol=5e-4)
    assert_allclose(np.asarray(S1), np.asarray(S2), atol=5e-4, rtol=5e-4)


def test_wkv6_chunked_gradients_finite():
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, T, H, hs = 1, 16, 2, 4
    r, k, v = (jax.random.normal(kk, (B, T, H, hs)) for kk in ks[:3])
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, hs))))
    u = jax.random.normal(ks[4], (H, hs)) * 0.1

    def loss(r, k, v, w):
        o, _ = ssm._wkv6_chunked(r, k, v, w, u, chunk=4)
        return jnp.sum(o ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(r, k, v, w)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()


def test_expert_rebalance_is_equivariant():
    """Permuting experts (LPT placement) leaves MoE outputs unchanged."""
    from repro.core import load_balance as lb
    cfg = moe_cfg(E=8, k=2)
    p = moe.init_moe(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
    out0, aux0 = moe.moe_ffn(cfg, p, x, group_size=16)
    # observed loads -> LPT permutation -> rebalanced params
    load = np.asarray(aux0["expert_load"]) + 1e-3
    assign, perm = lb.rebalance_experts(load, n_devices=4)
    p2 = lb.rebalance_moe_params(p, perm)
    out1, aux1 = moe.moe_ffn(cfg, p2, x, group_size=16)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                               atol=1e-5, rtol=1e-5)
    # loads follow the permutation
    np.testing.assert_allclose(np.asarray(aux1["expert_load"]),
                               np.asarray(aux0["expert_load"])[perm],
                               atol=1e-6)
    # per-device balance improved (or already optimal)
    before = lb.balance_quality(load, np.arange(8) // 2, 4)
    after = lb.balance_quality(load[perm], np.arange(8) // 2, 4)
    assert after <= before + 1e-9
