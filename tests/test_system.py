"""End-to-end system behaviour: train a tiny RecLLM with the real trainer,
kill it mid-run (injected node failure), restart, and verify it resumes from
the checkpoint and converges.  Also: attention-impl parity and property
tests on the system's invariants."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import manager as ckpt
from repro.config import TrainConfig, get_arch, reduced
from repro.data import pipeline
from repro.models import transformer as tf
from repro.models.transformer import ModelCtx
from repro.optimizer import adamw
from repro.runtime import trainer


def tiny_setup(tmp_path, steps=30, ckpt_every=10):
    cfg = dataclasses.replace(reduced(get_arch("recllm-base")),
                              dtype="float32", num_layers=2)
    ctx = ModelCtx(attn_chunk=8)
    tcfg = TrainConfig(steps=steps, learning_rate=3e-3, warmup_steps=2,
                       checkpoint_every=ckpt_every,
                       checkpoint_dir=str(tmp_path / "ckpt"),
                       keep_checkpoints=2, grad_clip=1.0)

    def loss_fn(p, b):
        return tf.loss_fn(cfg, p, b, ctx)

    def step_fn(params, opt, batch):
        lr = 3e-3
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                   batch)
        params, opt = adamw.adamw_apply(params, g, opt, lr, tcfg)
        return params, opt, {"loss": loss}

    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params)
    return cfg, tcfg, jax.jit(step_fn), {"params": params, "opt": opt}


def batches(cfg, n, start=0):
    return list(pipeline.synthetic_lm_batches(
        cfg.vocab_size, 8, 16, n, seed=123))[start:]


def test_train_checkpoint_restart_resumes(tmp_path):
    """Fault tolerance: crash at step 25, restart resumes from step 20."""
    cfg, tcfg, step_fn, state = tiny_setup(tmp_path)
    data = batches(cfg, 40)

    with pytest.raises(RuntimeError, match="injected failure"):
        trainer.train_loop(state, iter(data), step_fn, tcfg,
                           samples_per_batch=8, fail_at=25)
    # --- restart: fresh process state, resume from latest checkpoint -----
    cfg2, tcfg2, step_fn2, fresh = tiny_setup(tmp_path)
    start, state2 = trainer.resume_or_init(fresh, tcfg2)
    assert start == 20
    assert int(state2["opt"]["step"]) == 20
    res = trainer.train_loop(state2, iter(data[start:40]), step_fn2, tcfg2,
                             start_step=start, samples_per_batch=8)
    assert res.final_step == 40
    assert np.isfinite(res.losses).all()


def test_training_reduces_loss(tmp_path):
    cfg, tcfg, step_fn, state = tiny_setup(tmp_path, steps=60,
                                           ckpt_every=0)
    data = batches(cfg, 60)
    res = trainer.train_loop(state, iter(data), step_fn, tcfg,
                             samples_per_batch=8)
    assert np.mean(res.losses[-10:]) < np.mean(res.losses[:10])
    assert res.throughput > 0


def test_checkpoint_keeps_n(tmp_path):
    cfg, tcfg, step_fn, state = tiny_setup(tmp_path, steps=50,
                                           ckpt_every=10)
    data = batches(cfg, 50)
    trainer.train_loop(state, iter(data), step_fn, tcfg,
                       samples_per_batch=8)
    assert ckpt.list_steps(tcfg.checkpoint_dir) == [40, 50]


# -- attention implementation parity (system invariant) ---------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8, 16]),
       st.sampled_from([0, 8]))
def test_chunked_equals_naive_attention(seed, chunk, window):
    from repro.models import attention as al
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, H, Hk, D = 2, 24, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    a = al.naive_attention(q, k, v, causal=True, window=window)
    b = al.chunked_attention(q, k, v, causal=True, window=window,
                             chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_decode_attention_matches_naive_last_position(seed):
    from repro.models import attention as al
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, H, Hk, D = 2, 12, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    full = al.naive_attention(q, k, v, causal=True)
    dec = al.decode_attention(q[:, -1:], k, v,
                              jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5,
                               rtol=2e-5)


# -- numeric invariants --------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_loss_invariant_to_pad_masking(seed):
    """Masked positions must not affect the loss."""
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32",
                              num_layers=1)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    tokens = rng.integers(3, cfg.vocab_size, (2, 8)).astype(np.int32)
    targets = rng.integers(3, cfg.vocab_size, (2, 8)).astype(np.int32)
    mask = np.ones((2, 8), np.float32)
    mask[:, 6:] = 0.0
    ctx = ModelCtx(attn_chunk=8)
    t2 = targets.copy()
    t2[:, 6:] = rng.integers(3, cfg.vocab_size, (2, 2))  # garbage in masked
    l1, _ = tf.loss_fn(cfg, params, {"tokens": jnp.asarray(tokens),
                                     "targets": jnp.asarray(targets),
                                     "mask": jnp.asarray(mask)}, ctx)
    l2, _ = tf.loss_fn(cfg, params, {"tokens": jnp.asarray(tokens),
                                     "targets": jnp.asarray(t2),
                                     "mask": jnp.asarray(mask)}, ctx)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
