"""Multi-device checks, run in a subprocess with 8 host devices (jax locks
the device count at first init, so the main pytest process — which must see
1 device — cannot run these inline).  Prints one JSON dict of results;
``test_distributed.py`` asserts each entry.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402

from repro import compat  # noqa: E402

RESULTS = {}


def check(name):
    def deco(fn):
        try:
            fn()
            RESULTS[name] = {"ok": True}
        except Exception as e:  # noqa: BLE001
            RESULTS[name] = {"ok": False,
                             "error": f"{type(e).__name__}: {e}",
                             "tb": traceback.format_exc(limit=6)}
        return fn
    return deco


def pod_mesh():
    return compat.make_mesh((2, 4), ("pod", "data"))


def data_mesh():
    return compat.make_mesh((8,), ("data",))


# ---------------------------------------------------------------------------
@check("hierarchical_allreduce_equals_flat")
def _():
    from repro.core import hierarchical
    mesh = pod_mesh()
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 33))

    def flat(xs):
        return hierarchical.flat_allreduce_mean(xs, ("pod", "data"))

    def hier(xs):
        return hierarchical.hierarchical_allreduce_mean(xs, "data", "pod")

    spec = P(("pod", "data"))
    f = shard_map(flat, mesh=mesh, in_specs=spec, out_specs=spec,
                  check_rep=False)
    h = shard_map(hier, mesh=mesh, in_specs=spec, out_specs=spec,
                  check_rep=False)
    # summation order differs (RS+AR+AG vs single ring): ~1e-6 rel noise
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(h(x)),
                               rtol=1e-5, atol=1e-7)
    # and both equal the true mean broadcast
    want = np.broadcast_to(np.asarray(x).mean(0, keepdims=True), x.shape)
    np.testing.assert_allclose(np.asarray(h(x)), want, rtol=1e-5)


# ---------------------------------------------------------------------------
@check("onebit_sync_matches_manual")
def _():
    from repro.core import compression
    mesh = data_mesh()
    P_ = 8
    N = 8 * 512
    g = jax.random.normal(jax.random.PRNGKey(1), (P_, N))
    resid = jnp.zeros((P_, N))

    def inner(gs, rs):
        out, new_r = compression.onebit_sync({"w": gs[0]}, rs[0],
                                             axis="data", block=512)
        return out["w"][None], new_r[None]

    f = shard_map(inner, mesh=mesh, in_specs=(P("data"), P("data")),
                  out_specs=(P("data"), P("data")), check_rep=False)
    synced, new_resid = f(g, resid)
    # every rank holds the same mean of dequantized peers
    from repro.kernels import ops
    deq = []
    for p in range(P_):
        pk, sc = ops.onebit_quantize(g[p], 512)
        deq.append(np.asarray(ops.onebit_dequantize(pk, sc, 512)))
    want = np.mean(deq, axis=0)
    for p in range(P_):
        np.testing.assert_allclose(np.asarray(synced[p]), want, atol=1e-5)
    # error feedback: residual + dequant == original
    np.testing.assert_allclose(np.asarray(new_resid[0] + deq[0]),
                               np.asarray(g[0]), atol=1e-5)


# ---------------------------------------------------------------------------
@check("topk_sync_matches_manual")
def _():
    from repro.core import compression
    mesh = data_mesh()
    N = 4096
    g = jax.random.normal(jax.random.PRNGKey(2), (8, N))
    resid = jnp.zeros((8, N))

    def inner(gs, rs):
        out, new_r = compression.topk_sync({"w": gs[0]}, rs[0],
                                           axis="data", block=1024, k=16)
        return out["w"][None], new_r[None]

    f = shard_map(inner, mesh=mesh, in_specs=(P("data"), P("data")),
                  out_specs=(P("data"), P("data")), check_rep=False)
    synced, new_resid = f(g, resid)
    g_np = np.asarray(g)
    kept = np.zeros_like(g_np)
    for p in range(8):
        for b in range(N // 1024):
            blk = g_np[p, b * 1024:(b + 1) * 1024]
            idx = np.argsort(-np.abs(blk))[:16]
            kept[p, b * 1024 + idx] = blk[idx]
    want = kept.mean(0)
    np.testing.assert_allclose(np.asarray(synced[0]), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_resid), g_np - kept,
                               atol=1e-5)


# ---------------------------------------------------------------------------
@check("gpipe_matches_serial")
def _():
    from repro.core import pipeline
    mesh = compat.make_mesh((8,), ("stage",))
    S, M, mb, d = 8, 16, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(3), S)
    Ws = jnp.stack([jax.random.normal(k, (d, d)) * (d ** -0.5) for k in ks])

    def stage_fn(p, x):
        return jnp.tanh(x @ p["W"])

    pipe = pipeline.gpipe(stage_fn, mesh, S, M)
    x = jax.random.normal(jax.random.PRNGKey(4), (M, mb, d))
    y = pipe({"W": Ws}, x)

    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ Ws[s])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    # gradient parity (GPipe backward via autodiff)
    tgt = jax.random.normal(jax.random.PRNGKey(5), (M, mb, d))

    def loss_pipe(W):
        return jnp.mean((pipe({"W": W}, x) - tgt) ** 2)

    def loss_ref(W):
        h = x
        for s in range(S):
            h = jnp.tanh(h @ W[s])
        return jnp.mean((h - tgt) ** 2)

    g1 = jax.grad(loss_pipe)(Ws)
    g2 = jax.grad(loss_ref)(Ws)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# ---------------------------------------------------------------------------
@check("pipeline_1f1b_matches_gpipe_and_serial")
def _():
    """The manual 1F1B executor's loss AND gradients match the autodiff
    GPipe reference and the unpipelined model, on a real small transformer
    with deliberately uneven (padded) stages."""
    import dataclasses
    from repro.config import get_arch, reduced
    from repro.core import pipeline
    from repro.models import layers as L, transformer as tf
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), num_layers=6,
                              dtype="float32")
    ctx = tf.ModelCtx(attn_chunk=16)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    bounds = [0, 2, 3, 5, 6]
    pp = tf.pp_partition_params(cfg, params, bounds)
    stage_fn = tf.make_stage_fn(cfg, ctx)
    last_fn = tf.make_last_fn(cfg, ctx)
    B, Sq, M = 8, 16, 4
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, Sq)), jnp.int32)
    targets = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, Sq)),
                          jnp.int32)
    h = L.embed_tokens(params["embed"], tokens)
    x_m = pipeline.microbatch(h, M)
    t_m = pipeline.microbatch(targets, M)
    m_m = pipeline.microbatch(jnp.ones((B, Sq)), M)

    # unpipelined reference: same chain, differentiated directly
    def ref_loss(sp, lp, xm):
        hh = xm.reshape((B, Sq, cfg.d_model))
        for s in range(4):
            hh = stage_fn(jax.tree.map(lambda a, s=s: a[s], sp), hh)
        return last_fn(lp, hh, targets, jnp.ones((B, Sq))) / (B * Sq)

    l0, (g_sp0, g_lp0, g_x0) = jax.value_and_grad(ref_loss, argnums=(0, 1, 2))(
        pp["stage"], pp["last"], x_m.reshape((B, Sq, cfg.d_model)))
    g_x0 = g_x0.reshape(x_m.shape)

    mesh = compat.make_mesh((4,), ("stage",))
    outs = {}
    # parity oracle #2: autodiff straight through the gpipe tick scan
    ad = jax.jit(pipeline.gpipe_value_and_grad(stage_fn, last_fn, mesh, 4,
                                               M))
    cases = [("gpipe", None), ("1f1b", None), ("gpipe_autodiff", ad)]
    for sched, vag in cases:
        if vag is None:
            vag = jax.jit(pipeline.make_pipeline_value_and_grad(
                stage_fn, last_fn, mesh, 4, M, schedule=sched))
        l1, (g_sp, g_lp, g_x) = vag(pp["stage"], pp["last"], x_m, t_m, m_m)
        np.testing.assert_allclose(float(l1), float(l0), rtol=1e-5,
                                   err_msg=sched)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, err_msg=sched),
            g_sp["blocks"], g_sp0["blocks"])
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, err_msg=sched),
            g_lp, g_lp0)
        np.testing.assert_allclose(np.asarray(g_x), np.asarray(g_x0),
                                   atol=2e-4, err_msg=sched)
        outs[sched] = float(l1)
    RESULTS.setdefault("pipeline_losses", outs)


# ---------------------------------------------------------------------------
@check("pp_hybrid_train_step_matches_dp")
def _():
    """The full DP x TP x stage pipelined train step (both schedules, 2x2x2
    mesh) follows the plain DP-8 trajectory exactly, including a remainder
    batch that does not divide into the micro-batches."""
    import dataclasses
    from repro.config import TrainConfig, get_arch, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models import layers as L, transformer as tf
    from repro.optimizer import adamw
    from repro.runtime import trainer
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), num_layers=4,
                              dtype="float32")
    ctx = tf.ModelCtx(attn_chunk=8)
    tcfg = TrainConfig(steps=8, learning_rate=1e-3, warmup_steps=2,
                       checkpoint_every=0)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    bounds = [0, 2, 4]
    rng = np.random.default_rng(0)
    B, Sq = 8, 16
    batches = [{"tokens": jnp.asarray(rng.integers(3, 200, (B, Sq)),
                                      jnp.int32),
                "targets": jnp.asarray(rng.integers(3, 200, (B, Sq)),
                                       jnp.int32),
                "mask": jnp.ones((B, Sq), jnp.float32)}
               for _ in range(4)]

    def ref_loss(p, b):
        logits, _, _ = tf.forward(cfg, p, b, ctx)
        nll = L._nll(logits, b["targets"])
        return jnp.sum(nll * b["mask"]) / jnp.sum(b["mask"])

    scfg = trainer.DPSyncConfig(mode="flat")
    p_ref = jax.tree.map(jnp.copy, params)
    opt_ref = adamw.init_opt_state(p_ref)
    resid = jnp.zeros((8, trainer.residual_size(p_ref, scfg)))
    step_ref = trainer.make_dp_train_step(ref_loss, make_host_mesh(data=8),
                                          tcfg, scfg)
    ref_losses = []
    for b in batches:
        p_ref, opt_ref, resid, l = step_ref(p_ref, opt_ref, resid, b)
        ref_losses.append(float(l))

    for sched in ("1f1b", "gpipe"):
        mesh = make_host_mesh(data=2, model=2, stage=2)
        pp = tf.pp_partition_params(cfg, jax.tree.map(jnp.copy, params),
                                    bounds)
        pp_shape = jax.eval_shape(lambda: pp)
        opt = adamw.init_opt_state(
            trainer.pp_trainable(pp, cfg.tie_embeddings))
        res = jnp.zeros((2, 2, 2,
                         trainer.pp_residual_size(cfg, pp_shape, mesh,
                                                  scfg)))
        step = trainer.make_pp_train_step(cfg, mesh, tcfg, bounds, pp_shape,
                                          n_micro=2, pp_schedule=sched,
                                          scfg=scfg, ctx=ctx)
        losses = []
        for b in batches:
            pp, opt, res, l = step(pp, opt, res, b)
            losses.append(float(l))
        np.testing.assert_allclose(losses, ref_losses, rtol=2e-4,
                                   atol=1e-5, err_msg=sched)
        RESULTS.setdefault("pp_losses", {})[sched] = losses
        if sched == "1f1b":
            # microbatch remainder: B=6 does not divide n_micro=4 — the
            # step pads and masks, and the loss equals the unpipelined
            # loss on the 6 real rows
            step6 = trainer.make_pp_train_step(
                cfg, mesh, tcfg, bounds, pp_shape, n_micro=4,
                pp_schedule=sched, scfg=scfg, ctx=ctx)
            b6 = {k: v[:6] for k, v in batches[0].items()}
            pp6 = tf.pp_partition_params(
                cfg, jax.tree.map(jnp.copy, params), bounds)
            opt6 = adamw.init_opt_state(
                trainer.pp_trainable(pp6, cfg.tie_embeddings))
            res6 = jnp.zeros_like(res)
            _, _, _, l6 = step6(pp6, opt6, res6, b6)
            np.testing.assert_allclose(float(l6), float(ref_loss(
                params, b6)), rtol=2e-4)


# ---------------------------------------------------------------------------
@check("pp_train_step_compressed_embed_sync_converges")
def _():
    """The pipelined step composes the compressed (top-k) DP sync and the
    rows-touched sparse embedding sync on an untied arch."""
    import dataclasses
    from repro.config import TrainConfig, get_arch, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tf
    from repro.optimizer import adamw
    from repro.runtime import trainer
    cfg = dataclasses.replace(reduced(get_arch("deepseek-7b")),
                              num_layers=4, dtype="float32")
    assert not cfg.tie_embeddings
    tcfg = TrainConfig(steps=10, learning_rate=3e-3, warmup_steps=2,
                       checkpoint_every=0)
    mesh = make_host_mesh(data=2, model=2, stage=2)
    bounds = [0, 2, 4]
    scfg = trainer.DPSyncConfig(mode="topk", topk_block=256, k=64)
    esync = trainer.EmbedSyncConfig(
        id_fns={"embed": lambda b: b["tokens"]})
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    pp = tf.pp_partition_params(cfg, params, bounds)
    pp_shape = jax.eval_shape(lambda: pp)
    opt = adamw.init_opt_state(trainer.pp_trainable(pp, False))
    res = jnp.zeros((2, 2, 2, trainer.pp_residual_size(
        cfg, pp_shape, mesh, scfg, embed_sync=esync)))
    step = trainer.make_pp_train_step(cfg, mesh, tcfg, bounds, pp_shape,
                                      n_micro=2, scfg=scfg,
                                      embed_sync=esync)
    rng = np.random.default_rng(1)
    losses = []
    for i in range(10):
        b = {"tokens": jnp.asarray(rng.integers(3, 200, (8, 16)),
                                   jnp.int32),
             "targets": jnp.asarray(rng.integers(3, 16, (8, 16)),
                                    jnp.int32)}
        pp, opt, res, l = step(pp, opt, res, b)
        losses.append(float(l))
    assert np.isfinite(losses).all() and losses[-1] < losses[0], losses
    RESULTS.setdefault("pp_compressed_losses", losses)


# ---------------------------------------------------------------------------
@check("pp_rebalance_in_loop")
def _():
    """Rebalance-in-the-loop: training from a deliberately skewed
    layer->stage split, the in-loop probe->rebalance->remap hook converges
    the bounds to the balanced partition, and the loss trajectory matches
    an unrebalanced run (the remap is model-function invariant)."""
    import dataclasses
    from repro.config import TrainConfig, get_arch, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tf
    from repro.optimizer import adamw
    from repro.runtime import trainer
    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), num_layers=6,
                              dtype="float32")
    ctx = tf.ModelCtx(attn_chunk=8)
    tcfg = TrainConfig(steps=6, learning_rate=1e-3, warmup_steps=2,
                       checkpoint_every=0)
    skew = [0, 1, 6]                           # stage 0: 1 layer, stage 1: 5
    rng = np.random.default_rng(2)
    batches = [{"tokens": jnp.asarray(rng.integers(3, 200, (8, 16)),
                                      jnp.int32),
                "targets": jnp.asarray(rng.integers(3, 200, (8, 16)),
                                       jnp.int32)}
               for _ in range(6)]
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    scfg = trainer.DPSyncConfig(mode="flat")

    def run(rebalance_every):
        mesh = make_host_mesh(data=2, model=2, stage=2)
        pp = tf.pp_partition_params(cfg, jax.tree.map(jnp.copy, params),
                                    skew)
        pp_shape = jax.eval_shape(lambda: pp)
        opt = adamw.init_opt_state(trainer.pp_trainable(pp,
                                                        cfg.tie_embeddings))
        res = jnp.zeros((2, 2, 2, trainer.pp_residual_size(
            cfg, pp_shape, mesh, scfg)))
        step = trainer.make_pp_train_step(cfg, mesh, tcfg, skew, pp_shape,
                                          n_micro=2, scfg=scfg, ctx=ctx)
        rebal = trainer.PPRebalancer(cfg, mesh, tcfg, skew, n_micro=2,
                                     scfg=scfg, ctx=ctx, probe_batch=4,
                                     probe_seq=32)
        state = {"params": pp, "opt": opt, "residual": res}
        out = trainer.train_loop(
            state, iter(batches), step, tcfg,
            rebalance_every=rebalance_every,
            rebalance_fn=rebal if rebalance_every else None)
        return out.losses, rebal
    base_losses, _ = run(0)
    losses, rebal = run(2)
    assert len(rebal.history) > 1, "rebalance never fired"
    final = rebal.history[-1]
    sizes = [final[s + 1] - final[s] for s in range(2)]
    assert max(sizes) <= 4, (rebal.history, rebal.last_stage_times)
    # the remap preserves the model function: same trajectory either way
    np.testing.assert_allclose(losses, base_losses, rtol=5e-3, atol=1e-4)
    RESULTS.setdefault("pp_rebalance_history", rebal.history)

    # checkpoint/resume leg: the moved carve points ride in the checkpoint,
    # and restore rebuilds a working step at THOSE bounds (not the skewed
    # template's) — a resumed rebalanced run must not scramble its layers
    import shutil
    import tempfile
    ckpt_dir = tempfile.mkdtemp(prefix="pp_rebal_ckpt_")
    try:
        tcfg_ck = dataclasses.replace(tcfg, checkpoint_every=2,
                                      checkpoint_dir=ckpt_dir)
        mesh = make_host_mesh(data=2, model=2, stage=2)

        def fresh_state():
            pp = tf.pp_partition_params(cfg,
                                        jax.tree.map(jnp.copy, params),
                                        skew)
            opt = adamw.init_opt_state(
                trainer.pp_trainable(pp, cfg.tie_embeddings))
            res = jnp.zeros((2, 2, 2, trainer.pp_residual_size(
                cfg, jax.eval_shape(lambda: pp), mesh, scfg)))
            return {"params": pp, "opt": opt, "residual": res,
                    "stage_bounds": jnp.asarray(skew, jnp.int32)}

        state = fresh_state()
        step = trainer.make_pp_train_step(
            cfg, mesh, tcfg_ck, skew, jax.eval_shape(lambda: state["params"]),
            n_micro=2, scfg=scfg, ctx=ctx)
        rebal2 = trainer.PPRebalancer(cfg, mesh, tcfg_ck, skew, n_micro=2,
                                      scfg=scfg, ctx=ctx, probe_batch=4,
                                      probe_seq=32)
        trainer.train_loop(state, iter(batches[:4]), step, tcfg_ck,
                           rebalance_every=2, rebalance_fn=rebal2)
        assert len(rebal2.history) > 1
        start, restored = trainer.resume_or_init(fresh_state(), tcfg_ck)
        assert start == 4
        rb = [int(b) for b in restored["stage_bounds"]]
        assert rb == rebal2.bounds, (rb, rebal2.bounds)
        step_r = trainer.make_pp_train_step(
            cfg, mesh, tcfg_ck, rb,
            jax.eval_shape(lambda: restored["params"]), n_micro=2,
            scfg=scfg, ctx=ctx)
        _, _, _, l = step_r(restored["params"], restored["opt"],
                            restored["residual"], batches[4])
        assert np.isfinite(float(l)), float(l)
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


# ---------------------------------------------------------------------------
@check("pp_launch_train_e2e")
def _():
    """launch/train.py drives the pipelined hybrid path end-to-end on the
    8-device mesh (the acceptance-criterion entrypoint)."""
    from repro.launch import train as launch_train
    rc = launch_train.main([
        "--arch", "olmo-1b", "--reduced", "--data", "2", "--model", "2",
        "--pp-stages", "2", "--pp-micro", "2", "--steps", "3",
        "--batch", "8", "--seq", "16",
        "--ckpt-dir", "/tmp/repro_ppcheck_ckpt"])
    assert rc == 0


# ---------------------------------------------------------------------------
@check("embed_zero_opt_state_matches_replicated")
def _():
    """Row-wise-sharded optimizer state for embedding tables (ZeRO over
    the vocab dim, composing with the sparse rows-touched sync): the
    trajectory is identical to the replicated optimizer, while the AdamW
    moments physically shard 1/8 per device."""
    from repro.config import TrainConfig
    from repro.optimizer import adamw
    from repro.runtime import trainer
    mesh = data_mesh()
    rng = np.random.default_rng(2)
    n_users, dim = 64, 8
    Wt = jnp.asarray(rng.normal(size=(n_users, dim)), jnp.float32)

    def loss_fn(params, batch):
        emb = params["emb"][batch["user"]]
        return jnp.mean((emb @ params["W"] - batch["y"]) ** 2)

    tcfg = TrainConfig(steps=40, learning_rate=1e-2, warmup_steps=4,
                       weight_decay=0.0, grad_clip=1.0, checkpoint_every=0)
    W0 = (rng.standard_normal((dim, 4)) * 0.1).astype(np.float32)
    trajs, finals = {}, {}
    for name, zero in (("replicated", False), ("zero", True)):
        esync = trainer.EmbedSyncConfig(
            id_fns={"emb": lambda b: b["user"]}, zero_opt=zero)
        scfg = trainer.DPSyncConfig(mode="flat")
        params = {"emb": jnp.zeros((n_users, dim)), "W": jnp.asarray(W0)}
        pshape = jax.eval_shape(lambda: params)
        rng2 = np.random.default_rng(7)
        opt = adamw.init_opt_state(params)
        resid = jnp.zeros((8, trainer.residual_size(
            params, scfg, exclude=esync.exclude)))
        step = trainer.make_dp_train_step(loss_fn, mesh, tcfg, scfg,
                                          embed_sync=esync,
                                          params_shape=pshape)
        losses = []
        for _ in range(40):
            users = jnp.asarray(rng2.integers(0, n_users, 64), jnp.int32)
            y = Wt[users] @ np.ones((dim, 4), np.float32) * 0.1
            params, opt, resid, loss = step(
                params, opt, resid, {"user": users, "y": jnp.asarray(y)})
            losses.append(float(loss))
        trajs[name] = losses
        finals[name] = np.asarray(params["emb"])
        if zero:
            shard = opt["m"]["emb"].sharding.shard_shape(
                opt["m"]["emb"].shape)
            assert shard == (n_users // 8, dim), shard
    np.testing.assert_allclose(trajs["zero"], trajs["replicated"],
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(finals["zero"], finals["replicated"],
                               rtol=1e-4, atol=1e-6)
    RESULTS.setdefault("embed_zero_losses", trajs)


# ---------------------------------------------------------------------------
@check("dp_train_step_hier_and_compressed_converge")
def _():
    from repro.config import TrainConfig
    from repro.optimizer import adamw
    from repro.runtime import trainer
    mesh = pod_mesh()
    rng = np.random.default_rng(0)
    Wt = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["W"]
        return jnp.mean((pred - batch["y"]) ** 2)

    tcfg = TrainConfig(steps=60, learning_rate=3e-2, warmup_steps=5,
                       weight_decay=0.0, grad_clip=0, checkpoint_every=0)
    for mode, inter in (("flat", None), ("hierarchical", "pod"),
                        ("onebit", None), ("topk", None)):
        scfg = trainer.DPSyncConfig(mode=mode, inter_axis=inter, block=512,
                                    topk_block=64, k=16)
        params = {"W": jnp.zeros((16, 4))}
        opt = adamw.init_opt_state(params)
        n = trainer.residual_size(params, scfg)
        resid = jnp.zeros((8, n))
        step = trainer.make_dp_train_step(loss_fn, mesh, tcfg, scfg)
        losses = []
        for i in range(60):
            x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
            y = x @ Wt + 0.01 * jnp.asarray(rng.normal(size=(64, 4)),
                                            jnp.float32)
            params, opt, resid, loss = step(params, opt, resid,
                                            {"x": x, "y": y})
            losses.append(float(loss))
        # top-k (25% density) legitimately converges slower (paper trade-off)
        bar = 0.3 if mode == "topk" else 0.15
        assert losses[-1] < bar * losses[0], (mode, losses[0], losses[-1])
        RESULTS.setdefault("dp_losses", {})[mode] = (losses[0], losses[-1])


# ---------------------------------------------------------------------------
@check("hybrid_gspmd_train_step_runs")
def _():
    import dataclasses
    from repro.config import get_arch, reduced, TrainConfig, ParallelConfig, \
        SHAPES
    from repro.core.hybrid import auto_plan
    from repro.models import transformer as tf, model_zoo
    from repro.optimizer import adamw
    from repro.runtime import trainer
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(reduced(get_arch("qwen3-moe-30b-a3b")),
                              dtype="float32", num_heads=2, num_kv_heads=2)
    plan = auto_plan(cfg, mesh, SHAPES["train_4k"], ParallelConfig())
    tcfg = TrainConfig(steps=5, checkpoint_every=0)
    step, jitted, shardings_for = trainer.make_hybrid_train_step(
        cfg, plan, tcfg)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(3, 200, (8, 16)), jnp.int32),
             "targets": jnp.asarray(rng.integers(3, 200, (8, 16)), jnp.int32),
             "mask": jnp.ones((8, 16), jnp.float32)}
    fn = jitted(jax.eval_shape(lambda: params), batch)
    losses = []
    for _ in range(5):
        params, opt, metrics = fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]
    RESULTS.setdefault("hybrid_losses", losses)


# ---------------------------------------------------------------------------
@check("elastic_reshard_roundtrip")
def _():
    from repro.runtime import elastic
    mesh8 = data_mesh()
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh8, P("data")))
    # shrink to 4 survivors
    mesh4 = elastic.make_mesh_for(4)
    ys = elastic.reshard({"x": xs},
                         {"x": NamedSharding(mesh4, P("data"))})
    np.testing.assert_array_equal(np.asarray(ys["x"]), np.asarray(x))
    assert len(ys["x"].sharding.device_set) == 4


# ---------------------------------------------------------------------------
@check("embed_sharded_lookup_matches_replicated")
def _():
    """Every sharding plan's lookup — and its gradient — matches the
    replicated-dense reference on the 8-device mesh."""
    from repro import embeddings
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    spec = embeddings.EmbedSpec("t", rows=96, dim=16)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(96, 16)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 96, size=48), jnp.int32)
    tgt = jnp.asarray(rng.normal(size=(48, 16)), jnp.float32)
    want = np.asarray(table)[np.asarray(ids)]
    g_want = np.asarray(jax.grad(
        lambda t: 0.5 * jnp.mean((t[ids] - tgt) ** 2))(table))
    for kind in embeddings.PLANS:
        plan = embeddings.make_plan(kind)
        lk = embeddings.make_sharded_lookup(mesh, spec, plan)
        t_sh = jax.device_put(table, embeddings.named_sharding(mesh, plan))
        i_sh = jax.device_put(ids, NamedSharding(mesh, P("data")))
        np.testing.assert_allclose(np.asarray(lk(t_sh, i_sh)), want,
                                   atol=1e-6, err_msg=kind)
        g = jax.grad(lambda t: 0.5 * jnp.mean((lk(t, i_sh) - tgt) ** 2))(
            t_sh)
        np.testing.assert_allclose(np.asarray(g), g_want, atol=1e-6,
                                   err_msg=f"{kind} grad")


# ---------------------------------------------------------------------------
@check("embed_sparse_row_sync_matches_dense_pmean")
def _():
    """Rows-touched sparse gradient sync == dense pmean over dp ranks."""
    from repro.embeddings import sparse_row_sync
    mesh = data_mesh()
    rng = np.random.default_rng(1)
    ids = rng.integers(0, 64, size=(8, 12)).astype(np.int32)
    g = np.zeros((8, 64, 8), np.float32)
    for p in range(8):                  # gradient mass only on touched rows
        for j in ids[p]:
            g[p, j] += rng.normal(size=8)

    def body(g_loc, ids_loc):
        return sparse_row_sync(g_loc[0], ids_loc[0], ("data",))[None]

    f = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                  out_specs=P("data"), check_rep=False)
    out = np.asarray(f(jnp.asarray(g), jnp.asarray(ids[:, None])))
    want = g.mean(0)
    for p in range(8):
        np.testing.assert_allclose(out[p], want, atol=1e-6)


# ---------------------------------------------------------------------------
@check("dp_train_step_sparse_embed_matches_dense")
def _():
    """The DP train step with EmbedSyncConfig (rows-touched exchange)
    follows the dense-flat-sync trajectory."""
    from repro.config import TrainConfig
    from repro.optimizer import adamw
    from repro.runtime import trainer
    mesh = data_mesh()
    rng = np.random.default_rng(2)
    n_users, dim = 64, 8
    Wt = jnp.asarray(rng.normal(size=(n_users, dim)), jnp.float32)

    def loss_fn(params, batch):
        emb = params["emb"][batch["user"]]            # (B, dim)
        return jnp.mean((emb @ params["W"] - batch["y"]) ** 2)

    tcfg = TrainConfig(steps=40, learning_rate=1e-2, warmup_steps=4,
                       weight_decay=0.0, grad_clip=0, checkpoint_every=0)
    esync = trainer.EmbedSyncConfig(id_fns={"emb": lambda b: b["user"]})
    W0 = (rng.standard_normal((dim, 4)) * 0.1).astype(np.float32)
    trajs = {}
    cases = (("dense", "flat", None), ("sparse", "flat", esync),
             # embed grads ride the sparse path even when the rest of the
             # tree goes through compressed sync (residual excludes them)
             ("sparse_topk", "topk", esync))
    for name, mode, es in cases:
        scfg = trainer.DPSyncConfig(mode=mode, topk_block=32, k=16)
        # fresh arrays per run: the jitted step donates its inputs
        params = {"emb": jnp.zeros((n_users, dim)), "W": jnp.asarray(W0)}
        rng2 = np.random.default_rng(7)               # same batches per run
        opt = adamw.init_opt_state(params)
        exclude = es.exclude if es is not None else ()
        resid = jnp.zeros((8, trainer.residual_size(params, scfg,
                                                    exclude=exclude)))
        step = trainer.make_dp_train_step(loss_fn, mesh, tcfg, scfg,
                                          embed_sync=es)
        losses = []
        for _ in range(40):
            users = jnp.asarray(rng2.integers(0, n_users, 64), jnp.int32)
            y = Wt[users] @ np.ones((dim, 4), np.float32) * 0.1
            params, opt, resid, loss = step(
                params, opt, resid,
                {"user": users, "y": jnp.asarray(y)})
            losses.append(float(loss))
        trajs[name] = losses
    np.testing.assert_allclose(trajs["sparse"], trajs["dense"],
                               rtol=1e-4, atol=1e-6)
    # compressed non-embed sync still converges with sparse embed grads
    assert trajs["sparse_topk"][-1] < 0.5 * trajs["sparse_topk"][0]
    RESULTS.setdefault("embed_losses", trajs)


# ---------------------------------------------------------------------------
@check("hybrid_recllm_embed_plan_matches_replicated")
def _():
    """The hybrid GSPMD train step with the recsys CF tables routed through
    EmbedPlan placement (row-sharded over ``model``) places the tables
    sharded AND follows the replicated-placement loss trajectory exactly
    (placement must not change the math)."""
    import dataclasses
    from repro.config import get_arch, reduced, TrainConfig, ParallelConfig, \
        SHAPES
    from repro.core.hybrid import auto_plan
    from repro.models import transformer as tf
    from repro.optimizer import adamw
    from repro.recsys import model as recsys_model
    from repro.runtime import trainer
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(reduced(get_arch("recllm-base")),
                              dtype="float32")
    n_users = 64
    tcfg = TrainConfig(steps=4, checkpoint_every=0)
    ctx = tf.ModelCtx(attn_chunk=8)
    loss_fn = lambda p, b: recsys_model.recllm_loss(cfg, p, b, ctx)  # noqa: E731
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(3, 200, (8, 16)), jnp.int32),
             "targets": jnp.asarray(rng.integers(3, 200, (8, 16)),
                                    jnp.int32),
             "user": jnp.asarray(rng.integers(0, n_users, (8,)), jnp.int32)}
    trajs = {}
    for name, eplans in (("replicated", None),
                         ("embed_plan", recsys_model.embed_plans("row"))):
        plan = auto_plan(cfg, mesh, SHAPES["train_4k"], ParallelConfig(),
                         embed_plans=eplans)
        step, jitted, shardings_for = trainer.make_hybrid_train_step(
            cfg, plan, tcfg, loss_fn=loss_fn)
        params = recsys_model.init_recllm(jax.random.PRNGKey(0), cfg,
                                          n_users)
        pspecs = plan.sharding.param_specs(
            cfg, jax.eval_shape(lambda: params))
        want = P("model", None) if eplans else P(None, None)
        assert pspecs["cf_user"] == want, pspecs["cf_user"]
        assert pspecs["cf_item"] == want, pspecs["cf_item"]
        opt = adamw.init_opt_state(params)
        fn = jitted(jax.eval_shape(lambda: params), batch)
        losses = []
        for _ in range(4):
            params, opt, metrics = fn(params, opt, batch)
            losses.append(float(metrics["loss"]))
        if eplans:
            # the table shards actually land row-sharded over `model`
            assert params["cf_user"].sharding.spec == P("model", None), \
                params["cf_user"].sharding
        assert np.isfinite(losses).all()
        trajs[name] = losses
    np.testing.assert_allclose(trajs["embed_plan"], trajs["replicated"],
                               rtol=1e-4, atol=1e-6)
    RESULTS.setdefault("recllm_embed_losses", trajs)


# ---------------------------------------------------------------------------
@check("cf_hot_row_cache_matches_sharded")
def _():
    """The serving hot-row cache is bit-exact against the raw table at
    every sharding plan on the 8-device mesh, with real cache hits, and
    the rows-touched refresh restores exactness after a table update."""
    from repro import embeddings
    from repro.embeddings.serving import CacheConfig, CachedLookup
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    spec = embeddings.EmbedSpec("cf_item", rows=96, dim=16)
    rng = np.random.default_rng(7)
    table = rng.normal(size=(96, 16)).astype(np.float32)
    ids = np.clip(rng.zipf(1.3, size=160), 1, 96) - 1   # head-heavy
    rates = {}
    for kind in embeddings.PLANS:
        plan = embeddings.make_plan(kind)
        lk = CachedLookup(spec, plan, table, mesh=mesh,
                          cache=CacheConfig(rows=24))
        for lo in range(0, len(ids), 32):
            rows, _ = lk(ids[lo:lo + 32])
            np.testing.assert_array_equal(
                rows, table[ids[lo:lo + 32]], err_msg=kind)
        assert lk.hits > 0, kind
        # trainer update + rows-touched refresh keeps the replica exact
        hot = np.asarray(lk.cache.ids[:8])
        lk.update_rows(hot, np.full((len(hot), 16), 2.5, np.float32))
        rows, _ = lk(hot)
        np.testing.assert_array_equal(
            rows, np.full((len(hot), 16), 2.5, np.float32),
            err_msg=f"{kind} post-update")
        rates[kind] = lk.hit_rate
    RESULTS.setdefault("cf_cache_hit_rates", rates)


# ---------------------------------------------------------------------------
@check("dryrun_cell_on_host_mesh")
def _():
    """A miniature dry-run: the full build_cell path on an 8-device mesh."""
    import dataclasses
    from repro.config import get_arch, reduced, SHAPES, ParallelConfig
    import repro.config as rc
    from repro.launch import dryrun_lib
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    cfg = reduced(get_arch("olmo-1b"))
    shape = dataclasses.replace(SHAPES["decode_32k"], seq_len=64,
                                global_batch=8)
    lower_fn, plan = dryrun_lib.build_cell(cfg, shape, mesh)
    compiled = lower_fn().compile()
    assert compiled.memory_analysis().temp_size_in_bytes >= 0


if __name__ == "__main__":
    print("RESULTS_JSON:" + json.dumps(RESULTS))
