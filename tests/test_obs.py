"""Observability subsystem: tracer ring semantics, disabled fast path,
Perfetto export schema, exact percentile delegation, engine span/TTFT
reconciliation, trace-fed stage rebalancing, and the kernel dispatch
recorder.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.obs import (DEFAULT_BOUNDS, NULL_TRACER, ManualClock,
                       MetricsRegistry, Tracer, chrome_trace, or_null,
                       percentile, stage_tick_times,
                       synthesize_pipeline_ticks, write_trace)
from repro.obs.metrics import Histogram
from repro.obs.trace import _NOOP_SPAN


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering():
    clk = ManualClock()
    tr = Tracer(clock=clk)
    with tr.span("outer", track="t"):
        clk.advance(1.0)
        with tr.span("inner", track="t", step=3):
            clk.advance(0.5)
        clk.advance(0.25)
    ev = tr.events
    # children exit (and therefore land) before their parent
    assert [e["name"] for e in ev] == ["inner", "outer"]
    inner, outer = ev
    assert outer["depth"] == 0 and inner["depth"] == 1
    assert outer["ts"] == 0.0 and outer["dur"] == pytest.approx(1.75)
    assert inner["ts"] == 1.0 and inner["dur"] == pytest.approx(0.5)
    assert inner["args"] == {"step": 3}
    # depth bookkeeping unwinds: a sibling span is back at depth 0
    with tr.span("sibling", track="t"):
        pass
    assert tr.events[-1]["depth"] == 0


def test_instant_and_complete():
    clk = ManualClock(5.0)
    tr = Tracer(clock=clk)
    tr.instant("sched.admit", track="sched", rid=7)
    tr.complete("req.prefill", 1.0, 3.5, track="slot0", rid=7)
    inst, comp = tr.events
    assert inst["ph"] == "i" and inst["ts"] == 5.0
    assert inst["args"]["rid"] == 7
    assert comp["ph"] == "X" and comp["ts"] == 1.0 and comp["dur"] == 2.5


def test_ring_wraparound_keeps_newest():
    tr = Tracer(capacity=4, clock=ManualClock())
    for i in range(10):
        tr.instant("e", i=i)
    assert tr.capacity == 4
    assert [e["args"]["i"] for e in tr.events] == [6, 7, 8, 9]


def test_disabled_path_allocates_nothing():
    tr = Tracer(enabled=False)
    # every span() call returns the one shared no-op singleton
    assert tr.span("a") is _NOOP_SPAN
    assert tr.span("b", track="x", big_arg=list(range(100))) is _NOOP_SPAN
    with tr.span("c"):
        pass
    tr.instant("d")
    tr.complete("e", 0.0, 1.0)
    tr.extend([{"ph": "i", "name": "f", "track": "m", "ts": 0, "args": {}}])
    assert tr.events == []
    assert or_null(None) is NULL_TRACER
    assert or_null(tr) is tr


def test_extend_merges_probe_tracer():
    probe = Tracer(clock=ManualClock())
    with probe.span("stage_tick", track="stage0", stage=0):
        pass
    main = Tracer(clock=ManualClock())
    main.extend(probe.events)
    assert main.span_names() == {"stage_tick": 1}


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _sample_tracer_registry():
    clk = ManualClock()
    tr = Tracer(clock=clk)
    reg = MetricsRegistry(clock=clk)
    with tr.span("decode_step", track="engine", step=0):
        clk.advance(2e-3)
    tr.instant("sched.admit", track="sched", rid=0)
    reg.gauge("pool.used_blocks").set(3)
    clk.advance(1e-3)
    reg.gauge("pool.used_blocks").set(5)
    return tr, reg


def test_chrome_trace_schema_valid():
    tr, reg = _sample_tracer_registry()
    obj = json.loads(json.dumps(chrome_trace(tr, reg)))   # JSON round-trip
    ev = obj["traceEvents"]
    assert ev and obj["displayTimeUnit"] == "ms"
    for e in ev:
        for key in ("ph", "ts", "pid", "tid"):
            assert key in e, (key, e)
    by_ph = {}
    for e in ev:
        by_ph.setdefault(e["ph"], []).append(e)
    assert all("dur" in e for e in by_ph["X"])
    assert all(e["s"] == "t" for e in by_ph["i"])
    # one thread_name metadata row per track, plus the process_name row
    meta = {e["args"]["name"] for e in by_ph["M"] if
            e["name"] == "thread_name"}
    assert {"engine", "sched", "counter:pool.used_blocks"} <= meta
    # gauge series became counter events in microseconds on the same clock
    cts = [(e["ts"], e["args"]["value"]) for e in by_ph["C"]]
    assert cts == [(2e-3 * 1e6, 3.0), (3e-3 * 1e6, 5.0)]
    # span timestamps are microseconds
    assert by_ph["X"][0]["dur"] == pytest.approx(2e3)


def test_write_trace_suffix_dispatch(tmp_path):
    tr, reg = _sample_tracer_registry()
    jpath = tmp_path / "t.json"
    n = write_trace(str(jpath), tr, reg)
    obj = json.loads(jpath.read_text())
    assert len(obj["traceEvents"]) == n
    lpath = tmp_path / "t.jsonl"
    n = write_trace(str(lpath), tr, reg)
    lines = [json.loads(x) for x in lpath.read_text().splitlines()]
    assert len(lines) == n
    assert lines[0]["ph"] == "X" and lines[0]["ts"] == 0.0   # seconds
    assert "metrics" in lines[-1]
    assert lines[-1]["metrics"]["gauges"]["pool.used_blocks"]["peak"] == 5.0


# ---------------------------------------------------------------------------
# metrics: exact percentiles, serving-metrics delegation
# ---------------------------------------------------------------------------

def test_percentile_matches_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 100):
        xs = rng.exponential(0.01, n).tolist()
        for q in (0, 25, 50, 95, 99, 100):
            assert percentile(xs, q) == pytest.approx(
                float(np.percentile(xs, q)), rel=0, abs=0)


def test_histogram_exact_window_then_bucket_fallback():
    h = Histogram(DEFAULT_BOUNDS, max_samples=8)
    rng = np.random.default_rng(1)
    xs = rng.exponential(0.01, 8).tolist()
    for x in xs:
        h.observe(x)
    assert h.exact
    assert h.percentile(95) == float(np.percentile(xs, 95))
    assert h.summary()["mean"] == sum(xs) / len(xs)
    for x in rng.exponential(0.01, 8):
        h.observe(float(x))          # ages the window out: 16 > max_samples
    assert not h.exact and h.count == 16
    p50 = h.percentile(50)
    assert h.min <= p50 <= h.max     # bucket interpolation stays bounded


def test_serving_dist_delegates_to_obs():
    from repro.serving import metrics as sm
    assert sm.percentile is percentile
    rng = np.random.default_rng(2)
    xs = rng.exponential(0.005, 37).tolist()
    d = sm._dist(xs)
    assert d["mean"] == sum(xs) / len(xs)
    for q, key in ((50, "p50"), (95, "p95"), (99, "p99")):
        assert d[key] == float(np.percentile(xs, q))


def test_registry_snapshot():
    clk = ManualClock()
    reg = MetricsRegistry(clock=clk)
    reg.counter("c").inc()
    reg.counter("c").inc(2.0)
    reg.gauge("g").set(4)
    reg.gauge("g").set(1)
    reg.histogram("h").observe(0.5)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.0
    assert snap["gauges"]["g"] == {"value": 1.0, "peak": 4.0, "points": 2}
    assert snap["histograms"]["h"]["count"] == 1
    assert reg.counter("c") is reg.counter("c")      # get-or-create


# ---------------------------------------------------------------------------
# serving engine: spans reconcile with TTFT/TPOT on the simulated clock
# ---------------------------------------------------------------------------

def _engine_run_with_trace():
    import jax

    from repro.cache_layout import CacheLayout
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    from repro.serving import engine as eng
    from repro.serving import traffic

    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(6):
        reqs.append(traffic.Request(
            rid=i, user_id=i,
            prompt=tuple(int(t) for t in
                         rng.integers(3, cfg.vocab_size,
                                      int(rng.integers(4, 12)))),
            max_new_tokens=int(rng.integers(3, 8)),
            arrival=0.002 * i))
    layout = CacheLayout(kind="paged", block_size=8)
    backend = eng.make_backend(cfg, params, layout=layout)
    ecfg = eng.EngineConfig(n_slots=2, max_len=64, layout=layout)
    clock = traffic.Clock(fixed_decode_s=1e-3, fixed_prefill_s=5e-3)
    tracer = Tracer()
    registry = MetricsRegistry()
    engine = eng.ServingEngine(backend, ecfg, clock=clock, tracer=tracer,
                               metrics=registry)
    outputs, records, summary = engine.run(reqs)
    return records, summary, tracer, registry


def test_engine_spans_reconcile_with_ttft_tpot():
    records, summary, tracer, registry = _engine_run_with_trace()
    spans = {}
    for e in tracer.events:
        if e["ph"] == "X" and e["name"].startswith("req."):
            spans.setdefault(e["args"]["rid"], {})[e["name"]] = e
    finished = [r for r in records if r.finished is not None]
    assert finished, "no requests finished"
    for r in finished:
        sp = spans[r.rid]
        assert set(sp) == {"req.queue_wait", "req.prefill", "req.decode"}
        # TTFT = queue_wait + prefill span durations, exactly (same
        # RequestRecord timestamps, same simulated clock domain)
        ttft = sp["req.queue_wait"]["dur"] + sp["req.prefill"]["dur"]
        assert ttft == pytest.approx(r.ttft, abs=1e-12)
        if r.tpot is not None:
            tpot = sp["req.decode"]["dur"] / (r.tokens_out - 1)
            assert tpot == pytest.approx(r.tpot, abs=1e-12)
        assert sp["req.decode"]["args"]["tokens_out"] == r.tokens_out
        # all three phases share the request's slot track
        assert len({e["track"] for e in sp.values()}) == 1
    # scheduler instants: one admission per finished request
    admits = [e for e in tracer.events
              if e["ph"] == "i" and e["name"] == "sched.admit"]
    assert len(admits) >= len(finished)
    # decode_step spans ride the engine track with modeled roofline args
    steps = [e for e in tracer.events if e["name"] == "decode_step"]
    assert len(steps) == summary["decode_steps"]
    assert steps[0]["track"] == "engine"
    assert steps[0]["args"]["attn_read_bytes"] > 0
    assert steps[0]["args"]["model_flops"] > 0
    # summary carries the obs section; pool metrics landed in the registry
    assert summary["obs"]["span_counts"]["decode_step"] == len(steps)
    snap = registry.snapshot()
    assert snap["gauges"]["pool.used_blocks"]["peak"] > 0
    assert "pool.shared_hits" in snap["counters"]
    assert "pool.cow_events" in snap["counters"]
    assert snap["gauges"]["engine.active_slots"]["peak"] == \
        summary["max_concurrent_slots"]
    # and the whole thing exports schema-valid
    obj = chrome_trace(tracer, registry)
    for e in obj["traceEvents"]:
        for key in ("ph", "ts", "pid", "tid"):
            assert key in e


def test_untraced_engine_summary_has_no_obs():
    import jax

    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    from repro.serving import engine as eng
    from repro.serving import traffic

    cfg = dataclasses.replace(reduced(get_arch("olmo-1b")), dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    reqs = [traffic.Request(rid=0, user_id=0, prompt=(5, 6, 7),
                            max_new_tokens=3, arrival=0.0)]
    backend = eng.make_backend(cfg, params)
    engine = eng.ServingEngine(backend, eng.EngineConfig(n_slots=1,
                                                         max_len=32))
    _, _, summary = engine.run(reqs)
    assert "obs" not in summary
    assert not engine.tracer.enabled


# ---------------------------------------------------------------------------
# straggler harness on the registry
# ---------------------------------------------------------------------------

def test_straggler_metrics_registry_equivalence():
    from repro.runtime import straggler

    sim = straggler.StragglerSim(n_workers=4, seed=3)
    base = straggler.run_policy(sim, 256, 20, "adaptive")
    reg, clk = MetricsRegistry(), ManualClock()
    out = straggler.run_policy(sim, 256, 20, "adaptive",
                               metrics=reg, clock=clk)
    assert out == base                       # same math, caller-held registry
    hist = reg.histogram("straggler.step_time_s")
    assert hist.count == 20
    # the simulated clock ends at the total simulated duration
    assert clk.now == pytest.approx(hist.total)
    assert reg.gauge("straggler.slowest_worker_t").peak > 0
    assert len(reg.gauge("straggler.slowest_worker_t").series) == 20


# ---------------------------------------------------------------------------
# trace-fed pipeline rebalancing
# ---------------------------------------------------------------------------

def _pp_setup():
    import jax

    from repro.config import get_arch, reduced
    from repro.models import transformer as tf

    cfg = dataclasses.replace(
        reduced(get_arch("olmo-1b"), layers=8), dtype="float32",
        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    bounds = [0, 1, 8]                       # skewed: stage 1 has 7 layers
    pp = tf.pp_partition_params(cfg, params, bounds)
    return cfg, pp, bounds


def test_stage_tick_spans_feed_rebalance():
    from repro.core import load_balance
    from repro.runtime import trainer

    cfg, pp, bounds = _pp_setup()
    tr = Tracer()
    times = trainer.probe_stage_times(cfg, pp, bounds, iters=3, tracer=tr)
    ticks = [e for e in tr.events if e["name"] == "stage_tick"]
    assert len(ticks) == 3 * (len(bounds) - 1)
    assert {e["track"] for e in ticks} == {"stage0", "stage1"}
    # the trace recovers the probe's own medians exactly (same samples,
    # same sort-then-middle reduction)
    assert stage_tick_times(tr.events, len(bounds) - 1) == list(times)
    # ... so trace-fed rebalancing lands on the same bounds
    assert load_balance.rebalance_from_trace(tr.events, bounds) == \
        load_balance.rebalance_stages(times, bounds)


def test_synthesized_pipeline_timeline():
    for sched in ("1f1b", "gpipe"):
        tr = Tracer()
        end = synthesize_pipeline_ticks(tr, sched, n_stages=4, n_micro=8,
                                        stage_times=[1e-3] * 4)
        ev = tr.events
        fwd = [e for e in ev if e["name"] == "pp.fwd"]
        bwd = [e for e in ev if e["name"] == "pp.bwd"]
        assert len(fwd) == len(bwd) == 4 * 8
        assert {e["track"] for e in ev} == {f"stage{s}" for s in range(4)}
        # bwd ticks cost bwd_cost_ratio x fwd
        assert fwd[0]["dur"] == pytest.approx(1e-3)
        assert bwd[0]["dur"] == pytest.approx(2e-3)
        assert end >= 8 * 3e-3               # makespan >= useful work
        # no span crosses the end, every stage's micros appear once
        for s in range(4):
            micros = sorted(e["args"]["micro"] for e in fwd
                            if e["args"]["stage"] == s)
            assert micros == list(range(8))
        assert max(e["ts"] + e["dur"] for e in ev) == pytest.approx(end)


# ---------------------------------------------------------------------------
# kernel dispatch recorder
# ---------------------------------------------------------------------------

def test_ops_dispatch_recorder():
    import jax
    import jax.numpy as jnp

    from repro.cache_layout import CacheLayout
    from repro.kernels import ops

    records = []
    prev = ops.set_dispatch_recorder(records.append)
    try:
        B, S, Hk, H, D = 2, 16, 2, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        cache = {"k": jax.random.normal(ks[1], (B, S, Hk, D)),
                 "v": jax.random.normal(ks[2], (B, S, Hk, D))}
        lengths = jnp.asarray([5, 9], jnp.int32)
        out = ops.decode_attention(q, cache, lengths,
                                   layout=CacheLayout(impl="dense"))
        assert out.shape == (B, 1, H, D)
        assert len(records) == 1
        r = records[0]
        assert r["op"] == "decode_attention" and r["impl"] == "dense"
        assert r["batch"] == B and r["heads"] == H and r["head_dim"] == D
        assert r["s_max"] == S
        assert r["kv_resident_bytes"] == 2 * B * S * Hk * D * 4  # float32
        assert r["modeled_flops"] == 4.0 * B * H * D * S
    finally:
        ops.set_dispatch_recorder(prev)
    # recorder removed: further dispatches record nothing
    ops.decode_attention(q, cache, lengths,
                         layout=CacheLayout(impl="dense"))
    assert len(records) == 1
