"""Sharding plan unit tests (single-device mesh: rules only, no collectives)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.config import ParallelConfig, SHAPES, get_arch, reduced
from repro.core.hybrid import auto_plan
from repro.core.sharding import ShardingPlan, make_plan
from repro.models import transformer as tf


def mesh11():
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def plan():
    return make_plan(mesh11(), ParallelConfig())


def specs_for(arch, plan):
    cfg = get_arch(arch)
    shapes = jax.eval_shape(lambda k: tf.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    return cfg, shapes, plan.param_specs(cfg, shapes)


def test_dense_param_rules(plan):
    cfg, shapes, specs = specs_for("olmo-1b", plan)
    # embedding: vocab over model
    assert specs["embed"] == P("model", None)
    blk = specs["blocks"]
    # stacked layer dim is unsharded; qkv column-parallel, wo row-parallel
    assert blk["attn"]["wq"] == P(None, None, "model")
    assert blk["attn"]["wo"] == P(None, "model", None)
    assert blk["ffn"]["mlp"]["wi_gate"] == P(None, None, "model")
    assert blk["ffn"]["mlp"]["wo"] == P(None, "model", None)


def test_embed_plan_routes_cf_tables():
    """Top-level table keys named in ``embed_plans`` take their placement
    from the embeddings subsystem (row/col/2D) instead of the LM rules;
    non-dividing tables fall back to replication via the plan guard."""
    from repro.recsys import model as recsys_model
    am = compat.abstract_mesh((4, 4), ("data", "model"))
    shapes = {"cf_user": jax.ShapeDtypeStruct((64, 8), jnp.float32),
              "cf_item": jax.ShapeDtypeStruct((256, 8), jnp.float32),
              "odd": jax.ShapeDtypeStruct((63, 8), jnp.float32)}
    cfg = get_arch("recllm-base")
    plans = recsys_model.embed_plans("row")
    from repro.embeddings import make_plan as embed_make_plan
    plans["odd"] = embed_make_plan("row")
    sp = ShardingPlan(mesh=am, dp_axes=("data",), tp_axis="model",
                      embed_plans=plans)
    specs = sp.param_specs(cfg, shapes)
    assert specs["cf_user"] == P("model", None)
    assert specs["cf_item"] == P("model", None)
    assert specs["odd"] == P(None, None)        # 63 rows: guard replicates
    # 2D (row x col) placement flows through too
    sp2 = ShardingPlan(mesh=am, dp_axes=("data",), tp_axis="model",
                       embed_plans={"cf_user": embed_make_plan("row_col")})
    assert sp2.param_specs(cfg, shapes)["cf_user"] == P("model", "data")
    # without plans, the tables fall back to replicated LM rules
    sp3 = ShardingPlan(mesh=am, dp_axes=("data",), tp_axis="model")
    assert sp3.param_specs(cfg, shapes)["cf_user"] == P(None, None)


def test_gqa_kv_replication_rule():
    """Production-mesh rules via AbstractMesh (no devices needed)."""
    import dataclasses
    am = compat.abstract_mesh((16, 16), ("data", "model"))
    sp = ShardingPlan(mesh=am, dp_axes=("data",), tp_axis="model")
    # guard: a dim of size 8 cannot shard over 16 — falls back to None
    assert sp.guard(("model",), (8,)) == P(None)
    assert sp.guard(("model",), (16384,)) == P("model")
    cfg = get_arch("internlm2-20b")
    shapes = jax.eval_shape(lambda k: tf.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = sp.param_specs(cfg, shapes)
    blk = specs["blocks"]
    # q heads 48 % 16 == 0 -> sharded; kv 8 < 16 -> replicated (GQA rule)
    assert blk["attn"]["wq"] == P(None, None, "model")
    assert blk["attn"]["wk"] == P(None, None, None)
    assert blk["attn"]["wv"] == P(None, None, None)


def test_moe_expert_rules(plan):
    cfg, shapes, specs = specs_for("qwen3-moe-30b-a3b", plan)
    blk = specs["blocks"]
    assert blk["ffn"]["moe"]["wi_gate"][1] == "model"   # (L, E, d, f)
    assert blk["ffn"]["moe"]["router"] == P(None, None, None)


def test_zero1_adds_dp_axis():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    sp = make_plan(mesh, ParallelConfig())
    z = sp.zero1_spec(P(None, "model"), (64, 32))
    assert z == P("data", "model")
    # already dp-sharded: unchanged
    z2 = sp.zero1_spec(P("data", None), (64, 32))
    assert z2 == P("data", None)


def test_constrain_is_noop_without_real_sharding(plan):
    x = jnp.ones((4, 8, 16))
    y = plan.constrain(x, "residual")
    assert y.shape == x.shape


def test_auto_plan_dp_heavy_choice():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    # tp=1: dp_heavy not applicable
    plan = auto_plan(get_arch("internlm2-20b"), mesh, SHAPES["train_4k"])
    assert not plan.sharding.dp_heavy
    # moe archs never pick dp_heavy
    plan2 = auto_plan(get_arch("qwen3-moe-30b-a3b"), mesh,
                      SHAPES["train_4k"])
    assert not plan2.sharding.dp_heavy


def test_batch_and_cache_specs(plan):
    cfg = get_arch("olmo-1b")
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    bs = plan.batch_specs(batch)
    # jax >= 0.5 canonicalizes the singleton dp-axes tuple to its string
    assert bs["tokens"][0] in ("data", ("data",))
    cache = jax.eval_shape(
        lambda: tf.init_cache(reduced(cfg), 8, 32))
    cs = plan.cache_specs(cfg, cache)
    # (L, B, S, Hk, D): batch dim carries the dp axes
    assert cs["k"][1] in ("data", ("data",))
